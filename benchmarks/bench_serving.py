"""Continuous-batching serving bench — what coalescing + double-buffering buy.

Open-loop comparison on a mixed-width workload (rooms-M routes queries over
three bucket widths, so arrival order interleaves dispatch keys):

* **fixed-batch baseline** — requests are popped FIFO in arrival order and
  pushed through ``PathServer.query`` in ``batch_size`` chunks.  Each chunk
  fragments over the dispatch keys present in it and every fragment is
  padded to ``batch_size``, so occupancy collapses as key diversity grows.
* **continuous batching** — the same arrivals go through ``submit()`` into
  the :class:`~repro.serving.batcher.CoalescingBatcher`: per-key groups
  fill across chunk boundaries (full flushes under load, deadline flushes
  at the tail) and dispatch is double-buffered.

Two phases per engine:

1. *capacity* — every request is queued at t=0 and the drain is timed
   (closed-system throughput ceiling);
2. *rate* — open-loop Poisson arrivals at ~1.6x the baseline's measured
   capacity: the baseline saturates (queue grows, p99 blows up) while the
   coalescing loop sustains the rate, which is the >= 1.5x qps-at-equal-p99
   acceptance gate.  Midway through the async rate phase the engine is
   hot-swapped (same artifact content repacked under a new generation), so
   the bitwise-identity check also covers swap-under-load: queued groups
   re-route, in-flight groups finish pinned.

    PYTHONPATH=src python -m benchmarks.bench_serving --smoke

``--smoke`` shrinks the workload and relaxes the qps gate to 1.15x (CI);
exits nonzero when a gate fails either way.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro import obs
from repro.core import pack_bucketed, uniform_queries
from repro.indexing import SwappableEngine
from repro.serving import JnpEngine, PathServer

from . import common



def _occupancy(stats) -> float:
    q = sum(b.queries for b in stats.per_bucket.values())
    sl = sum(b.slots for b in stats.per_bucket.values())
    return q / max(1, sl)


def _pcts(lat_s: np.ndarray) -> tuple:
    ms = 1e3 * lat_s
    return float(np.percentile(ms, 50)), float(np.percentile(ms, 99))


def _pcts3(lat_s: np.ndarray) -> tuple:
    ms = 1e3 * lat_s
    return (float(np.percentile(ms, 50)), float(np.percentile(ms, 95)),
            float(np.percentile(ms, 99)))


def _burst_baseline(srv, s, t) -> float:
    """Closed-system capacity of the FIFO fixed-batch path (qps)."""
    n, bs = len(s), srv.batch_size
    t0 = time.perf_counter()
    for lo in range(0, n, bs):
        srv.query(s[lo:lo + bs], t[lo:lo + bs])
    return n / (time.perf_counter() - t0)


def _burst_async(srv, s, t, max_wait_ms: float) -> float:
    """Closed-system capacity of the coalescing loop (qps)."""
    srv.start_async(max_wait_ms=max_wait_ms)
    t0 = time.perf_counter()
    tickets = [srv.submit(s[i], t[i]) for i in range(len(s))]
    srv.flush()
    srv.drain(timeout=600)
    qps = len(s) / (time.perf_counter() - t0)
    for tk in tickets:
        tk.result(timeout=1)
    srv.stop_async()
    return qps


def _rate_baseline(srv, s, t, arrivals):
    """Open-loop replay through FIFO fixed-batch chunks.

    Arrivals are independent of service (the open-loop property): a chunk
    is cut from whatever has arrived by the clock, at most ``batch_size``
    FIFO entries at a time."""
    n, bs = len(s), srv.batch_size
    out = np.zeros(n, np.float32)
    done = np.zeros(n)
    t0 = time.perf_counter()
    i = 0
    while i < n:
        now = time.perf_counter() - t0
        arrived = int(np.searchsorted(arrivals, now, side="right"))
        if arrived <= i:
            time.sleep(min(1e-3, max(0.0, arrivals[i] - now)))
            continue
        j = min(i + bs, arrived)
        out[i:j] = srv.query(s[i:j], t[i:j])
        done[i:j] = time.perf_counter() - t0
        i = j
    return out, done - arrivals, n / done.max()


def _rate_async(srv, s, t, arrivals, max_wait_ms: float, swap_fn=None):
    """Open-loop replay through ``submit()``; optional mid-stream swap."""
    n = len(s)
    half = n // 2
    srv.start_async(max_wait_ms=max_wait_ms)
    t0 = time.perf_counter()
    tickets = []
    for i in range(n):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
        if swap_fn is not None and i == half:
            swap_fn()
        tickets.append(srv.submit(s[i], t[i]))
    srv.flush()
    srv.drain(timeout=600)
    t_end = time.perf_counter()
    out = np.concatenate([tk.result(timeout=1) for tk in tickets])
    lat = np.array([tk.completed_at - (t0 + a)
                    for tk, a in zip(tickets, arrivals)])
    srv.stop_async()
    return out, lat, n / (t_end - t0)


def run(map_name: str = "rooms-M", budget: float = 0.3,
        batch_size: int = 64, quick: bool = False):
    """Returns (csv rows, gate-failure strings)."""
    # Compile/cost capture must be live before the FIRST warmup: the pjit
    # cache is process-wide, so every cold compile in this bench happens
    # exactly once — at srv_ref.warmup() below.  The capture gets its own
    # registry so its series don't dilute the overhead-gate registries.
    prof = obs.enable_profile(registry=obs.MetricsRegistry())
    try:
        return _run(map_name, budget, batch_size, quick, prof)
    finally:
        obs.disable_profile()


def _run(map_name, budget, batch_size, quick, prof):
    n = 600 if quick else 2000
    wait_ms = 5.0
    min_ratio = 1.15 if quick else 1.5
    ctx = common.suite(map_name)
    idx, _, _ = common.ehl_star_cached(ctx, budget)
    bx = pack_bucketed(idx)
    qs = uniform_queries(ctx.scene, ctx.graph, n, seed=7,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)

    rows = [common.emit(
        f"serving/{map_name}/workload", 0.0,
        f"n={n};widths={list(bx.widths)};batch={batch_size}")]

    # sync reference (also traces every jit entry these shapes can hit —
    # identical-shaped repacks below reuse the same executables)
    srv_ref = PathServer(JnpEngine(bx), batch_size=batch_size)
    srv_ref.warmup()
    ref = srv_ref.query(s, t)

    srv_base = PathServer(JnpEngine(bx), batch_size=batch_size)
    srv_base.warmup()
    cap_base = _burst_baseline(srv_base, s, t)
    occ_base_cap = _occupancy(srv_base.stats)

    swap = SwappableEngine(JnpEngine(bx))
    srv_async = PathServer(swap, batch_size=batch_size)
    srv_async.warmup()
    cap_async = _burst_async(srv_async, s, t, wait_ms)
    rows.append(common.emit(
        f"serving/{map_name}/capacity", 0.0,
        f"qps_fixed={cap_base:.0f};qps_async={cap_async:.0f};"
        f"ratio={cap_async / cap_base:.2f};occ_fixed={occ_base_cap:.2f}"))

    # open-loop rate: past the baseline's ceiling, inside the async one
    rate = min(1.6 * cap_base, 0.85 * cap_async)
    rng = np.random.default_rng(17)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))

    srv_base2 = PathServer(JnpEngine(bx), batch_size=batch_size)
    out_b, lat_b, qps_b = _rate_baseline(srv_base2, s, t, arrivals)
    p50_b, p99_b = _pcts(lat_b)
    occ_b = _occupancy(srv_base2.stats)

    # swap target: same artifact content repacked -> answers must not move
    bx2 = pack_bucketed(idx)
    eng2 = JnpEngine(bx2)
    swap2 = SwappableEngine(JnpEngine(bx))
    srv_async2 = PathServer(swap2, batch_size=batch_size)
    srv_async2.warmup()
    out_a, lat_a, qps_a = _rate_async(
        srv_async2, s, t, arrivals, wait_ms,
        swap_fn=lambda: swap2.swap(eng2))
    p50_a, p99_a = _pcts(lat_a)
    occ_a = _occupancy(srv_async2.stats)
    st = srv_async2.stats

    identical = bool(np.array_equal(ref, out_b)
                     and np.array_equal(ref, out_a))
    ratio = qps_a / qps_b
    rows.append(common.emit(
        f"serving/{map_name}/fixed_batch", 1e6 / max(1.0, qps_b),
        f"qps={qps_b:.0f};p50_ms={p50_b:.1f};p99_ms={p99_b:.1f};"
        f"occupancy={occ_b:.2f}"))
    rows.append(common.emit(
        f"serving/{map_name}/continuous", 1e6 / max(1.0, qps_a),
        f"qps={qps_a:.0f};p50_ms={p50_a:.1f};p99_ms={p99_a:.1f};"
        f"occupancy={occ_a:.2f};ratio={ratio:.2f};"
        f"full={st.full_flushes};deadline={st.deadline_flushes};"
        f"swaps={st.swaps};requeued={st.requeued_batches};"
        f"stale={st.stale_batches};identical={identical}"))

    # ---- instrumentation-overhead gate (DESIGN.md §12) ------------------
    # Same workload, two private registries: head-sampling enabled (the
    # production default) vs ``Telemetry.off()``.  The registry records in
    # both — it IS the serving stats — so the delta isolates what spans +
    # events cost.  Closed-system capacity (best-of-3, interleaved) gives
    # the throughput ratio; an open-loop replay at the shared rate gives
    # p99 at equal offered load.
    tel_on = obs.Telemetry(registry=obs.MetricsRegistry(), sample_rate=0.05)
    tel_off = obs.Telemetry.off(registry=obs.MetricsRegistry())
    srv_on = PathServer(JnpEngine(bx), batch_size=batch_size,
                        telemetry=tel_on)
    srv_off = PathServer(JnpEngine(bx), batch_size=batch_size,
                         telemetry=tel_off)
    srv_on.warmup()
    srv_off.warmup()
    cap_on = cap_off = 0.0
    for _ in range(3):
        cap_off = max(cap_off, _burst_async(srv_off, s, t, wait_ms))
        cap_on = max(cap_on, _burst_async(srv_on, s, t, wait_ms))
    ratio_tel = cap_on / cap_off
    _, lat_off, _ = _rate_async(srv_off, s, t, arrivals, wait_ms)
    _, lat_on, _ = _rate_async(srv_on, s, t, arrivals, wait_ms)
    p50_off, p95_off, p99_off = _pcts3(lat_off)
    p50_on, p95_on, p99_on = _pcts3(lat_on)

    # ---- profile-capture overhead gate (DESIGN.md §13) ------------------
    # Same servers, steady state (everything compiled long ago): with the
    # capture installed every dispatch goes through the profiler wrapper
    # (thread-local trace check + stopwatch); with it disabled the wrapper
    # short-circuits to the bare jit callable.  Interleaved best-of-3
    # capacity + an open-loop replay for p99 at equal offered load.
    cap_pon = cap_poff = 0.0
    for _ in range(3):
        obs.disable_profile()
        cap_poff = max(cap_poff, _burst_async(srv_off, s, t, wait_ms))
        obs.enable_profile(capture=prof)
        cap_pon = max(cap_pon, _burst_async(srv_off, s, t, wait_ms))
    ratio_prof = cap_pon / cap_poff
    obs.disable_profile()
    _, lat_poff, _ = _rate_async(srv_off, s, t, arrivals, wait_ms)
    obs.enable_profile(capture=prof)
    _, lat_pon, _ = _rate_async(srv_off, s, t, arrivals, wait_ms)
    _, _, p99_poff = _pcts3(lat_poff)
    _, _, p99_pon = _pcts3(lat_pon)
    compiles = prof.summary()
    compile_s = sum(r["compile_s"] for r in compiles.values())
    rows.append(common.emit(
        f"serving/{map_name}/profile_overhead", 0.0,
        f"qps_on={cap_pon:.0f};qps_off={cap_poff:.0f};"
        f"ratio={ratio_prof:.3f};p99_on={p99_pon:.1f};"
        f"p99_off={p99_poff:.1f};entries={len(compiles)};"
        f"compile_s={compile_s:.2f}"))

    # span attribution: telescoping stages must reproduce e2e (<= 5% gap)
    spans = tel_on.spans.traces("async")
    gaps = [abs(tr.e2e_seconds - tr.stage_sum) / tr.e2e_seconds
            for tr in spans if tr.e2e_seconds > 0]
    span_gap = max(gaps) if gaps else float("nan")
    rows.append(common.emit(
        f"serving/{map_name}/telemetry_overhead", 0.0,
        f"qps_on={cap_on:.0f};qps_off={cap_off:.0f};ratio={ratio_tel:.3f};"
        f"p99_on={p99_on:.1f};p99_off={p99_off:.1f};"
        f"spans={len(spans)};span_gap={span_gap:.4f}"))

    failures = []
    if ratio_tel < 0.97:
        failures.append(
            f"telemetry overhead: sampled qps {cap_on:.0f} is "
            f"{ratio_tel:.3f}x of disabled {cap_off:.0f} (< 0.97x gate)")
    if p99_on > 1.25 * p99_off + 2.0:
        failures.append(
            f"telemetry overhead: p99 {p99_on:.1f}ms vs disabled "
            f"{p99_off:.1f}ms (> 1.25x + 2ms band)")
    if ratio_prof < 0.97:
        failures.append(
            f"profile capture: qps {cap_pon:.0f} is {ratio_prof:.3f}x of "
            f"capture-off {cap_poff:.0f} (< 0.97x gate)")
    if p99_pon > 1.25 * p99_poff + 2.0:
        failures.append(
            f"profile capture: p99 {p99_pon:.1f}ms vs capture-off "
            f"{p99_poff:.1f}ms (> 1.25x + 2ms band)")
    if not compiles:
        failures.append("profile capture recorded no compiles "
                        "(was it enabled before the first warmup?)")
    if not spans:
        failures.append("head sampling produced no async spans")
    elif span_gap > 0.05:
        failures.append(f"span stage attribution off by {span_gap:.1%} "
                        "of e2e (> 5% gate)")
    if not identical:
        failures.append("answers differ from the sync reference "
                        "(across hot-swap under load)")
    if ratio < min_ratio:
        failures.append(f"qps ratio {ratio:.2f} below {min_ratio}x gate "
                        f"(fixed={qps_b:.0f}, continuous={qps_a:.0f})")
    if p99_a > p99_b:
        failures.append(f"continuous p99 {p99_a:.1f}ms worse than "
                        f"fixed-batch {p99_b:.1f}ms")
    if st.swaps < 1:
        failures.append("mid-stream hot-swap was not observed")
    if st.full_flushes < 1 or st.deadline_flushes < 1:
        failures.append(f"flush mix degenerate (full={st.full_flushes}, "
                        f"deadline={st.deadline_flushes})")

    common.write_bench_json(
        "serving", qps=qps_a, p50_ms=p50_on, p95_ms=p95_on, p99_ms=p99_on,
        device_bytes=bx.device_bytes(), registry=tel_on.registry,
        data=dict(map=map_name, budget_frac=budget, n=n,
                  batch_size=batch_size, max_wait_ms=wait_ms,
                  capacity_qps=dict(fixed=cap_base, continuous=cap_async),
                  rate_qps=rate,
                  fixed=dict(qps=qps_b, p50_ms=p50_b, p99_ms=p99_b,
                             occupancy=occ_b),
                  continuous=dict(qps=qps_a, p50_ms=p50_a, p99_ms=p99_a,
                                  occupancy=occ_a,
                                  full_flushes=st.full_flushes,
                                  deadline_flushes=st.deadline_flushes,
                                  swaps=st.swaps,
                                  requeued=st.requeued_batches,
                                  stale=st.stale_batches),
                  telemetry_overhead=dict(
                      qps_on=cap_on, qps_off=cap_off, ratio=ratio_tel,
                      p50_on_ms=p50_on, p95_on_ms=p95_on, p99_on_ms=p99_on,
                      p50_off_ms=p50_off, p95_off_ms=p95_off,
                      p99_off_ms=p99_off, spans=len(spans),
                      span_gap=span_gap),
                  profile_overhead=dict(
                      qps_on=cap_pon, qps_off=cap_poff, ratio=ratio_prof,
                      p99_on_ms=p99_pon, p99_off_ms=p99_poff,
                      compile_s=compile_s, compiles=compiles),
                  ratio=ratio, identical=identical, failures=failures))
    return rows, failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--map", default="rooms-M")
    ap.add_argument("--budget", type=float, default=0.3)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI size: small workload, 1.15x qps gate")
    args = ap.parse_args(argv)
    _, failures = run(args.map, args.budget, batch_size=args.batch,
                      quick=args.smoke)
    if failures:
        print("SERVING BENCH FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("serving bench OK")


if __name__ == "__main__":
    main()
