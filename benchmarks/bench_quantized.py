"""Quantized-slab bench — what the DESIGN.md §11 formats buy and cost.

One compressed index, packed under each slab layout (f32 / bf16 / f16),
measured four ways:

* **device bytes** — realized artifact footprint + the analytic estimator
  (``bucketed_device_bytes``) which must agree exactly (the planner and the
  budget loop steer by the estimator, so drift there mis-sizes artifacts);
* **exactness** — distance error vs the f32 engine must sit inside the
  documented ``2 * qerr`` bound, and the argmin winners (covis verdicts +
  via/hub ids, i.e. the extracted paths) must be **bitwise identical** —
  the residual-rescue guarantee, gated in ``--smoke`` CI mode;
* **join latency** — us/query through the bucketed serving engine (the
  quantized gather adds an in-register decode before the same f32 join);
* **regions admitted** — ``compress_to_device_budget`` under one shared
  device-byte budget per layout: narrower slots admit a finer region
  partition, which is the whole point of spending the dtype (full mode
  only — the merge loop is the offline phase).

    PYTHONPATH=src python -m benchmarks.bench_quantized --smoke

``--smoke`` shrinks the workload and skips the merge-loop and async-qps
columns; the exactness + estimator gates run either way (exit nonzero on
any violation).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import (bucketed_device_bytes, compress_to_device_budget,
                        pack_bucketed, query_batch_bucketed, slab_layout,
                        uniform_queries)
from repro.serving import JnpEngine, PathServer

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
DTYPES = ("f32", "bf16", "f16")

# async-qps parity gates vs f32.  bf16 (the serving-recommended dtype —
# native on TPU, a bit shift on CPU) must hold full parity; f16 decode
# pays real conversion instructions on CPU (~5-10% at small async batch
# sizes, a consistent deficit, not jitter) so it gates at 0.90x.
QPS_GATE = {"bf16": 0.95, "f16": 0.90}


def _latency(bx, s, t, batch_size: int, reps: int = 3) -> float:
    srv = PathServer(JnpEngine(bx), batch_size=batch_size)
    srv.warmup()
    best = np.inf
    for _ in range(reps):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(s, t)
        best = min(best, srv.stats.us_per_query)
    return best


def _async_qps(bx, s, t, batch_size: int, reps: int = 2) -> float:
    """Best-of-``reps`` open-loop qps (scheduling jitter is a few percent,
    which matters against a 0.95x parity gate)."""
    srv = PathServer(JnpEngine(bx), batch_size=batch_size)
    srv.warmup()
    best = 0.0
    for _ in range(reps):
        srv.start_async(max_wait_ms=5.0)
        t0 = time.perf_counter()
        tickets = [srv.submit(s[i], t[i]) for i in range(len(s))]
        srv.flush()
        srv.drain(timeout=600)
        qps = len(s) / (time.perf_counter() - t0)
        for tk in tickets:
            tk.result(timeout=1)
        srv.stop_async()
        best = max(best, qps)
    return best


def _exactness(bx, base, s, t) -> tuple:
    """(max |d - d32|, bound, argmin-bitwise?) vs the f32 reference."""
    ref = [np.asarray(r) for r in query_batch_bucketed(
        base, s, t, want_argmin=True)]
    got = [np.asarray(r) for r in query_batch_bucketed(
        bx, s, t, want_argmin=True)]
    qerr = float(np.asarray(bx.qerr)) if bx.qerr is not None else 0.0
    fin = np.isfinite(ref[0])
    err = float(np.max(np.abs(np.where(fin, got[0] - ref[0], 0.0))))
    bound = 2.0 * qerr + 1e-4 * float(np.max(np.abs(
        np.where(fin, ref[0], 0.0)))) + 1e-6
    m = ~ref[1] & fin
    bitwise = (np.array_equal(fin, np.isfinite(got[0]))
               and np.array_equal(ref[1], got[1])
               and all(np.array_equal(r[m], g[m])
                       for r, g in zip(ref[2:], got[2:])))
    return err, bound, bool(bitwise)


def run(map_name: str = "rooms-M", budget: float = 0.3,
        batch_size: int = 64, quick: bool = False):
    """Returns (csv rows, gate-failure strings)."""
    n = 400 if quick else 2000
    ctx = common.suite(map_name)
    idx, _, _ = common.ehl_star_cached(ctx, budget)
    qs = uniform_queries(ctx.scene, ctx.graph, n, seed=7,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)

    rows, failures, table = [], [], {}
    base = pack_bucketed(idx)
    b32 = base.device_bytes()
    qps32 = None
    for dtype in DTYPES:
        lay = slab_layout(dtype)
        bx = base if dtype == "f32" else pack_bucketed(idx, layout=lay)
        est = bucketed_device_bytes(idx, layout=lay)
        if est != bx.device_bytes():
            failures.append(f"{dtype}: analytic estimator {est}B != "
                            f"realized {bx.device_bytes()}B")
        err, bound, bitwise = (0.0, 0.0, True) if dtype == "f32" \
            else _exactness(bx, base, s, t)
        if err > bound:
            failures.append(f"{dtype}: distance error {err:.3e} over the "
                            f"2*qerr bound {bound:.3e}")
        if not bitwise:
            failures.append(f"{dtype}: argmin winners not bitwise-identical "
                            "to the f32 engine")
        us = _latency(bx, s, t, batch_size)
        qps = None if quick else _async_qps(bx, s, t, batch_size)
        if dtype == "f32":
            qps32 = qps
        st = bx.quant_stats() if lay.quantized else {}
        ratio = b32 / bx.device_bytes()
        table[dtype] = dict(
            device_bytes=bx.device_bytes(), ratio=ratio,
            qerr=float(np.asarray(bx.qerr)) if bx.qerr is not None else 0.0,
            max_dist_err=err, argmin_bitwise=bitwise, us_per_query=us,
            async_qps=qps, quant_stats={k: str(v) for k, v in st.items()})
        rows.append(common.emit(
            f"quantized/{map_name}/{dtype}", us,
            f"bytes={bx.device_bytes()};ratio={ratio:.2f};"
            f"err={err:.2e};bitwise={bitwise}"
            + (f";qps={qps:.0f}" if qps else "")))
        gate = QPS_GATE.get(dtype)
        if qps is not None and qps32 and gate and qps < gate * qps32:
            failures.append(f"{dtype}: async qps {qps:.0f} below {gate}x of "
                            f"f32 ({qps32:.0f})")

    if not quick:
        # equal-budget admission: re-merge a fresh region partition under
        # one shared device budget per layout (quantized slots are ~3x
        # narrower, so the same budget keeps ~3x the regions)
        target = int(0.6 * b32)
        snap = None
        for dtype in DTYPES:
            fresh, _ = common.fresh_ehl_cached(ctx)
            if snap is None:
                snap = fresh.snapshot_regions()
            else:
                fresh.restore_regions(snap)
            st = compress_to_device_budget(fresh, target,
                                           layout=slab_layout(dtype))
            table[dtype]["regions_admitted"] = st.regions
            table[dtype]["budget_device_bytes"] = st.device_bytes
            rows.append(common.emit(
                f"quantized/{map_name}/admitted/{dtype}", 0.0,
                f"budget={target};regions={st.regions};"
                f"bytes={st.device_bytes}"))

    os.makedirs(OUT, exist_ok=True)
    # smoke runs keep their own artifact so CI never clobbers the full
    # table (make_tables reads quantized.json for EXPERIMENTS.md §5)
    name = "quantized_smoke.json" if quick else "quantized.json"
    json.dump(dict(map=map_name, budget_frac=budget, n=n,
                   batch_size=batch_size, f32_bytes=b32, table=table,
                   failures=failures),
              open(os.path.join(OUT, name), "w"), indent=1)
    return rows, failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--map", default="rooms-M")
    ap.add_argument("--budget", type=float, default=0.3)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI size: small workload, exactness gates only")
    args = ap.parse_args(argv)
    _, failures = run(args.map, args.budget, batch_size=args.batch,
                      quick=args.smoke)
    if failures:
        print("QUANTIZED BENCH FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("quantized bench OK")


if __name__ == "__main__":
    main()
