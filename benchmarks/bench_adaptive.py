"""Adaptive index lifecycle bench — what a hot-swap costs and buys.

Serves a Cluster-k workload the index was NOT compressed for, lets the
manager adapt, and reports:

* swap pipeline costs (host merge loop, repack+warmup, probe validation);
* expected per-query join cost (mean dispatch-width^2) on the live
  workload: uniform-score artifact vs the adapted workload-aware one at the
  same device-byte budget;
* serving latency before vs after the swap, same engine generation
  accounting the serving stack reports (``ServeStats``).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import bucketed_device_bytes, cluster_queries
from repro.indexing import IndexManager
from repro.serving import PathServer, expected_join_cost

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def _served_us(srv, s, t, reps: int = 3) -> float:
    best = np.inf
    for _ in range(reps):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(s, t)
        best = min(best, srv.stats.us_per_query)
    return best


def run(map_name: str = "rooms-M", budget: float = 0.25, quick: bool = False):
    n = 300 if quick else 1000
    ctx = common.suite(map_name)
    idx, _ = common.fresh_ehl_cached(ctx)
    budget_bytes = int(bucketed_device_bytes(idx) * budget)

    mgr = IndexManager(idx, budget_bytes, batch_size=256,
                       min_queries=n // 2, replan_threshold=0.10, seed=23)
    srv = PathServer(mgr.engine, batch_size=256, recorder=mgr.recorder)
    srv.warmup()

    qs = cluster_queries(ctx.scene, ctx.graph, 4, n, seed=301,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)

    uniform_engine = mgr.engine.current
    jc_uniform = expected_join_cost(uniform_engine, s, t)
    us_before = _served_us(srv, s, t)

    swapped = mgr.maybe_adapt()
    rec = mgr.history[-1] if mgr.history else None
    jc_adapted = expected_join_cost(mgr.engine.current, s, t)
    us_after = _served_us(srv, s, t)

    rows = [common.emit(
        f"adaptive/{map_name}/serve", us_after,
        f"us_before_swap={us_before:.1f};swapped={swapped};"
        f"joincost_uniform={jc_uniform:.0f};joincost_adapted={jc_adapted:.0f};"
        f"device_mb={mgr.device_bytes() / 1e6:.2f};"
        f"budget_mb={budget_bytes / 1e6:.2f}")]
    if rec is not None:
        rows.append(common.emit(
            f"adaptive/{map_name}/swap_cost", 0.0,
            f"kind={rec.kind};build_s={rec.build_s:.3f};"
            f"pack_s={rec.pack_s:.3f};validate_s={rec.validate_s:.3f};"
            f"merges={rec.merges};regions={rec.regions};"
            f"probe_max_err={rec.probe_max_err:.2e}"))

    os.makedirs(OUT, exist_ok=True)
    payload = dict(map=map_name, budget_frac=budget,
                   budget_bytes=budget_bytes, swapped=bool(swapped),
                   us_before=us_before, us_after=us_after,
                   joincost_uniform=jc_uniform, joincost_adapted=jc_adapted,
                   lifecycle=mgr.stats(),
                   history=[dataclass_dict(r) for r in mgr.history])
    json.dump(payload, open(os.path.join(OUT, "adaptive.json"), "w"),
              indent=1, default=str)
    return rows


def dataclass_dict(rec) -> dict:
    import dataclasses
    return dataclasses.asdict(rec)
