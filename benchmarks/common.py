"""Shared benchmark plumbing: suite construction, timers, CSV emission."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.hublabel import build_hub_labels
from repro.core.maps import make_map
from repro.core.packed import pack_index
from repro.core.visgraph import build_visgraph
from repro.core.workload import (cluster_queries, mixed_queries,
                                 uniform_queries, workload_scores)

# map suite -> base cell size (EHL-1); EHL-k multiplies by k
SUITE_CELLS = {"rooms-M": 2.0, "maze-M": 2.0, "scatter-M": 2.0}
BUDGETS = (0.8, 0.6, 0.4, 0.2, 0.1, 0.05)


@dataclasses.dataclass
class SuiteContext:
    name: str
    scene: object
    graph: object
    hl: object
    base_cell: float
    build_graph_s: float


_CACHE: dict = {}


def suite(name: str, seed: int = 0) -> SuiteContext:
    key = (name, seed)
    if key not in _CACHE:
        t0 = time.perf_counter()
        scene = make_map(name, seed=seed)
        graph = build_visgraph(scene)
        hl = build_hub_labels(graph)
        _CACHE[key] = SuiteContext(name, scene, graph, hl,
                                   SUITE_CELLS.get(name, 2.0),
                                   time.perf_counter() - t0)
    return _CACHE[key]


def fresh_ehl(ctx: SuiteContext, cell_mult: int = 1):
    t0 = time.perf_counter()
    idx = build_ehl(ctx.scene, ctx.base_cell * cell_mult, graph=ctx.graph,
                    hl=ctx.hl)
    return idx, time.perf_counter() - t0 + ctx.build_graph_s


def ehl_star(ctx: SuiteContext, fraction: float, scores=None, alpha=0.0):
    """EHL*-x: budget = x of EHL-1 label memory."""
    idx, t_base = fresh_ehl(ctx)
    t0 = time.perf_counter()
    stats = compress_to_fraction(idx, fraction, cell_scores=scores,
                                 alpha=alpha)
    return idx, t_base + time.perf_counter() - t0, stats


def query_sets(ctx: SuiteContext, n: int = 400, seed: int = 1):
    out = {"Unknown": uniform_queries(ctx.scene, ctx.graph, n, seed=seed)}
    for k in (2, 4, 8):
        out[f"Cluster-{k}"] = cluster_queries(ctx.scene, ctx.graph, k, n,
                                              seed=seed + k)
    return out


def time_queries(index, qs, batch_size: int = 256, reps: int = 3,
                 use_kernels: bool = False) -> float:
    """Mean us/query through the batched JAX engine (packed index)."""
    from repro.serving.engine import PathServer
    pk = pack_index(index)
    srv = PathServer(pk, batch_size=batch_size, use_kernels=use_kernels)
    srv.warmup()
    best = np.inf
    for _ in range(reps):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
        best = min(best, srv.stats.us_per_query)
    return best


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.3f},{derived}"
    print(line, flush=True)
    return line
