"""Shared benchmark plumbing: suite construction, timers, CSV emission,
and a disk cache for built indexes so repeated invocations skip the offline
phase (visibility polygons + the merge loop)."""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import time

import numpy as np

from repro.checkpoint import load_ehl_index, save_ehl_index
from repro.core import (build_ehl, build_hub_labels, build_visgraph,
                        cluster_queries, compress_to_fraction, make_map,
                        pack_index, uniform_queries)

# map suite -> base cell size (EHL-1); EHL-k multiplies by k
SUITE_CELLS = {"rooms-M": 2.0, "maze-M": 2.0, "scatter-M": 2.0}
BUDGETS = (0.8, 0.6, 0.4, 0.2, 0.1, 0.05)

INDEX_CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts", "index_cache")

# Label-construction version: bump when offline-phase code changes the label
# sets a given scene produces (the scene hash alone cannot see code changes).
# v2: exact-chord _point_in_star (degenerate shadow-boundary chords no longer
# hand far cells phantom visibility labels).
LABELS_VERSION = 2

# Slab-layout salt: the host label sets are dtype-independent, but benches
# that key cache entries to a packed artifact must not reuse an entry across
# slab formats — non-f32 layouts get their own cache files (``layout=`` on
# ``_cache_path``/``ehl_star_cached``); f32 keeps the historical key.
SLAB_FORMAT_VERSION = 1


@dataclasses.dataclass
class SuiteContext:
    name: str
    scene: object
    graph: object
    hl: object
    base_cell: float
    build_graph_s: float


_CACHE: dict = {}


def suite(name: str, seed: int = 0) -> SuiteContext:
    key = (name, seed)
    if key not in _CACHE:
        t0 = time.perf_counter()
        scene = make_map(name, seed=seed)
        graph = build_visgraph(scene)
        hl = build_hub_labels(graph)
        _CACHE[key] = SuiteContext(name, scene, graph, hl,
                                   SUITE_CELLS.get(name, 2.0),
                                   time.perf_counter() - t0)
    return _CACHE[key]


def fresh_ehl(ctx: SuiteContext, cell_mult: int = 1):
    t0 = time.perf_counter()
    idx = build_ehl(ctx.scene, ctx.base_cell * cell_mult, graph=ctx.graph,
                    hl=ctx.hl)
    return idx, time.perf_counter() - t0 + ctx.build_graph_s


def ehl_star(ctx: SuiteContext, fraction: float, scores=None, alpha=0.0):
    """EHL*-x: budget = x of EHL-1 label memory."""
    idx, t_base = fresh_ehl(ctx)
    t0 = time.perf_counter()
    stats = compress_to_fraction(idx, fraction, cell_scores=scores,
                                 alpha=alpha)
    return idx, t_base + time.perf_counter() - t0, stats


def _workload_hash(scores, alpha: float) -> str:
    """Cache-key fragment for the (score vector, alpha) pair.

    alpha participates even with uniform scores — it changes the Eq. 5
    merge-target selection regardless of the score initialisation."""
    if scores is None:
        return f"uniform-a{alpha:g}"
    h = hashlib.sha1(np.ascontiguousarray(
        np.asarray(scores, np.float64)).tobytes())
    h.update(np.float64(alpha).tobytes())
    return h.hexdigest()[:12]


def _scene_hash(scene) -> str:
    """Geometry fingerprint: ties a cached index to the exact obstacle set
    (map seed AND map-generation code changes both invalidate)."""
    h = hashlib.sha1(np.ascontiguousarray(scene.edges).tobytes())
    h.update(np.float64([scene.width, scene.height]).tobytes())
    return h.hexdigest()[:10]


def _cache_path(ctx: SuiteContext, fraction, cell_mult: int,
                scores, alpha: float, layout: str = "f32") -> str:
    frac = "full" if fraction is None else f"{fraction:g}"
    # non-f32 slab layouts salt the key with the dtype + packed-format
    # version, so a quantized bench never resurrects an entry written for a
    # different slab format (and vice versa)
    salt = "" if layout == "f32" else f"_{layout}-s{SLAB_FORMAT_VERSION}"
    return os.path.join(
        INDEX_CACHE,
        f"{ctx.name}_{_scene_hash(ctx.scene)}_v{LABELS_VERSION}"
        f"_cell{ctx.base_cell * cell_mult:g}_f{frac}"
        f"_{_workload_hash(scores, alpha)}{salt}.npz")


def fresh_ehl_cached(ctx: SuiteContext, cell_mult: int = 1):
    """Disk-cached ``fresh_ehl``: the uncompressed EHL build (the visibility
    sweep is the expensive part) keyed by (map, cell size)."""
    path = _cache_path(ctx, None, cell_mult, None, 0.0)
    if os.path.exists(path):
        t0 = time.perf_counter()
        idx = load_ehl_index(path, ctx.scene, ctx.graph, ctx.hl)
        return idx, time.perf_counter() - t0
    idx, t = fresh_ehl(ctx, cell_mult)
    save_ehl_index(path, idx)
    return idx, t


def ehl_star_cached(ctx: SuiteContext, fraction: float, scores=None,
                    alpha: float = 0.0, cell_mult: int = 1,
                    layout: str = "f32"):
    """Disk-cached ``ehl_star``: the compressed index keyed by
    (map, cell size, budget fraction, workload-hash, slab layout).

    Cache hits skip both the visibility sweep and the merge loop; the
    returned stats are ``None`` on a hit (no compression ran).
    """
    path = _cache_path(ctx, fraction, cell_mult, scores, alpha,
                       layout=layout)
    if os.path.exists(path):
        t0 = time.perf_counter()
        idx = load_ehl_index(path, ctx.scene, ctx.graph, ctx.hl)
        return idx, time.perf_counter() - t0, None
    idx, t_base = fresh_ehl_cached(ctx, cell_mult)   # compress from the
    t0 = time.perf_counter()                         # cached base build
    stats = compress_to_fraction(idx, fraction, cell_scores=scores,
                                 alpha=alpha)
    save_ehl_index(path, idx)
    return idx, t_base + time.perf_counter() - t0, stats


def query_sets(ctx: SuiteContext, n: int = 400, seed: int = 1):
    out = {"Unknown": uniform_queries(ctx.scene, ctx.graph, n, seed=seed)}
    for k in (2, 4, 8):
        out[f"Cluster-{k}"] = cluster_queries(ctx.scene, ctx.graph, k, n,
                                              seed=seed + k)
    return out


def best_seconds(fn, *args, reps: int = 5) -> float:
    """Best-of-``reps`` wall seconds for ``fn(*args)`` on the shared
    monotonic clock (``repro.obs.timing.Stopwatch``) — the one timer every
    bench reports through, so kernel/serving/attribution numbers are
    comparable run to run."""
    from repro.obs import Stopwatch
    best = np.inf
    for _ in range(reps):
        with Stopwatch() as sw:
            fn(*args)
        best = min(best, sw.seconds)
    return float(best)


def time_queries(index, qs, batch_size: int = 256, reps: int = 3,
                 use_kernels: bool = False) -> float:
    """Mean us/query through the batched JAX engine (packed index)."""
    from repro.serving import PathServer
    pk = pack_index(index)
    srv = PathServer(pk, batch_size=batch_size, use_kernels=use_kernels)
    srv.warmup()
    best = np.inf
    for _ in range(reps):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
        best = min(best, srv.stats.us_per_query)
    return best


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.3f},{derived}"
    print(line, flush=True)
    return line


# --------------------------------------------------------------------------
# Common-schema bench artifacts (DESIGN.md §12): every bench that measures a
# serving loop writes ``BENCH_<name>.json`` with the same top-level keys, so
# make_tables / CI diff runs without per-bench parsing.

BENCH_SCHEMA_VERSION = 1

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:   # noqa: BLE001 — benches run outside checkouts too
        return "unknown"


def _jsonable(x):
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, np.ndarray):
        return x.tolist()
    return x


def write_bench_json(name: str, *, qps: float = None, p50_ms: float = None,
                     p95_ms: float = None, p99_ms: float = None,
                     device_bytes: int = None, registry=None,
                     data: dict = None, out_dir: str = None) -> str:
    """Write ``BENCH_<name>.json`` in the shared schema; returns the path.

    ``registry`` (a ``repro.obs.MetricsRegistry``) is snapshotted so the
    artifact carries the full metric state the numbers were derived from;
    ``data`` holds bench-specific detail under one key, never at top level.

    Every write also appends a sha-keyed copy under ``history/``
    (``BENCH_<name>_<sha12>.json``) — the bench *trajectory* the trend
    table and the CI regression gate read.  Re-running at the same
    commit overwrites that commit's entry (one snapshot per sha), so
    iterating locally never pollutes the history.
    """
    out_dir = ARTIFACTS if out_dir is None else out_dir
    rec = {
        "name": name,
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": git_sha(),
        "written_at": time.time(),  # repolint: disable=monotonic-time -- wall stamp is run metadata, never subtracted
        "qps": qps,
        "p50_ms": p50_ms,
        "p95_ms": p95_ms,
        "p99_ms": p99_ms,
        "device_bytes": device_bytes,
        "registry": registry.snapshot() if registry is not None else None,
        "data": _jsonable(data or {}),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    hist_dir = os.path.join(out_dir, "history")
    os.makedirs(hist_dir, exist_ok=True)
    sha12 = rec["git_sha"][:12] if rec["git_sha"] != "unknown" else "unknown"
    with open(os.path.join(hist_dir, f"BENCH_{name}_{sha12}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return path


def load_history(name: str, out_dir: str = None) -> list:
    """All history snapshots for bench ``name``, oldest first.

    Ordered by ``written_at`` (entries from schema v1 files without the
    stamp sort first, by file mtime).
    """
    out_dir = ARTIFACTS if out_dir is None else out_dir
    hist_dir = os.path.join(out_dir, "history")
    if not os.path.isdir(hist_dir):
        return []
    entries = []
    for fname in sorted(os.listdir(hist_dir)):
        if not (fname.startswith(f"BENCH_{name}_")
                and fname.endswith(".json")):
            continue
        path = os.path.join(hist_dir, fname)
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if rec.get("name") != name:
            continue
        rec.setdefault("written_at", os.path.getmtime(path))
        entries.append(rec)
    entries.sort(key=lambda r: r["written_at"])
    return entries
