"""Paper Table 5: memory, build time, query runtime — EHL* vs competitors.

Columns: EHL*-{80,60,40,20,10,5} / EHL-1/2/4 / visgraph-A* (the index-free
online stand-in for Polyanya; see DESIGN.md §5 for the deviation note).
Query sets: Unknown + Cluster-{2,4,8}; workload-aware EHL* uses historical
cluster queries for scores (paper methodology).
"""

from __future__ import annotations

import time

from repro.core import astar, cluster_queries, workload_scores

from . import common


def run(maps=("rooms-M", "maze-M", "scatter-M"), n_queries=300,
        budgets=common.BUDGETS, quick=False):
    if quick:
        maps = maps[:1]
        budgets = (0.6, 0.2, 0.05)
        n_queries = 120
    rows = []
    for m in maps:
        ctx = common.suite(m)
        qsets = common.query_sets(ctx, n=n_queries)

        # EHL-k baselines (disk-cached: the visibility sweep + hub labels
        # are built once per (map, cell size), not once per invocation)
        for k in (1, 2, 4):
            idx, t_build = common.fresh_ehl_cached(ctx, k)
            mem = idx.label_memory() / 1e6
            for qname, qs in qsets.items():
                us = common.time_queries(idx, qs)
                rows.append(common.emit(
                    f"table5/{m}/EHL-{k}/{qname}", us,
                    f"mem_mb={mem:.2f};build_s={t_build:.2f}"))

        # EHL*-x (unknown workload) — ehl_star_cached compresses from the
        # cached base build and caches the compressed result per budget, so
        # repeated runs stop rebuilding the index per budget row; on a hit
        # stats is None (no compression ran, its budget held when written)
        for frac in budgets:
            idx, t_build, stats = common.ehl_star_cached(ctx, frac)
            mem = idx.label_memory() / 1e6
            budget_ok = (stats is None
                         or stats.final_bytes <= stats.budget)
            for qname, qs in qsets.items():
                us = common.time_queries(idx, qs)
                rows.append(common.emit(
                    f"table5/{m}/EHL*-{int(frac * 100)}/{qname}", us,
                    f"mem_mb={mem:.2f};build_s={t_build:.2f};"
                    f"budget_ok={budget_ok};cached={stats is None}"))

        # workload-aware EHL* (known cluster distribution, paper Fig 1b)
        for k in (2,):
            hist = cluster_queries(ctx.scene, ctx.graph, k, 2000,
                                   seed=77, require_path=False)
            for frac in (budgets if not quick else (0.05,)):
                idx, t_build, _ = common.ehl_star_cached(ctx, frac)
                scores = workload_scores(idx, hist)
                idx2, t2, _ = common.ehl_star_cached(ctx, frac,
                                                     scores=scores,
                                                     alpha=0.2)
                us = common.time_queries(idx2, qsets[f"Cluster-{k}"])
                rows.append(common.emit(
                    f"table5/{m}/EHL*w-{int(frac * 100)}/Cluster-{k}", us,
                    f"mem_mb={idx2.label_memory() / 1e6:.2f};"
                    f"build_s={t2:.2f}"))

        # index-free online baseline (Polyanya's role): A* on the visgraph
        qs = qsets["Unknown"]
        t0 = time.perf_counter()
        for s, t in zip(qs.s[:60], qs.t[:60]):
            astar(ctx.graph, s, t)
        us = 1e6 * (time.perf_counter() - t0) / 60
        rows.append(common.emit(f"table5/{m}/visgraph-A*/Unknown", us,
                                "mem_mb=0.0;online_baseline"))
    return rows
