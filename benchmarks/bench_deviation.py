"""Paper Table 6: query-distribution deviation (DA benchmark role).

EHL* (known) built from Cluster-x history vs EHL* (unknown) vs EHL-1/2/4,
evaluated on mixed workloads where only y% of queries follow the predicted
clusters (y in 100/80/50/20).
"""

from __future__ import annotations

from repro.core import (cluster_queries, mixed_queries, uniform_queries,
                        workload_scores)

from . import common


def run(map_name="rooms-M", budgets=(0.8, 0.4, 0.2),
        adherences=(1.0, 0.8, 0.5, 0.2), clusters=(2, 4, 8), quick=False):
    if quick:
        budgets = (0.4,)
        adherences = (1.0, 0.2)
        clusters = (2,)
    ctx = common.suite(map_name)
    rows = []
    n_eval = 120 if quick else 240
    uni_eval = uniform_queries(ctx.scene, ctx.graph, n_eval, seed=31)

    for k in clusters:
        hist = cluster_queries(ctx.scene, ctx.graph, k, 1500, seed=41 + k,
                               require_path=False)
        clus_eval = cluster_queries(ctx.scene, ctx.graph, k, n_eval,
                                    seed=51 + k)
        for frac in budgets:
            # known: workload-aware scores from history (the score pass and
            # the final build both hit the disk cache; the workload hash
            # keys the scored variant separately)
            idx_known, _, _ = common.ehl_star_cached(ctx, frac)
            scores = workload_scores(idx_known, hist)
            idx_known, _, _ = common.ehl_star_cached(ctx, frac,
                                                     scores=scores,
                                                     alpha=0.2)
            # unknown: uniform scores
            idx_unk, _, _ = common.ehl_star_cached(ctx, frac)
            for y in adherences:
                mixed = mixed_queries(clus_eval, uni_eval, y, seed=61)
                us_k = common.time_queries(idx_known, mixed)
                us_u = common.time_queries(idx_unk, mixed)
                pct = int(frac * 100)
                rows.append(common.emit(
                    f"table6/{map_name}/C-{k}/y{int(y * 100)}/"
                    f"EHL*known-{pct}", us_k, ""))
                rows.append(common.emit(
                    f"table6/{map_name}/C-{k}/y{int(y * 100)}/"
                    f"EHL*unknown-{pct}", us_u, ""))
    # EHL-1 reference row (distribution-independent)
    idx, _ = common.fresh_ehl_cached(ctx)
    us = common.time_queries(idx, uni_eval)
    rows.append(common.emit(f"table6/{map_name}/EHL-1/Unknown", us, ""))
    return rows
