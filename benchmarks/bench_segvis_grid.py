"""Edge-grid pruning bench — edges tested per query, dense vs grid (§10).

The §5 predicate's dense form tests every segment against every packed
edge slot; the grid walk gathers only the cells a segment's bounding box
overlaps.  This bench measures, per suite map (plus the edge-heavy
``scatter-L``):

* **edges touched per segment**: real edge slots the grid path evaluates
  (duplicate registrations counted — they are evaluated) vs the dense
  ``E``, on the engine's actual segment population (query point -> via
  vertex, plus direct s->t pairs);
* **tile vs slab slots**: the padded per-segment gather cost
  (``tile_slots``) vs the padded dense edge count — the auto-attach
  policy's decision quantity;
* **visibility wall time** through ``segvis_ref`` dense vs ``segvis_grid``
  (identical results, asserted here too — this is the §10 bitwise gate
  CI leans on).

Writes ``artifacts/segvis_grid.json`` for ``make_tables``.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_edge_grid, make_map, segvis_grid
from repro.core.packed import _pack_edges  # repolint: disable=layering -- the private packer IS the benchmark subject
from repro.kernels import ops

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")

MAPS = ("rooms-M", "maze-M", "scatter-M", "scatter-L")


def _segments(scene, n: int, seed: int):
    """The engine's segment population: half point->via, half s->t."""
    rng = np.random.default_rng(seed)
    V = scene.vertices.astype(np.float32)
    P = rng.uniform(0, [scene.width, scene.height], (n, 2)).astype(np.float32)
    Q = np.empty_like(P)
    half = n // 2
    Q[:half] = V[rng.integers(0, len(V), half)]
    Q[half:] = rng.uniform(0, [scene.width, scene.height],
                           (n - half, 2)).astype(np.float32)
    return P, Q


def _best_us(fn, reps: int = 5) -> float:
    return common.best_seconds(
        lambda: jax.block_until_ready(fn()), reps=reps) * 1e6


def run(maps=MAPS, n_segments: int = 2048, quick: bool = False):
    if quick:
        maps = maps[:1] + maps[-1:]
        n_segments = 512
    rows, table = [], []
    for name in maps:
        scene = make_map(name, seed=0)
        E = scene.edges.shape[0]
        ea, eb, ec = _pack_edges(scene, lane=128)
        grid = build_edge_grid(ea, eb, E, scene.width, scene.height,
                               sentinel=ea.shape[0] - 1)
        P, Q = _segments(scene, n_segments, seed=7)
        touched = grid.edges_touched(P, Q)

        p, q = jnp.asarray(P), jnp.asarray(Q)
        ea_, eb_, ec_ = map(jnp.asarray, (ea, eb, ec))
        dense_fn = jax.jit(lambda a, b: ops.segvis_ref(a, b, ea_, eb_, ec_))
        grid_fn = jax.jit(lambda a, b: segvis_grid(a, b, ea_, eb_, ec_,
                                                   grid))
        dense = np.asarray(dense_fn(p, q))
        pruned = np.asarray(grid_fn(p, q))
        assert (dense == pruned).all(), f"grid/dense split on {name}"

        us_dense = _best_us(lambda: dense_fn(p, q))
        us_grid = _best_us(lambda: grid_fn(p, q))
        red = E / max(1.0, touched.mean())
        rows.append(common.emit(
            f"segvis_grid/{name}/dense", us_dense,
            f"E={E};slots={ea.shape[0]}"))
        rows.append(common.emit(
            f"segvis_grid/{name}/grid", us_grid,
            f"touched={touched.mean():.1f};reduction={red:.1f}x"))
        table.append(dict(
            map=name, edges=E, padded_slots=int(ea.shape[0]),
            grid=f"{grid.gnx}x{grid.gny}", ell_width=int(grid.ell_width),
            tile_slots=int(grid.tile_slots),
            mean_touched=float(touched.mean()),
            p99_touched=float(np.percentile(touched, 99)),
            reduction=float(red),
            us_dense=float(us_dense), us_grid=float(us_grid),
            identical=True))
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "segvis_grid.json"), "w") as f:
        json.dump(dict(n_segments=n_segments, maps=table), f, indent=1)
    return rows


if __name__ == "__main__":
    run()
