"""Kernel microbenchmarks: Pallas bodies vs jnp references.

On this CPU container the Pallas kernels execute in interpret mode (Python —
orders of magnitude slower than compiled; meaningless as wall time), so the
numbers reported are (a) jnp-reference wall time per batch — the deployable
CPU path, and (b) the analytic TPU roofline estimate for the kernel at its
default BlockSpec tiling, derived from op counts (see EXPERIMENTS.md §Perf
for the derivation and the hillclimb on these terms).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

from . import common

V5E_VPU_FLOPS = 4e12          # f32 vector throughput per chip (approx)
V5E_HBM = 819e9


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    return common.best_seconds(
        lambda: jax.block_until_ready(fn(*args)), reps=reps)


def run(quick=False):
    rows = []
    rng = np.random.default_rng(0)
    B, L, E = (64, 256, 512) if quick else (256, 512, 1024)

    # segvis: N = B*L segments vs E edges
    N = B * L
    p = jnp.asarray(rng.uniform(0, 100, (N, 2)), jnp.float32)
    q = jnp.asarray(rng.uniform(0, 100, (N, 2)), jnp.float32)
    ea = jnp.asarray(rng.uniform(0, 100, (E, 2)), jnp.float32)
    eb = jnp.asarray(rng.uniform(0, 100, (E, 2)), jnp.float32)
    f = jax.jit(lambda *a: ops.segvis_ref(*a))
    sec = _time(f, p, q, ea, eb)
    flops = N * E * 20
    tpu_est = max(flops / V5E_VPU_FLOPS,
                  (N * 16 + E * 16) / V5E_HBM)
    rows.append(common.emit(
        "kernel/segvis_ref", 1e6 * sec / B,
        f"cpu_s={sec:.4f};flops={flops:.3g};tpu_roofline_s={tpu_est:.2e}"))

    # label_join: [B, L] x [B, L]
    hs = jnp.asarray(np.sort(rng.integers(0, 256, (B, L)), 1), jnp.int32)
    ht = jnp.asarray(np.sort(rng.integers(0, 256, (B, L)), 1), jnp.int32)
    vs = jnp.asarray(rng.uniform(0, 100, (B, L)), jnp.float32)
    vt = jnp.asarray(rng.uniform(0, 100, (B, L)), jnp.float32)
    g = jax.jit(lambda *a: ops.label_join_ref(*a))
    sec = _time(g, hs, vs, ht, vt)
    flops = B * L * L * 4
    tpu_est = max(flops / V5E_VPU_FLOPS, (B * L * 16) / V5E_HBM)
    rows.append(common.emit(
        "kernel/label_join_ref", 1e6 * sec / B,
        f"cpu_s={sec:.4f};flops={flops:.3g};tpu_roofline_s={tpu_est:.2e}"))

    # beyond-paper hub-dense join
    h = jax.jit(lambda *a: ops.label_join_hubdense_ref(*a, num_hubs=256))
    sec = _time(h, hs, vs, ht, vt)
    rows.append(common.emit(
        "kernel/label_join_hubdense", 1e6 * sec / B,
        f"cpu_s={sec:.4f};flops={B * (L + 256) * 8:.3g}"))
    return rows
