"""Paper Fig. 5: merged-region structure at 5% memory budget.

Quantifies the figure's visual claim: with workload-aware compression the
cells inside query clusters stay in much smaller regions than cells outside.
Emits region-size statistics + an ASCII region map artifact, plus per-bucket
padding-waste rows for the width-bucketed device layout (DESIGN.md §4) so
the memory win over the single global-Lmax slab is tracked in BENCH_*.json.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import (cluster_queries, pack_bucketed, slab_device_bytes,
                        slab_label_slots, workload_scores)

from . import common

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def _region_size_per_cell(idx):
    sizes = np.zeros(idx.nx * idx.ny)
    for r in idx.regions.values():
        for c in r.cells:
            sizes[c] = len(r.cells)
    return sizes


def _ascii_map(idx, path):
    sym = "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
    rid_of = {rid: i for i, rid in enumerate(sorted(idx.regions))}
    lines = []
    for iy in range(idx.ny - 1, -1, -1):
        row = "".join(sym[rid_of[int(idx.mapper[iy * idx.nx + ix])] % len(sym)]
                      for ix in range(idx.nx))
        lines.append(row)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def run(map_name="rooms-M", budget=0.05, clusters=(2, 4, 8), quick=False):
    if quick:
        clusters = (2,)
    ctx = common.suite(map_name)
    rows = []
    for k in clusters:
        hist = cluster_queries(ctx.scene, ctx.graph, k, 1500, seed=71 + k,
                               require_path=False)
        idx, _, _ = common.ehl_star_cached(ctx, budget)
        scores = workload_scores(idx, hist)
        idx, _, _ = common.ehl_star_cached(ctx, budget, scores=scores,
                                           alpha=0.2)

        sizes = _region_size_per_cell(idx)
        hot = scores > 1.0
        mean_in = sizes[hot].mean() if hot.any() else float("nan")
        mean_out = sizes[~hot].mean()
        rows.append(common.emit(
            f"fig5/{map_name}/Cluster-{k}", 0.0,
            f"mean_region_cells_in_cluster={mean_in:.1f};"
            f"outside={mean_out:.1f};regions={len(idx.regions)}"))
        rows.extend(_padding_waste_rows(idx, f"fig5/{map_name}/Cluster-{k}"))
        _ascii_map(idx, os.path.join(
            ART, f"fig5_{map_name}_c{k}_regions.txt"))
    return rows


def _padding_waste_rows(idx, prefix: str) -> list:
    """Device-layout padding accounting: single slab vs bucketed slabs.

    The slab numbers are computed analytically (``slab_device_bytes``) —
    materializing the global-Lmax slab just to count its padding would
    allocate the very artifact the bucketed layout exists to avoid.
    """
    bx = pack_bucketed(idx)
    slab_bytes = slab_device_bytes(idx)
    used_p, total_p = slab_label_slots(idx)
    used_b, total_b = bx.label_slots()
    rows = [common.emit(
        f"{prefix}/layout", 0.0,
        f"slab_mb={slab_bytes / 1e6:.2f};"
        f"bucketed_mb={bx.device_bytes() / 1e6:.2f};"
        f"byte_ratio={slab_bytes / max(1, bx.device_bytes()):.2f};"
        f"slab_waste={1 - used_p / max(1, total_p):.3f};"
        f"bucketed_waste={1 - used_b / max(1, total_b):.3f}")]
    for st in bx.bucket_stats():
        rows.append(common.emit(
            f"{prefix}/bucket{st['bucket']}", 0.0,
            f"width={st['width']};regions={st['regions']};"
            f"used={st['used_slots']};total={st['total_slots']};"
            f"waste={st['waste']:.3f}"))
    return rows
