"""Assemble the generated tables into EXPERIMENTS.md §5.

    PYTHONPATH=src python -m benchmarks.make_tables
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
MARK = "## 5. Generated tables"


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun_artifacts",
                                           "*.json"))):
        d = json.load(open(f))
        if d["status"] == "ok":
            coll = sum(d["collectives"]["bytes"].values())
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
                f"{d['peak_device_bytes'] / 2**30:.2f} | "
                f"{d['flops']:.3g} | {d['bytes_accessed']:.3g} | "
                f"{coll / 1e6:.0f} |")
        else:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                        f"{d['status']} | — | — | — | — |")
    head = ("### Dry-run matrix (per-device; scan bodies counted once — see "
            "§Roofline for calibrated totals)\n\n"
            "| arch | shape | mesh | status | peak GiB | HLO flops | "
            "HLO bytes | coll MB |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table() -> str:
    path = os.path.join(HERE, "artifacts", "roofline_table.md")
    if not os.path.exists(path):
        return "(roofline_table.md not yet generated)"
    return ("### Roofline (single-pod, calibrated totals)\n\n"
            + open(path).read())


def main():
    text = open(EXP).read()
    base = text.split(MARK)[0]
    out = (base + MARK + "\n\n" + roofline_table() + "\n\n"
           + dryrun_table() + "\n")
    open(EXP, "w").write(out)
    print(f"EXPERIMENTS.md updated "
          f"({len(out.splitlines())} lines)")


if __name__ == "__main__":
    main()
