"""Assemble the generated tables into EXPERIMENTS.md §5.

    PYTHONPATH=src python -m benchmarks.make_tables
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
MARK = "## 5. Generated tables"


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun_artifacts",
                                           "*.json"))):
        d = json.load(open(f))
        if d["status"] == "ok":
            coll = sum(d["collectives"]["bytes"].values())
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
                f"{d['peak_device_bytes'] / 2**30:.2f} | "
                f"{d['flops']:.3g} | {d['bytes_accessed']:.3g} | "
                f"{coll / 1e6:.0f} |")
        else:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                        f"{d['status']} | — | — | — | — |")
    head = ("### Dry-run matrix (per-device; scan bodies counted once — see "
            "§Roofline for calibrated totals)\n\n"
            "| arch | shape | mesh | status | peak GiB | HLO flops | "
            "HLO bytes | coll MB |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table() -> str:
    path = os.path.join(HERE, "artifacts", "roofline_table.md")
    if not os.path.exists(path):
        return "(roofline_table.md not yet generated)"
    return ("### Roofline (single-pod, calibrated totals)\n\n"
            + open(path).read())


def adaptive_table() -> str:
    """Swap cost vs join-cost savings (benchmarks.bench_adaptive)."""
    path = os.path.join(HERE, "artifacts", "adaptive.json")
    head = "### Adaptive serving (workload capture -> recompress -> swap)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only adaptive`)"
    d = json.load(open(path))
    rows = [
        "| map | budget MB | swapped | join cost uniform | join cost "
        "adapted | us/q before | us/q after |",
        "|---|---|---|---|---|---|---|",
        f"| {d['map']} | {d['budget_bytes'] / 1e6:.2f} | {d['swapped']} | "
        f"{d['joincost_uniform']:.0f} | {d['joincost_adapted']:.0f} | "
        f"{d['us_before']:.1f} | {d['us_after']:.1f} |",
    ]
    for h in d.get("history", []):
        rows.append(
            f"| swap gen {h['generation']} ({h['kind']}) | — | "
            f"{h['swapped']} | build {float(h['build_s']):.2f}s | "
            f"pack {float(h['pack_s']):.2f}s | "
            f"validate {float(h['validate_s']):.2f}s | "
            f"err {h['probe_max_err']} |")
    return head + "\n" + "\n".join(rows)


def sharded_table() -> str:
    """Placement balance + routing mix (benchmarks.bench_sharded)."""
    path = os.path.join(HERE, "artifacts", "sharded.json")
    head = "### Sharded serving (region shards over a device mesh)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only sharded`)"
    d = json.load(open(path))
    per = ", ".join(f"{b / 1e6:.2f}" for b in d["per_shard_bytes"])
    mix = "; ".join(f"{k}: {v:.0%}"
                    for k, v in d["same_shard_fraction"].items())
    return head + "\n" + "\n".join([
        "| map | shards | per-shard MB | imbalance | single-device MB | "
        "same-shard routing | bitwise identical |",
        "|---|---|---|---|---|---|---|",
        f"| {d['map']} | {d['num_shards']} | {per} | "
        f"{d['imbalance']:.3f} | {d['single_device_bytes'] / 1e6:.2f} | "
        f"{mix} | {d['identical']} |",
    ])


def segvis_grid_table() -> str:
    """Edge-grid pruning: edges tested per query (bench_segvis_grid)."""
    path = os.path.join(HERE, "artifacts", "segvis_grid.json")
    head = ("### Edge-grid visibility pruning (DESIGN.md §10, dense vs "
            "grid)\n")
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only segvis_grid`)"
    d = json.load(open(path))
    rows = [
        "| map | edges E | grid | mean edges touched | p99 | reduction | "
        "us dense | us grid | bitwise |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for m in d["maps"]:
        rows.append(
            f"| {m['map']} | {m['edges']} | {m['grid']} (M={m['ell_width']})"
            f" | {m['mean_touched']:.1f} | {m['p99_touched']:.0f} | "
            f"{m['reduction']:.1f}x | {m['us_dense']:.0f} | "
            f"{m['us_grid']:.0f} | {m['identical']} |")
    rows.append(f"\n({d['n_segments']} segments per map: half query-point "
                "-> via vertex, half direct s->t.  Wall time favors dense "
                "on small CPU maps — the per-segment gather dominates when "
                "tile slots exceed the padded edge count, which is exactly "
                "when the auto policy keeps the dense path; the reduction "
                "column is the device-side predicate workload the grid "
                "removes on edge-heavy maps.)")
    return head + "\n" + "\n".join(rows)


def quantized_table() -> str:
    """Slab dtype sweep: bytes / exactness / qps (bench_quantized)."""
    path = os.path.join(HERE, "artifacts", "quantized.json")
    head = ("### Quantized slabs (DESIGN.md §11, bf16/f16 distances + u16 "
            "delta ids)\n")
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.bench_quantized`)"
    d = json.load(open(path))
    rows = [
        "| dtype | device MB | vs f32 | qerr | max dist err | argmin "
        "bitwise | us/q | async qps | regions @0.6x f32 budget |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for dt, r in d["table"].items():
        qps = f"{r['async_qps']:.0f}" if r.get("async_qps") else "—"
        adm = r.get("regions_admitted", "—")
        rows.append(
            f"| {dt} | {r['device_bytes'] / 1e6:.2f} | {r['ratio']:.2f}x | "
            f"{r['qerr']:.2e} | {r['max_dist_err']:.2e} | "
            f"{r['argmin_bitwise']} | {r['us_per_query']:.0f} | {qps} | "
            f"{adm} |")
    rows.append(
        f"\n({d['map']} @ {d['budget_frac']} budget, {d['n']} queries, "
        f"batch {d['batch_size']}.  Argmin winners (covis verdicts + "
        "via/hub ids, i.e. the extracted paths) are bitwise-identical to "
        "the f32 engine via residual rescue; distances sit inside the "
        "2*qerr quantization bound.  The last column re-runs the merge "
        "loop under one shared device budget (0.6x of the f32 artifact): "
        "narrower slots admit a ~3.4x finer region partition.  Async qps "
        "gates: bf16 >= 0.95x of f32, f16 >= 0.90x — f16 decode pays real "
        "conversion instructions on CPU; bf16 is a bit shift and holds "
        "full parity.)")
    return head + "\n" + "\n".join(rows)


def telemetry_table() -> str:
    """Instrumentation overhead + span attribution (bench_serving)."""
    path = os.path.join(HERE, "artifacts", "BENCH_serving.json")
    head = "### Serving telemetry overhead (DESIGN.md §12)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.bench_serving`)"
    d = json.load(open(path))
    t = d["data"]["telemetry_overhead"]
    rows = [
        "| qps sampled | qps disabled | ratio (gate >= 0.97) | p99 sampled "
        "ms | p99 disabled ms | spans | max span gap vs e2e |",
        "|---|---|---|---|---|---|---|",
        f"| {t['qps_on']:.0f} | {t['qps_off']:.0f} | {t['ratio']:.3f}x | "
        f"{t['p99_on_ms']:.1f} | {t['p99_off_ms']:.1f} | {t['spans']} | "
        f"{t['span_gap']:.2%} |",
    ]
    rows.append(
        f"\n({d['data']['map']}, n={d['data']['n']}, batch "
        f"{d['data']['batch_size']}; head sampling at the production "
        "default rate with private registries per side — the registry "
        "records in both (it backs ServeStats), so the delta isolates "
        "span + event cost.  Span stages telescope over the batcher's own "
        "timestamps, so the attribution gap is float rounding, not "
        "measurement error.)")
    return head + "\n" + "\n".join(rows)


def main():
    if os.path.exists(EXP):
        text = open(EXP).read()
    else:
        text = ("# EXPERIMENTS\n\nGenerated measurement tables "
                "(`python -m benchmarks.make_tables`); raw CSV comes from "
                "`python -m benchmarks.run`.\n\n")
    base = text.split(MARK)[0]
    out = (base + MARK + "\n\n" + roofline_table() + "\n\n"
           + dryrun_table() + "\n\n" + adaptive_table() + "\n\n"
           + sharded_table() + "\n\n" + segvis_grid_table() + "\n\n"
           + quantized_table() + "\n\n" + telemetry_table() + "\n")
    open(EXP, "w").write(out)
    print(f"EXPERIMENTS.md updated "
          f"({len(out.splitlines())} lines)")


if __name__ == "__main__":
    main()
