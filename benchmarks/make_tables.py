"""Assemble the generated tables into EXPERIMENTS.md §5.

    PYTHONPATH=src python -m benchmarks.make_tables            # rewrite §5
    PYTHONPATH=src python -m benchmarks.make_tables --trend    # history view
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
MARK = "## 5. Generated tables"


def dryrun_table() -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun_artifacts",
                                           "*.json"))):
        d = json.load(open(f))
        if d["status"] == "ok":
            coll = sum(d["collectives"]["bytes"].values())
            rows.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
                f"{d['peak_device_bytes'] / 2**30:.2f} | "
                f"{d['flops']:.3g} | {d['bytes_accessed']:.3g} | "
                f"{coll / 1e6:.0f} |")
        else:
            rows.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                        f"{d['status']} | — | — | — | — |")
    head = ("### Dry-run matrix (per-device; scan bodies counted once — see "
            "§Roofline for calibrated totals)\n\n"
            "| arch | shape | mesh | status | peak GiB | HLO flops | "
            "HLO bytes | coll MB |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def roofline_table() -> str:
    path = os.path.join(HERE, "artifacts", "roofline_table.md")
    if not os.path.exists(path):
        return "(roofline_table.md not yet generated)"
    return ("### Roofline (single-pod, calibrated totals)\n\n"
            + open(path).read())


def adaptive_table() -> str:
    """Swap cost vs join-cost savings (benchmarks.bench_adaptive)."""
    path = os.path.join(HERE, "artifacts", "adaptive.json")
    head = "### Adaptive serving (workload capture -> recompress -> swap)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only adaptive`)"
    d = json.load(open(path))
    rows = [
        "| map | budget MB | swapped | join cost uniform | join cost "
        "adapted | us/q before | us/q after |",
        "|---|---|---|---|---|---|---|",
        f"| {d['map']} | {d['budget_bytes'] / 1e6:.2f} | {d['swapped']} | "
        f"{d['joincost_uniform']:.0f} | {d['joincost_adapted']:.0f} | "
        f"{d['us_before']:.1f} | {d['us_after']:.1f} |",
    ]
    for h in d.get("history", []):
        rows.append(
            f"| swap gen {h['generation']} ({h['kind']}) | — | "
            f"{h['swapped']} | build {float(h['build_s']):.2f}s | "
            f"pack {float(h['pack_s']):.2f}s | "
            f"validate {float(h['validate_s']):.2f}s | "
            f"err {h['probe_max_err']} |")
    return head + "\n" + "\n".join(rows)


def sharded_table() -> str:
    """Placement balance + routing mix (benchmarks.bench_sharded)."""
    path = os.path.join(HERE, "artifacts", "sharded.json")
    head = "### Sharded serving (region shards over a device mesh)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only sharded`)"
    d = json.load(open(path))
    per = ", ".join(f"{b / 1e6:.2f}" for b in d["per_shard_bytes"])
    mix = "; ".join(f"{k}: {v:.0%}"
                    for k, v in d["same_shard_fraction"].items())
    return head + "\n" + "\n".join([
        "| map | shards | per-shard MB | imbalance | single-device MB | "
        "same-shard routing | bitwise identical |",
        "|---|---|---|---|---|---|---|",
        f"| {d['map']} | {d['num_shards']} | {per} | "
        f"{d['imbalance']:.3f} | {d['single_device_bytes'] / 1e6:.2f} | "
        f"{mix} | {d['identical']} |",
    ])


def segvis_grid_table() -> str:
    """Edge-grid pruning: edges tested per query (bench_segvis_grid)."""
    path = os.path.join(HERE, "artifacts", "segvis_grid.json")
    head = ("### Edge-grid visibility pruning (DESIGN.md §10, dense vs "
            "grid)\n")
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.run --only segvis_grid`)"
    d = json.load(open(path))
    rows = [
        "| map | edges E | grid | mean edges touched | p99 | reduction | "
        "us dense | us grid | bitwise |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for m in d["maps"]:
        rows.append(
            f"| {m['map']} | {m['edges']} | {m['grid']} (M={m['ell_width']})"
            f" | {m['mean_touched']:.1f} | {m['p99_touched']:.0f} | "
            f"{m['reduction']:.1f}x | {m['us_dense']:.0f} | "
            f"{m['us_grid']:.0f} | {m['identical']} |")
    rows.append(f"\n({d['n_segments']} segments per map: half query-point "
                "-> via vertex, half direct s->t.  Wall time favors dense "
                "on small CPU maps — the per-segment gather dominates when "
                "tile slots exceed the padded edge count, which is exactly "
                "when the auto policy keeps the dense path; the reduction "
                "column is the device-side predicate workload the grid "
                "removes on edge-heavy maps.)")
    return head + "\n" + "\n".join(rows)


def quantized_table() -> str:
    """Slab dtype sweep: bytes / exactness / qps (bench_quantized)."""
    path = os.path.join(HERE, "artifacts", "quantized.json")
    head = ("### Quantized slabs (DESIGN.md §11, bf16/f16 distances + u16 "
            "delta ids)\n")
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.bench_quantized`)"
    d = json.load(open(path))
    rows = [
        "| dtype | device MB | vs f32 | qerr | max dist err | argmin "
        "bitwise | us/q | async qps | regions @0.6x f32 budget |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for dt, r in d["table"].items():
        qps = f"{r['async_qps']:.0f}" if r.get("async_qps") else "—"
        adm = r.get("regions_admitted", "—")
        rows.append(
            f"| {dt} | {r['device_bytes'] / 1e6:.2f} | {r['ratio']:.2f}x | "
            f"{r['qerr']:.2e} | {r['max_dist_err']:.2e} | "
            f"{r['argmin_bitwise']} | {r['us_per_query']:.0f} | {qps} | "
            f"{adm} |")
    rows.append(
        f"\n({d['map']} @ {d['budget_frac']} budget, {d['n']} queries, "
        f"batch {d['batch_size']}.  Argmin winners (covis verdicts + "
        "via/hub ids, i.e. the extracted paths) are bitwise-identical to "
        "the f32 engine via residual rescue; distances sit inside the "
        "2*qerr quantization bound.  The last column re-runs the merge "
        "loop under one shared device budget (0.6x of the f32 artifact): "
        "narrower slots admit a ~3.4x finer region partition.  Async qps "
        "gates: bf16 >= 0.95x of f32, f16 >= 0.90x — f16 decode pays real "
        "conversion instructions on CPU; bf16 is a bit shift and holds "
        "full parity.)")
    return head + "\n" + "\n".join(rows)


def telemetry_table() -> str:
    """Instrumentation overhead + span attribution (bench_serving)."""
    path = os.path.join(HERE, "artifacts", "BENCH_serving.json")
    head = "### Serving telemetry overhead (DESIGN.md §12)\n"
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.bench_serving`)"
    d = json.load(open(path))
    t = d["data"]["telemetry_overhead"]
    rows = [
        "| qps sampled | qps disabled | ratio (gate >= 0.97) | p99 sampled "
        "ms | p99 disabled ms | spans | max span gap vs e2e |",
        "|---|---|---|---|---|---|---|",
        f"| {t['qps_on']:.0f} | {t['qps_off']:.0f} | {t['ratio']:.3f}x | "
        f"{t['p99_on_ms']:.1f} | {t['p99_off_ms']:.1f} | {t['spans']} | "
        f"{t['span_gap']:.2%} |",
    ]
    p = d["data"].get("profile_overhead")
    if p:
        rows += [
            "",
            "| profile capture qps | capture-off qps | ratio (gate >= "
            "0.97) | p99 on ms | p99 off ms | entries compiled | compile "
            "s |",
            "|---|---|---|---|---|---|---|",
            f"| {p['qps_on']:.0f} | {p['qps_off']:.0f} | "
            f"{p['ratio']:.3f}x | {p['p99_on_ms']:.1f} | "
            f"{p['p99_off_ms']:.1f} | {len(p.get('compiles', {}))} | "
            f"{p['compile_s']:.2f} |",
        ]
    rows.append(
        f"\n({d['data']['map']}, n={d['data']['n']}, batch "
        f"{d['data']['batch_size']}; head sampling at the production "
        "default rate with private registries per side — the registry "
        "records in both (it backs ServeStats), so the delta isolates "
        "span + event cost.  Span stages telescope over the batcher's own "
        "timestamps, so the attribution gap is float rounding, not "
        "measurement error.  The profile rows gate the DESIGN.md §13 "
        "compile/cost capture: steady-state dispatch only pays the wrapper "
        "check, compile + cost_analysis time lands at trace time.)")
    return head + "\n" + "\n".join(rows)


def attribution_table() -> str:
    """Measured vs analytic kernel attribution (bench_attribution)."""
    path = os.path.join(HERE, "artifacts", "BENCH_attribution.json")
    head = ("### Roofline reconciliation (DESIGN.md §13, measured vs "
            "analytic)\n")
    if not os.path.exists(path):
        return head + "\n(run `python -m benchmarks.bench_attribution`)"
    d = json.load(open(path))
    band = d["data"]["band"]
    rows = [
        "| family | size | term | measured ms | predicted ms | "
        "meas/pred | gated | HLO/term flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in d["data"]["rows"]:
        ratio = (f"{r['ratio']:.2f} [cal]" if r["calibration"]
                 else f"{r['ratio']:.2f}"
                 + ("" if r["in_band"] else " **OUT**"))
        hlo = (f"{r['hlo_ratio']:.2f}" if "hlo_ratio" in r else "—")
        rows.append(
            f"| {r['family']} | {r['size']} | {r['term']:.3g} | "
            f"{r['measured_s'] * 1e3:.2f} | {r['predicted_s'] * 1e3:.2f} | "
            f"{ratio} | {'yes' if r['gated'] else 'no'} | {hlo} |")
    rows.append(
        f"\n(Acceptance band {band[0]}–{band[1]} on measured/predicted; "
        "each family calibrates its rate on the first row and predicts "
        "the rest from the analytic term alone, so the ratio tests the "
        "term's *scaling*, not an absolute CPU rate.  HLO/term compares "
        "the analytic flop count against XLA `cost_analysis()` — see "
        "DESIGN.md §13 for the while-loop single-count caveat that "
        "restricts this column to loop-free kernels.)")
    return head + "\n" + "\n".join(rows)


def trend_table(names=("serving", "harness", "attribution")) -> str:
    """Sha-keyed bench history (common.load_history)."""
    from . import common
    out = ["### Bench history (sha-keyed, oldest first)"]
    for name in names:
        hist = common.load_history(name)
        if not hist:
            continue
        out += [
            "",
            f"**{name}**",
            "",
            "| sha | written | qps | p50 ms | p99 ms | note |",
            "|---|---|---|---|---|---|",
        ]
        for rec in hist:
            when = time.strftime("%Y-%m-%d %H:%M",
                                 time.localtime(rec["written_at"]))
            qps = f"{rec['qps']:.0f}" if rec.get("qps") else "—"
            p50 = (f"{rec['p50_ms']:.2f}" if rec.get("p50_ms") is not None
                   else "—")
            p99 = (f"{rec['p99_ms']:.2f}" if rec.get("p99_ms") is not None
                   else "—")
            data = rec.get("data") or {}
            if name == "attribution":
                n_rows = len(data.get("rows", []))
                note = (f"{n_rows} rows, "
                        f"{len(data.get('failures', []))} out-of-band")
            elif "n" in data:
                note = f"n={data['n']}"
                if data.get("smoke"):
                    note += " (smoke)"
            else:                       # harness: csv row dump
                note = (f"{len(data.get('rows', []))} rows, "
                        f"{float(data.get('total_s', 0)):.0f}s")
            out.append(f"| {str(rec.get('git_sha', '?'))[:12]} | {when} | "
                       f"{qps} | {p50} | {p99} | {note} |")
    if len(out) == 1:
        out.append("\n(no history yet — benches append on every run)")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trend", action="store_true",
                    help="print the sha-keyed bench history view and exit "
                         "(does not rewrite EXPERIMENTS.md)")
    args = ap.parse_args(argv)
    if args.trend:
        print(trend_table())
        return
    if os.path.exists(EXP):
        text = open(EXP).read()
    else:
        text = ("# EXPERIMENTS\n\nGenerated measurement tables "
                "(`python -m benchmarks.make_tables`); raw CSV comes from "
                "`python -m benchmarks.run`.\n\n")
    base = text.split(MARK)[0]
    out = (base + MARK + "\n\n" + roofline_table() + "\n\n"
           + dryrun_table() + "\n\n" + adaptive_table() + "\n\n"
           + sharded_table() + "\n\n" + segvis_grid_table() + "\n\n"
           + quantized_table() + "\n\n" + telemetry_table() + "\n\n"
           + attribution_table() + "\n\n" + trend_table() + "\n")
    open(EXP, "w").write(out)
    print(f"EXPERIMENTS.md updated "
          f"({len(out.splitlines())} lines)")


if __name__ == "__main__":
    main()
