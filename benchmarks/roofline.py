import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis — the three terms per (arch x shape) on the 16x16 pod.

Terms (v5e constants per the brief):
    compute_s    = HLO_FLOPs_per_device / 197 TF/s
    memory_s     = HLO_bytes_per_device / 819 GB/s
    collective_s = collective_bytes_per_device / ~45 GB/s effective ICI

**Calibration.** XLA's cost_analysis counts while-loop bodies ONCE (measured:
tinyllama flops identical for L = 2/4/8), so the production scan-over-layers
lowering hides (L-1)/L of the flops.  We therefore lower small UNROLLED
calibration configs at full width — unrolled layer loop, unrolled attention
blocking (same block sizes => same memory pattern), unrolled CE chunks,
remat recompute included — and fit the linear model

    cost(L) = outside + L * body        (dense / ssm / hybrid / vlm)
    cost    = outside + M*body_moe + Dn*body_dense      (moe: 3 lowerings)
    cost    = outside + Ld*body_dec + Le*body_enc       (encdec: 3 lowerings)

then extrapolate to the real depth.  flops / bytes / per-kind collective
bytes all go through the same fit.  MODEL_FLOPS uses 6*N_active*D for train
and 2*N_active*D for inference shapes (D = tokens processed).
"""

import argparse
import dataclasses
import json


PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 45e9
HBM_PER_CHIP = 16 * 2 ** 30

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "dryrun_artifacts")
OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def _calib_cfg(cfg, n_layers, n_dense, enc_layers, seq_len):
    big = seq_len >= 32768
    return dataclasses.replace(
        cfg, n_layers=n_layers, n_dense_layers=n_dense,
        enc_layers=enc_layers, mtp=cfg.mtp,
        unroll_layers=True,
        attn_q_chunk=2048 if big else 512,
        attn_kv_chunk=4096 if big else 1024)


def _measure(cfg, shape, mesh):
    """Lower + compile one calibration config; return cost vector."""
    import jax
    from repro.launch import build_step, collective_bytes
    with mesh:
        fn, args, in_sh = build_step(cfg, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
    vec = {"flops": ca.get("flops", 0.0),
           "bytes": ca.get("bytes accessed", 0.0)}
    for k, v in coll["bytes"].items():
        vec[f"coll/{k}"] = float(v)
    return vec


def _vsub(a, b):
    return {k: a[k] - b.get(k, 0.0) for k in a}


def _vadd(a, b, s=1.0):
    return {k: a.get(k, 0.0) + s * b.get(k, 0.0) for k in set(a) | set(b)}


def calibrate_cell(arch: str, shape, mesh) -> dict:
    """Extrapolated per-device cost vector for the full-depth model."""
    from repro.configs import get_config
    cfg = get_config(arch)
    S = shape.seq_len

    if cfg.encdec:
        c11 = _measure(_calib_cfg(cfg, 1, 0, 1, S), shape, mesh)
        c21 = _measure(_calib_cfg(cfg, 2, 0, 1, S), shape, mesh)
        c12 = _measure(_calib_cfg(cfg, 1, 0, 2, S), shape, mesh)
        body_dec = _vsub(c21, c11)
        body_enc = _vsub(c12, c11)
        total = _vadd(_vadd(c11, body_dec, cfg.n_layers - 1),
                      body_enc, cfg.enc_layers - 1)
        parts = {"lowerings": 3}
    elif cfg.moe:
        nd = 1 if cfg.n_dense_layers else 0
        m11 = _measure(_calib_cfg(cfg, nd + 1, nd, 0, S), shape, mesh)
        m12 = _measure(_calib_cfg(cfg, nd + 2, nd, 0, S), shape, mesh)
        body_moe = _vsub(m12, m11)
        if nd:
            m21 = _measure(_calib_cfg(cfg, nd + 2, nd + 1, 0, S), shape, mesh)
            body_dense = _vsub(m21, m12)
        else:
            body_dense = {k: 0.0 for k in m11}
        M_real = cfg.n_layers - cfg.n_dense_layers
        total = _vadd(_vadd(m11, body_moe, M_real - 1),
                      body_dense, cfg.n_dense_layers - nd)
        parts = {"lowerings": 3 if nd else 2}
    else:
        c1 = _measure(_calib_cfg(cfg, 1, 0, 0, S), shape, mesh)
        c2 = _measure(_calib_cfg(cfg, 2, 0, 0, S), shape, mesh)
        body = _vsub(c2, c1)
        total = _vadd(c1, body, cfg.n_layers - 1)
        parts = {"lowerings": 2}
    total.update(parts)
    return total


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS (6*N_active*D train, 2*N_active*D inference)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def analyse_cell(arch: str, shape, mesh, artifact: dict) -> dict:
    from repro.configs import get_config
    cfg = get_config(arch)
    cal = calibrate_cell(arch, shape, mesh)
    n_dev = mesh.devices.size

    flops_pd = cal["flops"]
    bytes_pd = cal["bytes"]
    coll_pd = sum(v for k, v in cal.items() if k.startswith("coll/"))

    compute_s = flops_pd / PEAK_FLOPS
    memory_s = bytes_pd / HBM_BW
    collective_s = coll_pd / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_total = flops_pd * n_dev
    useful = mf / hlo_total if hlo_total else 0.0
    peak = artifact.get("peak_device_bytes", 0)

    moves = {
        "compute_s": "raise arithmetic efficiency: larger MXU tiles / fewer "
                     "remat recomputes / drop redundant gathers",
        "memory_s": "cut HBM traffic: keep KV/latents in bf16, fuse "
                    "norm+matmul, larger attention blocks",
        "collective_s": "reshard to cut all-gathers: overlap collectives "
                        "with the layer scan, reduce-scatter gradients",
    }
    return {
        "arch": arch, "shape": shape.name,
        "flops_per_dev": flops_pd, "bytes_per_dev": bytes_pd,
        "collective_bytes_per_dev": coll_pd,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_flop_ratio": round(useful, 4),
        "roofline_fraction": round(useful, 4),
        "peak_device_bytes": peak,
        "fits_16g": bool(peak and peak <= HBM_PER_CHIP),
        "note": moves[dominant],
        "calib": {k: v for k, v in cal.items() if k.startswith("coll/")},
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS, get_config
    from repro.launch import make_production_mesh
    from repro.models import LM_SHAPES, shape_applicable
    from repro.distributed import set_mesh_hints

    mesh = make_production_mesh()
    set_mesh_hints(mesh)
    os.makedirs(OUT, exist_ok=True)

    rows = []
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        for shape in LM_SHAPES:
            if args.shape and shape.name != args.shape:
                continue
            runs, reason = shape_applicable(get_config(arch), shape)
            out_path = os.path.join(OUT, f"roofline_{arch}__{shape.name}.json")
            if not runs:
                rec = {"arch": arch, "shape": shape.name, "status": "skipped",
                       "reason": reason}
                json.dump(rec, open(out_path, "w"), indent=1)
                rows.append(rec)
                continue
            if args.skip_existing and os.path.exists(out_path):
                rows.append(json.load(open(out_path)))
                print(f"[cached] {arch} {shape.name}")
                continue
            art_path = os.path.join(ART, f"{arch}__{shape.name}__pod.json")
            artifact = json.load(open(art_path)) if os.path.exists(art_path) \
                else {}
            print(f"[roofline] {arch} {shape.name} ...", flush=True)
            try:
                rec = analyse_cell(arch, shape, mesh, artifact)
                rec["status"] = "ok"
            except Exception as e:     # noqa: BLE001
                import traceback
                rec = {"arch": arch, "shape": shape.name, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-1500:]}
            json.dump(rec, open(out_path, "w"), indent=1)
            rows.append(rec)
            if rec["status"] == "ok":
                print(f"  compute={rec['compute_s']:.4f}s "
                      f"mem={rec['memory_s']:.4f}s "
                      f"coll={rec['collective_s']:.4f}s "
                      f"dom={rec['dominant']} useful={rec['useful_flop_ratio']}",
                      flush=True)
            else:
                print("  " + rec.get("error", rec["status"]), flush=True)

    # markdown table
    md = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL/HLO | fits 16G |",
          "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "ok":
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['dominant'].replace('_s', '')} | "
                f"{r['useful_flop_ratio']:.3f} | "
                f"{'y' if r.get('fits_16g') else 'n'} |")
        elif r["status"] == "skipped":
            md.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | "
                      f"— | — |")
    table = "\n".join(md)
    open(os.path.join(OUT, "roofline_table.md"), "w").write(table + "\n")
    print("\n" + table)


if __name__ == "__main__":
    main()
