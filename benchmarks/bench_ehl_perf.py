"""§Perf hillclimb 3 — the paper's own online phase on the JAX engine.

Measured (CPU wall-clock, real executions — unlike the TPU dry-run cells):

* **paper-faithful baseline**: dense row-min join (the TPU adaptation of the
  sorted merge-join, Eq. 3) + dense segment-visibility, full EHL-1 index;
* **iteration A — budget as padding optimizer**: EHL* compression shrinks
  the packed label width Lmax, which the O(L^2) join and O(L*E) visibility
  pay for directly -> query time drops with the budget (Fig. 1's tradeoff,
  reproduced structurally on the batched engine);
* **iteration B — beyond-paper hub-dense join**: scatter-min into dense hub
  space, O(L + H_vocab) per query instead of O(L^2);
* **iteration C — batch sizing**: amortize dispatch overhead.

Each variant also gets analytic v5e roofline terms for the kernels
(VPU-bound predicate evaluation): see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import pack_index, query_batch
from repro.core.query import query
from repro.core.workload import uniform_queries
from repro.kernels import ops

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
V5E_VPU = 4e12
V5E_HBM = 819e9


def _timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _hubdense_query(idx, num_hubs):
    """query_batch variant with the beyond-paper hub-scatter join."""
    @jax.jit
    def f(pk, s, t):
        from repro.core.packed import locate_regions
        s = s.astype(jnp.float32)
        t = t.astype(jnp.float32)
        rs = locate_regions(pk, s)
        rt = locate_regions(pk, t)
        hub_s, hub_t = pk.hub_ids[rs], pk.hub_ids[rt]
        xy_s, xy_t = pk.via_xy[rs], pk.via_xy[rt]
        d_s, d_t = pk.via_d[rs], pk.via_d[rt]
        B, L = hub_s.shape
        vis_s = ops.segvis_ref(jnp.repeat(s, L, 0), xy_s.reshape(-1, 2),
                               pk.edges_a, pk.edges_b).reshape(B, L)
        vis_t = ops.segvis_ref(jnp.repeat(t, L, 0), xy_t.reshape(-1, 2),
                               pk.edges_a, pk.edges_b).reshape(B, L)
        inf = jnp.float32(jnp.inf)
        vd_s = jnp.where(vis_s, jnp.linalg.norm(s[:, None] - xy_s, axis=-1) + d_s,
                         inf)
        vd_t = jnp.where(vis_t, jnp.linalg.norm(t[:, None] - xy_t, axis=-1) + d_t,
                         inf)
        d_lab = ops.label_join_hubdense_ref(hub_s, vd_s, hub_t, vd_t,
                                            num_hubs=num_hubs)
        covis = ops.segvis_ref(s, t, pk.edges_a, pk.edges_b)
        return jnp.where(covis, jnp.linalg.norm(s - t, axis=-1), d_lab)
    return f


def run(quick=False):
    ctx = common.suite("rooms-M")
    qs = uniform_queries(ctx.scene, ctx.graph, 160 if quick else 512, seed=3,
                         require_path=False)
    V = ctx.graph.num_nodes
    rows = []
    iterations = []

    def measure(tag, pk, fn, B, truth=None):
        s = jnp.asarray(np.resize(qs.s.astype(np.float32), (B, 2)))
        t = jnp.asarray(np.resize(qs.t.astype(np.float32), (B, 2)))
        sec = _timeit(fn, pk, s, t)
        us = 1e6 * sec / B
        L, E = pk.label_width, pk.num_edges
        flops_vis = 2 * B * L * E * 20 + B * E * 20
        flops_join = B * L * L * 4
        tpu_s = max((flops_vis + flops_join) / V5E_VPU,
                    pk.device_bytes() / V5E_HBM)
        rec = dict(tag=tag, us_per_query=us, L=L, E=E, B=B,
                   device_mb=pk.device_bytes() / 1e6,
                   tpu_roofline_us=1e6 * tpu_s / B)
        if truth is not None:
            got = np.asarray(fn(pk, jnp.asarray(qs.s.astype(np.float32)),
                                jnp.asarray(qs.t.astype(np.float32))))
            rec["max_err"] = float(np.nanmax(np.abs(
                np.where(np.isfinite(truth), got - truth, 0.0))))
        iterations.append(rec)
        rows.append(common.emit(f"ehlperf/{tag}", us,
                                f"L={L};dev_mb={rec['device_mb']:.1f};"
                                f"tpu_us={rec['tpu_roofline_us']:.2f}"))
        return rec

    # ground truth distances from the host oracle on the full index
    idx_full = build_ehl(ctx.scene, ctx.base_cell, graph=ctx.graph, hl=ctx.hl)
    truth = np.array([query(idx_full, s, t, want_path=False)[0]
                      for s, t in zip(qs.s, qs.t)])

    B0 = 256
    base_fn = jax.jit(lambda pk, s, t: query_batch(pk, s, t))

    # baseline: paper-faithful join, EHL-1 (no compression)
    pk_full = pack_index(idx_full)
    measure("baseline/EHL-1/rowmin", pk_full, base_fn, B0, truth)

    # iteration A: EHL* budgets shrink Lmax (paper technique as perf lever)
    for frac in (0.6, 0.2, 0.05):
        idx = build_ehl(ctx.scene, ctx.base_cell, graph=ctx.graph, hl=ctx.hl)
        compress_to_fraction(idx, frac)
        pk = pack_index(idx)
        measure(f"iterA/EHL*-{int(frac * 100)}/rowmin", pk, base_fn, B0,
                truth)

    # iteration B: beyond-paper hub-dense join at the tightest budget
    idx = build_ehl(ctx.scene, ctx.base_cell, graph=ctx.graph, hl=ctx.hl)
    compress_to_fraction(idx, 0.2)
    pk20 = pack_index(idx)
    hd_fn = _hubdense_query(idx, num_hubs=V)
    measure("iterB/EHL*-20/hubdense", pk20, hd_fn, B0, truth)

    # iteration C: batch scaling on the winner
    for B in ((64, 1024) if not quick else (64,)):
        measure(f"iterC/EHL*-20/hubdense/B{B}", pk20, hd_fn, B)

    # iteration D: bucketed padding — route queries whose regions fit a
    # narrow view (beyond-paper; global Lmax is set by one huge region)
    from repro.core.packed import locate_regions, narrow_view
    for width in (128, 256):
        nv, ok = narrow_view(pk20, width)
        okn = np.asarray(ok)
        rs = np.asarray(locate_regions(pk20, jnp.asarray(
            qs.s.astype(np.float32))))
        rt = np.asarray(locate_regions(pk20, jnp.asarray(
            qs.t.astype(np.float32))))
        fast_frac = float((okn[rs] & okn[rt]).mean())
        nv_fn = _hubdense_query(idx, num_hubs=V)
        rec_n = measure(f"iterD/EHL*-20/narrow{width}", nv, nv_fn, B0)
        # effective us/query = fast_frac * narrow + (1-fast_frac) * full
        full_us = next(r for r in iterations
                       if r["tag"] == "iterB/EHL*-20/hubdense")["us_per_query"]
        eff = fast_frac * rec_n["us_per_query"] + (1 - fast_frac) * full_us
        rows.append(common.emit(
            f"ehlperf/iterD/EHL*-20/bucketed{width}/effective", eff,
            f"fast_frac={fast_frac:.2f}"))
        iterations.append(dict(tag=f"iterD/bucketed{width}/effective",
                               us_per_query=eff, fast_frac=fast_frac))

    os.makedirs(OUT, exist_ok=True)
    json.dump(iterations, open(os.path.join(OUT, "ehl_perf.json"), "w"),
              indent=1)
    return rows
