"""§Perf hillclimb 3 — the paper's own online phase on the JAX engine.

Measured (CPU wall-clock, real executions — unlike the TPU dry-run cells):

* **paper-faithful baseline**: dense row-min join (the TPU adaptation of the
  sorted merge-join, Eq. 3) + dense segment-visibility, full EHL-1 index;
* **iteration A — budget as padding optimizer**: EHL* compression shrinks
  the packed label width Lmax, which the O(L^2) join and O(L*E) visibility
  pay for directly -> query time drops with the budget (Fig. 1's tradeoff,
  reproduced structurally on the batched engine);
* **iteration B — beyond-paper hub-dense join**: scatter-min into dense hub
  space, O(L + H_vocab) per query instead of O(L^2);
* **iteration C — batch sizing**: amortize dispatch overhead;
* **iteration D — bucketed packed layout**: width-bucketed slabs + per-
  bucket dispatch (DESIGN.md §4) kill the global-Lmax padding in both
  device bytes and per-query join width.

Each variant also gets analytic v5e roofline terms for the kernels
(VPU-bound predicate evaluation): see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack_index, query, query_batch, uniform_queries
from repro.kernels import ops

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
V5E_VPU = 4e12
V5E_HBM = 819e9


def _timeit(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    return common.best_seconds(
        lambda: jax.block_until_ready(fn(*args)), reps=reps)


def _hubdense_query(idx, num_hubs):
    """query_batch variant with the beyond-paper hub-scatter join."""
    @jax.jit
    def f(pk, s, t):
        from repro.core import locate_regions
        s = s.astype(jnp.float32)
        t = t.astype(jnp.float32)
        rs = locate_regions(pk, s)
        rt = locate_regions(pk, t)
        hub_s, hub_t = pk.hub_ids[rs], pk.hub_ids[rt]
        xy_s, xy_t = pk.via_xy[rs], pk.via_xy[rt]
        d_s, d_t = pk.via_d[rs], pk.via_d[rt]
        B, L = hub_s.shape
        vis_s = ops.segvis_ref(jnp.repeat(s, L, 0), xy_s.reshape(-1, 2),
                               pk.edges_a, pk.edges_b).reshape(B, L)
        vis_t = ops.segvis_ref(jnp.repeat(t, L, 0), xy_t.reshape(-1, 2),
                               pk.edges_a, pk.edges_b).reshape(B, L)
        inf = jnp.float32(jnp.inf)
        vd_s = jnp.where(vis_s, jnp.linalg.norm(s[:, None] - xy_s, axis=-1) + d_s,
                         inf)
        vd_t = jnp.where(vis_t, jnp.linalg.norm(t[:, None] - xy_t, axis=-1) + d_t,
                         inf)
        d_lab = ops.label_join_hubdense_ref(hub_s, vd_s, hub_t, vd_t,
                                            num_hubs=num_hubs)
        covis = ops.segvis_ref(s, t, pk.edges_a, pk.edges_b)
        return jnp.where(covis, jnp.linalg.norm(s - t, axis=-1), d_lab)
    return f


def run(quick=False):
    ctx = common.suite("rooms-M")
    qs = uniform_queries(ctx.scene, ctx.graph, 160 if quick else 512, seed=3,
                         require_path=False)
    V = ctx.graph.num_nodes
    rows = []
    iterations = []

    def measure(tag, pk, fn, B, truth=None):
        s = jnp.asarray(np.resize(qs.s.astype(np.float32), (B, 2)))
        t = jnp.asarray(np.resize(qs.t.astype(np.float32), (B, 2)))
        sec = _timeit(fn, pk, s, t)
        us = 1e6 * sec / B
        L, E = pk.label_width, pk.num_edges
        flops_vis = 2 * B * L * E * 20 + B * E * 20
        flops_join = B * L * L * 4
        tpu_s = max((flops_vis + flops_join) / V5E_VPU,
                    pk.device_bytes() / V5E_HBM)
        rec = dict(tag=tag, us_per_query=us, L=L, E=E, B=B,
                   device_mb=pk.device_bytes() / 1e6,
                   tpu_roofline_us=1e6 * tpu_s / B)
        if truth is not None:
            got = np.asarray(fn(pk, jnp.asarray(qs.s.astype(np.float32)),
                                jnp.asarray(qs.t.astype(np.float32))))
            rec["max_err"] = float(np.nanmax(np.abs(
                np.where(np.isfinite(truth), got - truth, 0.0))))
        iterations.append(rec)
        rows.append(common.emit(f"ehlperf/{tag}", us,
                                f"L={L};dev_mb={rec['device_mb']:.1f};"
                                f"tpu_us={rec['tpu_roofline_us']:.2f}"))
        return rec

    # ground truth distances from the host oracle on the full index
    # (disk-cached: repeated invocations skip the whole offline phase)
    idx_full, _ = common.fresh_ehl_cached(ctx)
    truth = np.array([query(idx_full, s, t, want_path=False)[0]
                      for s, t in zip(qs.s, qs.t)])

    B0 = 256
    base_fn = jax.jit(lambda pk, s, t: query_batch(pk, s, t))

    # baseline: paper-faithful join, EHL-1 (no compression)
    pk_full = pack_index(idx_full)
    measure("baseline/EHL-1/rowmin", pk_full, base_fn, B0, truth)

    # iteration A: EHL* budgets shrink Lmax (paper technique as perf lever)
    for frac in (0.6, 0.2, 0.05):
        idx, _, _ = common.ehl_star_cached(ctx, frac)
        pk = pack_index(idx)
        measure(f"iterA/EHL*-{int(frac * 100)}/rowmin", pk, base_fn, B0,
                truth)

    # iteration B: beyond-paper hub-dense join at the tightest budget
    idx, _, _ = common.ehl_star_cached(ctx, 0.2)
    pk20 = pack_index(idx)
    hd_fn = _hubdense_query(idx, num_hubs=V)
    measure("iterB/EHL*-20/hubdense", pk20, hd_fn, B0, truth)

    # iteration C: batch scaling on the winner
    for B in ((64, 1024) if not quick else (64,)):
        measure(f"iterC/EHL*-20/hubdense/B{B}", pk20, hd_fn, B)

    # iteration D: bucketed packed layout — per-bucket dispatch replaces
    # global-Lmax padding (beyond-paper; Lmax is set by one huge region).
    # Real end-to-end routing through PathServer, not an extrapolation.
    from repro.core import dispatch_buckets, pack_bucketed
    from repro.serving import PathServer
    bx20 = pack_bucketed(idx)
    srv = PathServer(bx20, batch_size=B0)
    srv.warmup()
    d_b = srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    best_us = np.inf
    for _ in range(3):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
        best_us = min(best_us, srv.stats.us_per_query)
    buckets = dispatch_buckets(bx20, qs.s, qs.t)
    occ = {int(k): float((buckets == k).mean()) for k in np.unique(buckets)}
    max_err = float(np.nanmax(np.abs(np.where(
        np.isfinite(truth), d_b - truth, 0.0))))
    dev_mb = bx20.device_bytes() / 1e6
    slab_mb = pk20.device_bytes() / 1e6
    rows.append(common.emit(
        "ehlperf/iterD/EHL*-20/bucketed", best_us,
        f"dev_mb={dev_mb:.1f};slab_mb={slab_mb:.1f};"
        f"byte_ratio={slab_mb / max(dev_mb, 1e-9):.2f};"
        f"widths={list(bx20.widths)};max_err={max_err:.2e}"))
    iterations.append(dict(tag="iterD/EHL*-20/bucketed",
                           us_per_query=best_us, device_mb=dev_mb,
                           slab_mb=slab_mb, widths=list(bx20.widths),
                           bucket_query_frac=occ, max_err=max_err))

    os.makedirs(OUT, exist_ok=True)
    json.dump(iterations, open(os.path.join(OUT, "ehl_perf.json"), "w"),
              indent=1)
    return rows
