"""Sharded serving bench — what region-sharding costs and balances.

Builds the budgeted artifact once, shards it over N (host) devices, and
reports against the single-device bucketed engine:

* placement quality: per-shard device bytes, imbalance (max/mean), planner
  rebalance moves;
* routing mix: same-shard vs cross-shard fraction on uniform and clustered
  workloads (locality-aware placement should keep clustered traffic
  same-shard);
* serving latency through the same PathServer stack, plus a bitwise
  identity check against the unsharded engine.

On a single CPU device the shards round-robin (placement degenerates but
every code path runs); under ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` the transfers are real.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import cluster_queries, pack_bucketed, uniform_queries
from repro.serving import PathServer, make_engine
from repro.sharding import ShardPlanner, ShardedQueryEngine

from . import common

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")


def _served_us(srv, s, t, reps: int = 3) -> float:
    best = np.inf
    for _ in range(reps):
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(s, t)
        best = min(best, srv.stats.us_per_query)
    return best


def _routing_mix(eng: ShardedQueryEngine, s, t) -> float:
    """Fraction of queries whose two endpoints live on one shard."""
    keys = eng.buckets_of(s, t)
    same = sum((lambda ij: ij[0] == ij[1])(eng.router.decode_key(int(k))[:2])
               for k in keys)
    return same / max(1, len(keys))


def run(map_name: str = "rooms-M", budget: float = 0.3,
        num_shards: int = 4, quick: bool = False):
    n = 300 if quick else 1000
    ctx = common.suite(map_name)
    idx, _, _ = common.ehl_star_cached(ctx, budget)
    bx = pack_bucketed(idx)

    planner = ShardPlanner(num_shards)
    plan = planner.plan(idx)
    sharded = planner.build(idx, plan)
    eng = ShardedQueryEngine(sharded)
    per = sharded.per_shard_bytes()

    qsets = {
        "Unknown": uniform_queries(ctx.scene, ctx.graph, n, seed=5,
                                   require_path=False),
        "Cluster-4": cluster_queries(ctx.scene, ctx.graph, 4, n, seed=6,
                                     require_path=False),
    }

    rows = [common.emit(
        f"sharded/{map_name}/placement", 0.0,
        f"shards={num_shards};imbalance={sharded.imbalance():.3f};"
        f"moves={plan.moves};"
        f"max_shard_mb={max(per) / 1e6:.2f};"
        f"total_mb={sharded.device_bytes() / 1e6:.2f};"
        f"single_mb={bx.device_bytes() / 1e6:.2f}")]

    srv_single = PathServer(make_engine(bx), batch_size=256)
    srv_single.warmup()
    srv_sharded = PathServer(ShardedQueryEngine(sharded), batch_size=256)
    srv_sharded.warmup()

    identical = True
    mix = {}
    for qname, qs in qsets.items():
        s = qs.s.astype(np.float32)
        t = qs.t.astype(np.float32)
        ref = srv_single.query(s, t)
        out = srv_sharded.query(s, t)
        fin = np.isfinite(ref)
        identical &= bool(
            np.array_equal(fin, np.isfinite(out))
            and np.array_equal(np.where(fin, ref, 0),
                               np.where(fin, out, 0)))
        mix[qname] = _routing_mix(eng, s, t)
        us_single = _served_us(srv_single, s, t)
        us_sharded = _served_us(srv_sharded, s, t)
        rows.append(common.emit(
            f"sharded/{map_name}/{qname}", us_sharded,
            f"us_single={us_single:.1f};same_shard={mix[qname]:.2f};"
            f"identical={identical}"))

    os.makedirs(OUT, exist_ok=True)
    json.dump(dict(map=map_name, budget_frac=budget, num_shards=num_shards,
                   per_shard_bytes=[int(b) for b in per],
                   imbalance=sharded.imbalance(), moves=plan.moves,
                   single_device_bytes=int(bx.device_bytes()),
                   total_bytes=int(sharded.device_bytes()),
                   same_shard_fraction=mix, identical=bool(identical)),
              open(os.path.join(OUT, "sharded.json"), "w"), indent=1)
    return rows
