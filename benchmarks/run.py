"""Benchmark harness entry — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table5,...]

Emits ``name,us_per_call,derived`` CSV lines (one per measurement).  The
dry-run / roofline artifacts are produced by their own entry points
(``repro.launch.dryrun``, ``benchmarks.roofline``) because they force a
512-device jax runtime; this harness reports them if present.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest sizes (single map, 3 budgets)")
    ap.add_argument("--full", action="store_true",
                    help="the paper-scale sweep (3 maps x 6 budgets x 4 "
                         "query sets; ~1h on one CPU core)")
    ap.add_argument("--only", default="",
                    help="comma list: table5,table6,fig5,kernels,ehlperf,"
                         "adaptive,sharded,serving,segvis_grid,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from repro import obs

    t0 = obs.monotonic()
    print("name,us_per_call,derived")

    rows: list = []

    def keep(res) -> None:
        """Collect CSV rows from bench modules that return them (modules
        return either ``rows`` or ``(rows, failures)``)."""
        if isinstance(res, tuple) and res:
            res = res[0]
        if isinstance(res, list):
            rows.extend(r for r in res if isinstance(r, str))

    def want(name):
        return only is None or name in only

    # default = mid-size (one map family per table, all budgets); --full
    # widens to the paper-scale sweep, --quick shrinks to CI size.
    if want("table5"):
        from . import bench_table5
        if args.full:
            keep(bench_table5.run())
        else:
            keep(bench_table5.run(maps=("rooms-M",), n_queries=160,
                                  budgets=(0.8, 0.6, 0.4, 0.2, 0.1, 0.05)
                                  if not args.quick else (0.6, 0.2, 0.05),
                                  quick=False))
    if want("table6"):
        from . import bench_deviation
        keep(bench_deviation.run(quick=args.quick or not args.full))
    if want("fig5"):
        from . import bench_regions
        keep(bench_regions.run(quick=args.quick))
    if want("kernels"):
        from . import bench_kernels
        keep(bench_kernels.run(quick=args.quick))
    if want("ehlperf"):
        from . import bench_ehl_perf
        keep(bench_ehl_perf.run(quick=True))
    if want("adaptive"):
        from . import bench_adaptive
        keep(bench_adaptive.run(quick=args.quick or not args.full))
    if want("sharded"):
        from . import bench_sharded
        keep(bench_sharded.run(quick=args.quick or not args.full))
    if want("serving"):
        from . import bench_serving
        keep(bench_serving.run(quick=args.quick or not args.full))
    if want("segvis_grid"):
        from . import bench_segvis_grid
        keep(bench_segvis_grid.run(quick=args.quick))

    if want("roofline"):
        art = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts")
        n = 0
        if os.path.isdir(art):
            for f in sorted(os.listdir(art)):
                if f.startswith("roofline_") and f.endswith(".json"):
                    r = json.load(open(os.path.join(art, f)))
                    if r.get("status") == "ok":
                        print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                              f"dom={r['dominant']};"
                              f"useful={r['useful_flop_ratio']}")
                        n += 1
        if n == 0:
            print("roofline/none,0.0,run `python -m benchmarks.roofline`")

    # harness-level artifact: all collected CSV rows + the process-wide
    # metrics registry (every engine/server the benches built records there)
    from . import common
    common.write_bench_json(
        "harness", registry=obs.REGISTRY,
        data={"rows": rows, "only": sorted(only) if only else None,
              "quick": args.quick, "full": args.full,
              "total_s": obs.monotonic() - t0})

    print(f"# total {obs.monotonic() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
