"""Roofline reconciliation — measured kernel time vs the analytic terms.

The analytic roofline terms (``bench_kernels`` / ``bench_ehl_perf``:
``flops_vis = N*E*20``, ``flops_join = B*L*L*4``, gather bytes = B*W*20)
predict how the kernels *scale*; this bench checks that the machine
agrees.  Per kernel family it:

1. measures wall seconds at a reference size and **calibrates** an
   effective rate (term units / second) from it — absolute CPU rates
   mean nothing (interpret-mode Pallas, XLA fusion), the *scaling* is
   the claim;
2. predicts every other size from the calibrated rate
   (``sec_pred = term / rate``) and flags entries whose
   measured/predicted ratio falls outside the documented band;
3. reconciles the analytic flop terms against XLA's own
   ``cost_analysis()`` (via ``repro.obs.aot_cost``) at the calibration
   size — a second, independent check that the terms count the work the
   compiled program actually does.

**The band** (``BAND``): measured/predicted within [0.33, 3.0].  Wider
than a TPU roofline would need because CPU wall time folds in cache
effects and per-dispatch overhead that the linear terms ignore; a
genuine complexity mismatch (e.g. an O(L^2) term for an O(L) kernel)
misses the band by the size ratio, which is what the gate is for.
``cost_analysis`` caveat (see ``benchmarks/roofline.py`` and DESIGN.md
§13): XLA counts while-loop bodies once, so looped/scan kernels
under-report HLO flops — the families here are loop-free on the jnp
path, which is why the HLO reconciliation is meaningful at all.

Families: ``label_join`` (O(B*L^2) hub join), ``segvis`` (dense O(N*E)
visibility), ``segvis_grid`` (grid-pruned visibility on real maps,
term scales with the per-segment padded tile slots), ``gather``
(bucketed label gather, memory term B*W*20 bytes).  The join + segvis
families gate (exit nonzero out of band — the acceptance criterion);
the grid + gather families report.

Writes ``BENCH_attribution.json`` (+ a sha-keyed history entry) for
``make_tables`` and the CI artifact upload.

    PYTHONPATH=src python -m benchmarks.bench_attribution [--smoke]
"""

from __future__ import annotations

import argparse
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import (build_edge_grid, make_map, pack_bucketed,
                        segvis_grid)
from repro.core.packed import _pack_edges  # repolint: disable=layering -- the private packer IS the benchmark subject
from repro.kernels import ops

from . import common

#: Documented measured/predicted acceptance band (see module docstring).
BAND = (0.33, 3.0)

#: HLO-vs-analytic flops band: the analytic terms round per-element op
#: counts (20 flops/edge test, 4/join cell), XLA counts the exact HLO mix
#: post-fusion — agreement within ~3x in either direction is "the same
#: complexity class, same leading constant order".
HLO_BAND = (0.2, 5.0)


def _measure(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))            # warm (trace + compile)
    return common.best_seconds(
        lambda: jax.block_until_ready(fn(*args)), reps=reps)


def _family(name: str, entries, reps: int, gate: bool):
    """Calibrate on the first entry, predict the rest.

    ``entries``: list of (size_label, term, fn, args, hlo_flops|None).
    Returns report rows; ratio is measured/predicted (1.0 by
    construction on the calibration row).
    """
    rows, rate = [], None
    for i, (label, term, fn, args, hlo) in enumerate(entries):
        sec = _measure(fn, *args, reps=reps)
        if rate is None:
            rate = term / sec                   # term units per second
            pred = sec
        else:
            pred = term / rate
        ratio = sec / pred
        row = dict(family=name, size=label, term=float(term),
                   measured_s=float(sec), predicted_s=float(pred),
                   ratio=float(ratio), calibration=i == 0, gated=gate,
                   in_band=bool(BAND[0] <= ratio <= BAND[1]))
        if hlo is not None:
            row["hlo_flops"] = float(hlo)
            row["hlo_ratio"] = float(hlo / term) if term else float("nan")
            row["hlo_in_band"] = bool(
                HLO_BAND[0] <= row["hlo_ratio"] <= HLO_BAND[1])
        rows.append(row)
    return rows


def _join_entries(sizes, rng):
    out = []
    for B, L in sizes:
        hs = jnp.asarray(np.sort(rng.integers(0, 256, (B, L)), 1), jnp.int32)
        ht = jnp.asarray(np.sort(rng.integers(0, 256, (B, L)), 1), jnp.int32)
        vs = jnp.asarray(rng.uniform(0, 100, (B, L)), jnp.float32)
        vt = jnp.asarray(rng.uniform(0, 100, (B, L)), jnp.float32)
        fn = jax.jit(lambda *a: ops.label_join_ref(*a))
        term = B * L * L * 4                    # flops_join
        hlo = obs.aot_cost(fn, hs, vs, ht, vt).get("flops")
        out.append((f"B{B}xL{L}", term, fn, (hs, vs, ht, vt), hlo))
    return out


def _segvis_entries(sizes, rng):
    out = []
    for N, E in sizes:
        p = jnp.asarray(rng.uniform(0, 100, (N, 2)), jnp.float32)
        q = jnp.asarray(rng.uniform(0, 100, (N, 2)), jnp.float32)
        ea = jnp.asarray(rng.uniform(0, 100, (E, 2)), jnp.float32)
        eb = jnp.asarray(rng.uniform(0, 100, (E, 2)), jnp.float32)
        fn = jax.jit(lambda *a: ops.segvis_ref(*a))
        term = N * E * 20                       # flops_vis
        hlo = obs.aot_cost(fn, p, q, ea, eb).get("flops")
        out.append((f"N{N}xE{E}", term, fn, (p, q, ea, eb), hlo))
    return out


def _grid_entries(maps, n_segments, rng):
    """Grid-pruned visibility on real maps: the term scales with the
    per-segment padded tile gather (``tile_slots``), the quantity the
    auto-attach policy reasons about."""
    out = []
    for name in maps:
        scene = make_map(name, seed=0)
        E = scene.edges.shape[0]
        ea, eb, ec = _pack_edges(scene, lane=128)
        grid = build_edge_grid(ea, eb, E, scene.width, scene.height,
                               sentinel=ea.shape[0] - 1)
        P = rng.uniform(0, [scene.width, scene.height],
                        (n_segments, 2)).astype(np.float32)
        Q = rng.uniform(0, [scene.width, scene.height],
                        (n_segments, 2)).astype(np.float32)
        p, q = jnp.asarray(P), jnp.asarray(Q)
        ea_, eb_, ec_ = map(jnp.asarray, (ea, eb, ec))
        fn = jax.jit(lambda a, b, g=grid, x=ea_, y=eb_, z=ec_:
                     segvis_grid(a, b, x, y, z, g))
        term = n_segments * int(grid.tile_slots) * 20
        out.append((f"{name}/T{int(grid.tile_slots)}", term, fn, (p, q),
                    None))
    return out


def _gather_entries(map_name, budget, B, rng):
    """Bucketed label gather — the memory-bound family: term is the
    slab bytes moved per batch (B rows x W slots x 20 B/slot f32)."""
    from repro.core import gather_labels_at_width
    ctx = common.suite(map_name)
    idx, _, _ = common.ehl_star_cached(ctx, budget)
    bx = pack_bucketed(idx)
    R = int(bx.region_bucket.shape[0])
    regions = jnp.asarray(rng.integers(0, R, B), jnp.int32)
    out = []
    for w in bx.widths:
        term = B * int(w) * 20                  # bytes moved
        hlo = obs.aot_cost(gather_labels_at_width.jit, bx, regions,
                           width=int(w)).get("bytes accessed")
        fn = (lambda bx_, r_, w_=int(w):
              gather_labels_at_width(bx_, r_, width=w_))
        out.append((f"{map_name}/W{int(w)}", term, fn, (bx, regions), hlo))
    return out


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    reps = 3 if smoke else 5
    # smallest size stays >= (128, 256): below that the operands fit in
    # cache and the effective rate roughly doubles, which is a property
    # of the machine, not of the analytic term being reconciled
    join_sizes = [(128, 256), (64, 512)] if smoke \
        else [(128, 256), (64, 512), (256, 512)]
    segvis_sizes = [(4096, 256), (8192, 512)] if smoke \
        else [(4096, 256), (8192, 512), (16384, 1024)]
    grid_maps = ("rooms-M", "scatter-L")
    n_grid = 512 if smoke else 2048

    report = []
    report += _family("label_join", _join_entries(join_sizes, rng),
                      reps, gate=True)
    report += _family("segvis", _segvis_entries(segvis_sizes, rng),
                      reps, gate=True)
    report += _family("segvis_grid", _grid_entries(grid_maps, n_grid, rng),
                      reps, gate=False)
    report += _family("gather",
                      _gather_entries("rooms-M", 0.2, 256, rng),
                      reps, gate=False)

    failures = []
    for r in report:
        flag = ""
        if r["gated"] and not r["calibration"] and not r["in_band"]:
            failures.append(f"{r['family']}/{r['size']}: measured/predicted "
                            f"{r['ratio']:.2f} outside band {BAND}")
            flag = "  OUT-OF-BAND"
        hlo = (f"  hlo_ratio={r['hlo_ratio']:.2f}"
               f"{'' if r.get('hlo_in_band', True) else ' (off)'}"
               if "hlo_ratio" in r else "")
        print(f"attribution/{r['family']}/{r['size']}: "
              f"measured={r['measured_s'] * 1e3:.2f}ms "
              f"predicted={r['predicted_s'] * 1e3:.2f}ms "
              f"ratio={r['ratio']:.2f}"
              f"{' [cal]' if r['calibration'] else ''}{hlo}{flag}",
              flush=True)

    common.write_bench_json(
        "attribution",
        data=dict(band=list(BAND), hlo_band=list(HLO_BAND), smoke=smoke,
                  rows=report, failures=failures))
    return report, failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / fewer reps for CI")
    args = ap.parse_args(argv)
    _, failures = run(smoke=args.smoke)
    if failures:
        print("ATTRIBUTION GATE FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("attribution gate OK: measured/predicted ratios inside "
          f"{BAND} for the gated kernel families")


if __name__ == "__main__":
    main()
