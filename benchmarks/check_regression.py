"""Bench regression gate — current run vs the committed history baseline.

``common.write_bench_json`` appends every bench run to
``artifacts/history/`` keyed by git sha.  This checker compares the
*current* ``BENCH_<name>.json`` against the most recent history entry
from a **different** commit with the **same config** (map / n / batch
size / budget — throughput at unequal config is not comparable) and
fails on:

* qps drop  > ``--max-qps-drop``   (default 10%);
* p99 inflation past ``p99_factor * baseline + p99_slack_ms``
  (default 1.25x + 2ms — the same shape as the serving overhead gate,
  with absolute slack so a 0.1ms baseline can't fail on noise).

No baseline (first run at a config, empty history) passes with a note —
the gate bites from the second commit onward, which is exactly when a
regression *can* exist.

    PYTHONPATH=src python -m benchmarks.check_regression serving
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import common

#: data[] keys that must match for two runs to be comparable.
CONFIG_KEYS = ("map", "n", "batch_size", "budget_frac", "smoke")


def config_of(rec: dict) -> dict:
    data = rec.get("data") or {}
    return {k: data.get(k) for k in CONFIG_KEYS}


def find_baseline(current: dict, history: list) -> dict | None:
    """Newest history entry from another commit at the same config."""
    want = config_of(current)
    sha = current.get("git_sha")
    for rec in reversed(history):               # newest first
        if rec.get("git_sha") != sha and config_of(rec) == want:
            return rec
    return None


def check(name: str, *, max_qps_drop: float = 0.10,
          p99_factor: float = 1.25, p99_slack_ms: float = 2.0,
          out_dir: str = None, current: dict = None) -> list:
    """Returns failure strings (empty == gate passes); prints a verdict
    line per compared metric."""
    out_dir = common.ARTIFACTS if out_dir is None else out_dir
    if current is None:
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        if not os.path.exists(path):
            return [f"no current artifact {path} — run the bench first"]
        with open(path) as f:
            current = json.load(f)
    base = find_baseline(current, common.load_history(name,
                                                      out_dir=out_dir))
    if base is None:
        print(f"regression[{name}]: no same-config baseline from another "
              "commit in history — first run at this config, gate passes")
        return []
    print(f"regression[{name}]: baseline sha "
          f"{base.get('git_sha', '?')[:12]} vs current "
          f"{current.get('git_sha', '?')[:12]}")
    failures = []
    q_cur, q_base = current.get("qps"), base.get("qps")
    if q_cur is not None and q_base:
        floor = (1.0 - max_qps_drop) * q_base
        verdict = "OK" if q_cur >= floor else "FAIL"
        print(f"  qps {q_cur:.0f} vs baseline {q_base:.0f} "
              f"(floor {floor:.0f}): {verdict}")
        if q_cur < floor:
            failures.append(
                f"{name}: qps {q_cur:.0f} dropped more than "
                f"{max_qps_drop:.0%} below baseline {q_base:.0f}")
    p_cur, p_base = current.get("p99_ms"), base.get("p99_ms")
    if p_cur is not None and p_base is not None:
        ceil = p99_factor * p_base + p99_slack_ms
        verdict = "OK" if p_cur <= ceil else "FAIL"
        print(f"  p99 {p_cur:.2f}ms vs baseline {p_base:.2f}ms "
              f"(ceiling {ceil:.2f}ms): {verdict}")
        if p_cur > ceil:
            failures.append(
                f"{name}: p99 {p_cur:.2f}ms inflated past "
                f"{p99_factor:.2f}x baseline {p_base:.2f}ms + "
                f"{p99_slack_ms:.1f}ms")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="*", default=["serving"],
                    help="bench names to check (default: serving)")
    ap.add_argument("--max-qps-drop", type=float, default=0.10)
    ap.add_argument("--p99-factor", type=float, default=1.25)
    ap.add_argument("--p99-slack-ms", type=float, default=2.0)
    args = ap.parse_args(argv)
    failures = []
    for name in (args.names or ["serving"]):
        failures += check(name, max_qps_drop=args.max_qps_drop,
                          p99_factor=args.p99_factor,
                          p99_slack_ms=args.p99_slack_ms)
    if failures:
        print("BENCH REGRESSION GATE FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print("bench regression gate OK")


if __name__ == "__main__":
    main()
