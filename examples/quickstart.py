"""Quickstart: build EHL* on a synthetic map, compress to a budget, query.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (astar, build_ehl, build_visgraph,
                        compress_to_fraction, make_map, pack_index,
                        query, query_batch, uniform_queries)

import jax.numpy as jnp


def main():
    # 1. a scene: polygonal obstacles on a 60x60 map
    scene = make_map("rooms-S", seed=1)
    print(f"scene: {len(scene.polygons)} obstacles, "
          f"{int(scene.convex_mask.sum())} convex vertices")

    # 2. offline: visibility graph -> hub labels -> EHL grid index
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    print(f"EHL: {index.nx}x{index.ny} cells, "
          f"{index.label_memory() / 1e6:.2f} MB of labels")

    # 3. EHL*: compress to 25% of the EHL memory (Algorithm 1)
    stats = compress_to_fraction(index, 0.25)
    print(f"EHL*-25: {stats.final_bytes / 1e6:.2f} MB after {stats.merges} "
          f"merges, {stats.regions} regions (budget "
          f"{'met' if stats.final_bytes <= stats.budget else 'MISSED'})")

    # 4. query: single pair, with optimal path
    qs = uniform_queries(scene, graph, 5, seed=7)
    for s, t in zip(qs.s[:3], qs.t[:3]):
        d, path = query(index, s, t)
        dref, _ = astar(graph, s, t)
        print(f"  d({np.round(s, 1)} -> {np.round(t, 1)}) = {d:.3f} "
              f"(A* says {dref:.3f}), path via {len(path)} points")

    # 5. batched TPU-style engine on the packed index
    pk = pack_index(index)
    d = query_batch(pk, jnp.asarray(qs.s), jnp.asarray(qs.t))
    print("batched distances:", np.round(np.asarray(d), 3))


if __name__ == "__main__":
    main()
