"""Train a ~1M-param reduced LM end-to-end on CPU for a few hundred steps.

Exercises the full training substrate: sharded init, jitted train step,
AdamW, checkpoint/restart, fault injection.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()
    loss = train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    ])
    print(f"final loss: {loss:.4f}")


if __name__ == "__main__":
    main()
