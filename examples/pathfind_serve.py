"""End-to-end serving driver: EHL* index answering batched ESPP queries.

Builds the index under a memory budget (workload-aware if --clusters > 0),
freezes it into a device layout (width-bucketed by default — DESIGN.md §4),
then serves a stream of query batches through a pluggable query engine and
reports throughput plus per-bucket routing stats — the paper's online phase
as a service.

    PYTHONPATH=src python examples/pathfind_serve.py --budget 0.2 --clusters 2
"""

import argparse

import numpy as np

from repro.core import build_ehl, build_visgraph, compress_to_fraction
from repro.core.maps import make_map
from repro.core.packed import (bucketed_device_bytes, pack_bucketed,
                               pack_index, plan_buckets, slab_device_bytes)
from repro.core.query import path_length
from repro.core.workload import (cluster_queries, uniform_queries,
                                 workload_scores)
from repro.serving.engine import PathServer
from repro.serving.query_engine import make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--map", default="rooms-M")
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--clusters", type=int, default=0)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--layout", choices=("bucketed", "slab"),
                    default="bucketed",
                    help="device layout: width-bucketed slabs or the single "
                         "global-Lmax slab")
    ap.add_argument("--backend", choices=("jnp", "pallas", "host"),
                    default="jnp", help="query engine backend")
    ap.add_argument("--kernels", action="store_true",
                    help="alias for --backend pallas (interpret on CPU)")
    ap.add_argument("--paths", type=int, default=0,
                    help="also extract N full paths via the batched argmin "
                         "engine and verify their lengths")
    args = ap.parse_args()
    backend = "pallas" if args.kernels else args.backend

    scene = make_map(args.map, seed=0)
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    full_mb = index.label_memory() / 1e6

    scores, alpha = None, 0.0
    if args.clusters > 0:
        hist = cluster_queries(scene, graph, args.clusters, 2000, seed=9,
                               require_path=False)
        scores, alpha = workload_scores(index, hist), 0.2
    stats = compress_to_fraction(index, args.budget, cell_scores=scores,
                                 alpha=alpha)
    print(f"index: {full_mb:.1f} MB -> {stats.final_bytes / 1e6:.1f} MB "
          f"({args.budget:.0%} budget, workload-aware={args.clusters > 0})")

    # only the layout that actually serves is materialized on device; the
    # other side of the comparison print is computed analytically from the
    # grid's pack metadata
    serve_bucketed = args.layout == "bucketed" and backend != "host"
    serve_slab = args.layout == "slab" and backend != "host"
    pk = pack_index(index) if serve_slab else None
    bx = pack_bucketed(index) if serve_bucketed else None
    slab_bytes = pk.device_bytes() if pk is not None \
        else slab_device_bytes(index)
    bucket_bytes = bx.device_bytes() if bx is not None \
        else bucketed_device_bytes(index)
    counts, widths, region_bucket = plan_buckets(index)
    print(f"slab layout:     {len(index.regions)} regions, "
          f"{slab_bytes / 1e6:.1f} MB on device")
    print(f"bucketed layout: widths={widths}, "
          f"{bucket_bytes / 1e6:.1f} MB on device "
          f"({slab_bytes / max(1, bucket_bytes):.1f}x smaller)")
    counts = np.asarray(counts)
    for k, w in enumerate(widths):
        m = region_bucket == k
        used, total = counts[m].sum(), max(1, m.sum()) * w
        print(f"  bucket {k}: width={w:5d} regions={int(m.sum()):5d} "
              f"waste={1 - used / total:.1%}")

    if backend == "host":
        engine = make_engine(index, backend="host")
    else:
        engine = make_engine(bx if serve_bucketed else pk, backend=backend)

    if args.clusters > 0:
        qs = cluster_queries(scene, graph, args.clusters, args.queries,
                             seed=33, require_path=False)
    else:
        qs = uniform_queries(scene, graph, args.queries, seed=33,
                             require_path=False)
    srv = PathServer(engine, batch_size=args.batch)
    srv.warmup(paths=args.paths > 0)
    d = srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    print(f"served {srv.stats.queries} queries in {srv.stats.seconds:.3f}s "
          f"-> {srv.stats.us_per_query:.1f} us/query "
          f"({srv.stats.qps:,.0f} qps); {np.isfinite(d).sum()} reachable "
          f"[layout={args.layout}, backend={backend}]")
    for k, b in sorted(srv.stats.per_bucket.items()):
        print(f"  bucket {k}: width={b.width:5d} queries={b.queries:5d} "
              f"batches={b.batches:3d} occupancy={b.occupancy:.1%} "
              f"{b.us_per_query:.1f} us/query")

    if args.paths > 0:
        n = min(args.paths, len(qs.s))
        dp, paths = srv.query_paths(qs.s[:n].astype(np.float32),
                                    qs.t[:n].astype(np.float32),
                                    host_index=index)
        err = max((abs(path_length(p) - float(di))
                   for di, p in zip(dp, paths) if np.isfinite(di)),
                  default=0.0)
        print(f"extracted {n} paths via batched argmin ({backend}); "
              f"max |len(path) - d| = {err:.2e}")


if __name__ == "__main__":
    main()
