"""End-to-end serving driver: EHL* index answering batched ESPP queries.

Builds the index under a memory budget (workload-aware if --clusters > 0),
freezes it into a device layout (width-bucketed by default — DESIGN.md §4),
then serves a stream of query batches through a pluggable query engine and
reports throughput plus per-bucket routing stats — the paper's online phase
as a service.

    PYTHONPATH=src python examples/pathfind_serve.py --budget 0.2 --clusters 2

``--adaptive`` instead runs the closed-loop demo (DESIGN.md §8): serve a
clustered workload, shift it mid-run, and watch the index manager capture
the live distribution, recompress under the device-byte budget, and
hot-swap the artifact with zero downtime:

    PYTHONPATH=src python examples/pathfind_serve.py --adaptive \
        --map rooms-S --queries 250 --budget 0.4 --rounds 6

``--shards N`` serves through the region-sharded engine (DESIGN.md §9):
the bucketed slabs are placed over N devices (forced host devices work:
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), batches route by
(shard, bucket), and the answers are checked bitwise against the
single-device engine — the CI sharded smoke gate:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python examples/pathfind_serve.py --shards 4 --queries 1000

``--shards`` combines with ``--adaptive``: hot-swaps then republish every
shard atomically under one generation.
"""

import argparse
import os
import sys

import numpy as np

from repro import obs
from repro.core import (build_ehl, build_visgraph, bucketed_device_bytes,
                        cluster_queries, compress_to_fraction, make_map,
                        pack_bucketed, pack_index, path_length, plan_buckets,
                        slab_device_bytes, slab_layout, uniform_queries,
                        workload_scores)
from repro.indexing import IndexManager
from repro.serving import PathServer, expected_join_cost, make_engine


def serving_mesh_or_none(num_shards: int):
    """A real N-device mesh when the runtime has one, else round-robin."""
    from repro.launch.mesh import make_serving_mesh
    try:
        return make_serving_mesh(num_shards)
    except ValueError as e:
        print(f"note: {e}; round-robining shards onto available devices")
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--map", default="rooms-M")
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--clusters", type=int, default=0)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--layout", choices=("bucketed", "slab"),
                    default="bucketed",
                    help="device layout: width-bucketed slabs or the single "
                         "global-Lmax slab")
    ap.add_argument("--backend", choices=("jnp", "pallas", "host"),
                    default="jnp", help="query engine backend")
    ap.add_argument("--kernels", action="store_true",
                    help="alias for --backend pallas (interpret on CPU)")
    ap.add_argument("--quantize", choices=("off", "bf16", "f16"),
                    default="off",
                    help="serve quantized label slabs (DESIGN.md §11): "
                         "narrow distances + delta-encoded u16 via ids with "
                         "exact-argmin residual rescue; checks argmin/path "
                         "answers bitwise against the f32 engine and the "
                         "byte drop against --quantize-min-drop (CI gate)")
    ap.add_argument("--quantize-min-drop", type=float, default=1.8,
                    help="[quantize] required f32/quantized device-byte "
                         "ratio")
    ap.add_argument("--paths", type=int, default=0,
                    help="also extract N full paths via the batched argmin "
                         "engine and verify their lengths")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve through the region-sharded engine over N "
                         "devices; checks answers bitwise against the "
                         "single-device engine and per-shard bytes against "
                         "the per-device cap (CI smoke gate)")
    ap.add_argument("--shard-tol", type=float, default=1.15,
                    help="[shards] per-device byte cap as a multiple of "
                         "total/num_shards")
    ap.add_argument("--serve-async", action="store_true",
                    help="also serve through the continuous-batching loop "
                         "(coalescing queue + double-buffered dispatch) and "
                         "check the answers bitwise against the synchronous "
                         "path, requiring >= 1 full-batch flush and >= 1 "
                         "deadline flush (CI smoke gate; exits nonzero)")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive serving demo: live workload capture -> "
                         "budgeted recompression -> zero-downtime hot-swap "
                         "(repro.indexing); shifts the workload mid-run")
    ap.add_argument("--rounds", type=int, default=8,
                    help="[adaptive] serving rounds (workload shifts at "
                         "the midpoint)")
    ap.add_argument("--min-swaps", type=int, default=1,
                    help="[adaptive] exit nonzero unless at least this many "
                         "hot-swaps were published (CI smoke gate)")
    ap.add_argument("--async-swap", action="store_true",
                    help="[adaptive] build/validate/swap on a background "
                         "thread instead of between rounds")
    ap.add_argument("--metrics", action="store_true",
                    help="export telemetry (DESIGN.md §12) on exit: "
                         "telemetry.prom + telemetry.json + events.jsonl "
                         "under --metrics-dir; self-checks that the "
                         "Prometheus text parses and the expected series/"
                         "events are present (CI smoke gate)")
    ap.add_argument("--metrics-dir",
                    default=os.path.join(os.path.dirname(
                        os.path.abspath(__file__)), "..", "benchmarks",
                        "artifacts", "telemetry"),
                    help="[metrics] output directory")
    args = ap.parse_args()
    backend = "pallas" if args.kernels else args.backend
    if args.metrics:
        # compile/cost attribution (DESIGN.md §13) rides along with the
        # telemetry export; it must be enabled before the FIRST warmup —
        # the pjit cache is process-wide, so every cold compile happens
        # exactly once, and a capture installed later sees none of them
        obs.enable_profile()
    if args.adaptive:
        return run_adaptive(args, backend)
    if args.shards > 1:
        return run_sharded(args, backend)

    scene = make_map(args.map, seed=0)
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    full_mb = index.label_memory() / 1e6

    scores, alpha = None, 0.0
    if args.clusters > 0:
        hist = cluster_queries(scene, graph, args.clusters, 2000, seed=9,
                               require_path=False)
        scores, alpha = workload_scores(index, hist), 0.2
    stats = compress_to_fraction(index, args.budget, cell_scores=scores,
                                 alpha=alpha)
    print(f"index: {full_mb:.1f} MB -> {stats.final_bytes / 1e6:.1f} MB "
          f"({args.budget:.0%} budget, workload-aware={args.clusters > 0})")

    # only the layout that actually serves is materialized on device; the
    # other side of the comparison print is computed analytically from the
    # grid's pack metadata
    serve_bucketed = args.layout == "bucketed" and backend != "host"
    serve_slab = args.layout == "slab" and backend != "host"
    pk = pack_index(index) if serve_slab else None
    bx = pack_bucketed(index) if serve_bucketed else None
    slab_bytes = pk.device_bytes() if pk is not None \
        else slab_device_bytes(index)
    bucket_bytes = bx.device_bytes() if bx is not None \
        else bucketed_device_bytes(index)
    counts, widths, region_bucket = plan_buckets(index)
    print(f"slab layout:     {len(index.regions)} regions, "
          f"{slab_bytes / 1e6:.1f} MB on device")
    print(f"bucketed layout: widths={widths}, "
          f"{bucket_bytes / 1e6:.1f} MB on device "
          f"({slab_bytes / max(1, bucket_bytes):.1f}x smaller)")
    counts = np.asarray(counts)
    for k, w in enumerate(widths):
        m = region_bucket == k
        used, total = counts[m].sum(), max(1, m.sum()) * w
        print(f"  bucket {k}: width={w:5d} regions={int(m.sum()):5d} "
              f"waste={1 - used / total:.1%}")

    if backend == "host":
        if args.quantize != "off":
            print("--quantize needs a device backend (jnp|pallas)")
            sys.exit(2)
        engine = make_engine(index, backend="host")
    else:
        engine = make_engine(bx if serve_bucketed else pk, backend=backend)

    eng32, qerr = None, 0.0
    if args.quantize != "off" and backend != "host":
        lay = slab_layout(args.quantize)
        artq = (pack_bucketed(index, layout=lay) if serve_bucketed
                else pack_index(index, layout=lay))
        art32 = bx if serve_bucketed else pk
        drop = art32.device_bytes() / artq.device_bytes()
        qerr = float(np.asarray(artq.qerr))
        qs_ = artq.quant_stats()
        print(f"quantized[{args.quantize}]: "
              f"{artq.device_bytes() / 1e6:.2f} MB on device "
              f"({drop:.2f}x smaller), qerr={qerr:.2e}, "
              f"id_fallback={qs_['id_fallback']} "
              f"vid_fallback={qs_['vid_fallback']} "
              f"dist_fallback={qs_['dist_fallback']}")
        eng32 = engine                  # f32 reference for the bitwise gate
        engine = make_engine(artq, backend=backend)
        if drop < args.quantize_min_drop:
            print(f"QUANTIZED SMOKE FAILED:\n  byte drop {drop:.2f}x < "
                  f"required {args.quantize_min_drop:.2f}x")
            sys.exit(1)

    if args.clusters > 0:
        qs = cluster_queries(scene, graph, args.clusters, args.queries,
                             seed=33, require_path=False)
    else:
        qs = uniform_queries(scene, graph, args.queries, seed=33,
                             require_path=False)
    srv = PathServer(engine, batch_size=args.batch)
    srv.warmup(paths=args.paths > 0)
    d = srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    print(f"served {srv.stats.queries} queries in {srv.stats.seconds:.3f}s "
          f"-> {srv.stats.us_per_query:.1f} us/query "
          f"({srv.stats.qps:,.0f} qps); {np.isfinite(d).sum()} reachable "
          f"[layout={args.layout}, backend={backend}]")
    for k, b in sorted(srv.stats.per_bucket.items()):
        print(f"  bucket {k}: width={b.width:5d} queries={b.queries:5d} "
              f"batches={b.batches:3d} occupancy={b.occupancy:.1%} "
              f"{b.us_per_query:.1f} us/query")

    if eng32 is not None:
        failures = check_quantized(engine, eng32, qs.s.astype(np.float32),
                                   qs.t.astype(np.float32), qerr)
        if failures:
            print("QUANTIZED SMOKE FAILED:\n  " + "\n  ".join(failures))
            sys.exit(1)
        print("quantized smoke OK: argmin/covis bitwise vs f32, "
              "distances within the 2*qerr bound")

    if args.serve_async:
        failures = check_async(srv, qs.s.astype(np.float32),
                               qs.t.astype(np.float32), backend)
        if failures:
            print("ASYNC SMOKE FAILED:\n  " + "\n  ".join(failures))
            sys.exit(1)

    if args.paths > 0:
        n = min(args.paths, len(qs.s))
        dp, paths = srv.query_paths(qs.s[:n].astype(np.float32),
                                    qs.t[:n].astype(np.float32),
                                    host_index=index)
        err = max((abs(path_length(p) - float(di))
                   for di, p in zip(dp, paths) if np.isfinite(di)),
                  default=0.0)
        print(f"extracted {n} paths via batched argmin ({backend}); "
              f"max |len(path) - d| = {err:.2e}")

    if args.metrics:
        failures = dump_metrics(args, srv.telemetry)
        if failures:
            print("METRICS SMOKE FAILED:\n  " + "\n  ".join(failures))
            sys.exit(1)


def engine_argmin(engine, s, t) -> list:
    """Full-batch argmin through any bucket-routed engine (exact shapes)."""
    from repro.core.packed import empty_results

    keys = engine.buckets_of(s, t)
    outs = empty_results(len(s), True)
    for k in np.unique(keys):
        m = keys == k
        res = engine.batch_argmin(s[m], t[m], bucket=int(k))
        for o, r in zip(outs, res):
            o[m] = np.asarray(r)[:int(m.sum())]
    return outs


def check_quantized(eng_q, eng_32, s, t, qerr: float) -> list:
    """The quantized serving gate: distances within the documented bound,
    argmin winners (covis verdicts + via/hub ids — i.e. the extracted
    paths) bitwise-identical to the f32 engine.  Returns failure strings.
    """
    d32, cv32, vs32, hb32, vt32 = engine_argmin(eng_32, s, t)
    dq, cvq, vsq, hbq, vtq = engine_argmin(eng_q, s, t)
    failures = []
    fin = np.isfinite(d32)
    if not np.array_equal(fin, np.isfinite(dq)):
        failures.append("reachability differs from the f32 engine")
    bound = 2.0 * qerr + 1e-4 * np.abs(np.where(fin, d32, 0.0))
    err = np.abs(np.where(fin, dq - d32, 0.0))
    if not np.all(err <= bound + 1e-6):
        failures.append(f"distance error {err.max():.3e} over the "
                        f"2*qerr bound {2 * qerr:.3e}")
    if not np.array_equal(cv32, cvq):
        failures.append("covis verdicts differ from the f32 engine")
    m = ~cv32 & fin                     # rows whose path runs via hubs
    for name, a, b in (("via_s", vs32, vsq), ("hub", hb32, hbq),
                       ("via_t", vt32, vtq)):
        if not np.array_equal(a[m], b[m]):
            failures.append(f"argmin {name} ids differ from the f32 "
                            "engine (paths not bitwise)")
    return failures


def check_async(srv, s, t, label: str) -> list:
    """Continuous-batching smoke: serve through the coalescing loop and
    compare bitwise against the synchronous path.

    Two traffic shapes force both flush reasons deterministically:

    * *burst* — one ``submit()`` of > batch_size queries that all share the
      hottest dispatch key, so a full group exists the moment the serve
      loop looks (>= 1 full flush guaranteed);
    * *trickle* — a sub-batch-size submit with no ``flush()``, so only the
      ``max_wait_ms`` deadline can ship it (>= 1 deadline flush).

    Returns a list of failure strings (empty = pass).
    """
    bs = srv.batch_size
    with srv.engine.pin() as eng:
        keys = eng.buckets_of(s, t)
    vals, counts = np.unique(keys, return_counts=True)
    hot = np.nonzero(keys == int(vals[np.argmax(counts)]))[0]
    reps = -(-(bs + 1) // len(hot))     # ceil: tile past one full batch
    sb = np.tile(s[hot], (reps, 1))[:bs + len(hot)]
    tb = np.tile(t[hot], (reps, 1))[:bs + len(hot)]
    ref_burst = srv.query(sb, tb)
    ref_trickle = srv.query(s[:8], t[:8])

    srv.start_async(max_wait_ms=2.0)
    got_burst = srv.submit(sb, tb).result(timeout=120)
    got_trickle = srv.submit(s[:8], t[:8]).result(timeout=120)
    srv.stop_async()

    st = srv.stats
    failures = []
    if not np.array_equal(ref_burst, got_burst):
        failures.append(f"{label}: burst answers differ from sync path")
    if not np.array_equal(ref_trickle, got_trickle):
        failures.append(f"{label}: trickle answers differ from sync path")
    if st.full_flushes < 1:
        failures.append(f"{label}: no full-batch flush observed "
                        f"({st.full_flushes})")
    if st.deadline_flushes < 1:
        failures.append(f"{label}: no deadline flush observed "
                        f"({st.deadline_flushes})")
    bad_occ = {k: b.occupancy for k, b in st.per_bucket.items()
               if b.occupancy > 1.0}
    if bad_occ:
        failures.append(f"{label}: per-bucket occupancy above 1.0: "
                        f"{bad_occ}")
    print(f"async serve [{label}]: submitted={st.submitted} "
          f"flushes full={st.full_flushes} deadline={st.deadline_flushes} "
          f"forced={st.forced_flushes} pipeline_peak={st.pipeline_peak} "
          f"queue_peak={st.queue_depth_peak} "
          f"identical={'yes' if not failures else 'NO'}")
    return failures


def dump_metrics(args, telemetry, *, expect_shards: int = 0,
                 expect_swaps: int = 0) -> list:
    """Export telemetry.prom / telemetry.json / events.jsonl and self-check
    the export (DESIGN.md §12).  Returns failure strings (empty = pass):

    * the Prometheus text must round-trip through ``parse_prometheus``;
    * ``serve_queries_total`` must be present with a nonzero sum;
    * compile/cost attribution series (``jit_compiles_total`` +
      ``jit_cost_flops_total``, DESIGN.md §13) must be present — the
      capture is enabled with ``--metrics`` before the first warmup;
    * sharded runs must export per-shard series for every shard id;
    * adaptive runs must have logged >= ``expect_swaps`` swap events and
      exported build-pipeline stage spans (``build_stage_ms``).
    """
    out = os.path.abspath(args.metrics_dir)
    os.makedirs(out, exist_ok=True)
    text = obs.prometheus_text(telemetry.registry)
    with open(os.path.join(out, "telemetry.prom"), "w") as f:
        f.write(text)
    with open(os.path.join(out, "telemetry.json"), "w") as f:
        f.write(obs.json_snapshot(telemetry.registry))
    n_events = telemetry.events.dump_jsonl(
        os.path.join(out, "events.jsonl"))

    failures = []
    try:
        parsed = obs.parse_prometheus(text)
    except ValueError as e:
        return [f"metrics: exported Prometheus text does not parse: {e}"]
    served = sum(parsed.get("serve_queries_total", {}).values())
    if served <= 0:
        failures.append("metrics: no serve_queries_total series exported")
    compiles = sum(parsed.get("jit_compiles_total", {}).values())
    if compiles <= 0:
        failures.append("metrics: no jit_compiles_total series exported "
                        "(profile capture not live before first warmup?)")
    if sum(parsed.get("jit_cost_flops_total", {}).values()) <= 0:
        failures.append("metrics: no jit_cost_flops_total series "
                        "(cost_analysis capture produced nothing)")
    if expect_shards > 0:
        shards = {dict(k).get("shard")
                  for k in parsed.get("shard_slots_total", {})}
        missing = {str(i) for i in range(expect_shards)} - shards
        if missing:
            failures.append("metrics: per-shard series missing for "
                            f"shard(s) {sorted(missing)}")
    if expect_swaps > 0:
        swaps = telemetry.events.counts().get("swap", 0)
        if swaps < expect_swaps:
            failures.append(f"metrics: {swaps} swap events in the log, "
                            f"expected >= {expect_swaps}")
        builds = sum(parsed.get("builds_total", {}).values())
        if builds < expect_swaps:
            failures.append(f"metrics: {builds:.0f} builds_total, "
                            f"expected >= {expect_swaps}")
        if sum(parsed.get("build_stage_ms_count", {}).values()) <= 0:
            failures.append("metrics: no build_stage_ms stage spans "
                            "exported for the adaptive build pipeline")
        if telemetry.events.counts().get("plan_execute", 0) < 1:
            failures.append("metrics: no plan_execute planner decision "
                            "records in the event log")
    print(f"metrics: exported {len(parsed)} series "
          f"({served:.0f} queries served, {compiles:.0f} jit compiles), "
          f"{n_events} events -> {out}")
    return failures


def run_sharded(args, backend: str) -> None:
    """Sharded serving smoke: answers must match the single-device engine
    bitwise and every shard must respect the per-device byte cap.  Exits
    nonzero on any violation (the CI gate)."""
    import jax
    from repro.sharding import ShardPlanner, ShardedQueryEngine

    if backend == "host":
        print("--shards needs a device backend (jnp|pallas)")
        sys.exit(2)
    scene = make_map(args.map, seed=0)
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    compress_to_fraction(index, args.budget)

    mesh = serving_mesh_or_none(args.shards)
    lay = None if args.quantize == "off" else slab_layout(args.quantize)
    planner = ShardPlanner(args.shards, tol=args.shard_tol)
    plan = planner.plan(index)
    sharded = planner.build(index, plan)
    eng = ShardedQueryEngine(sharded, mesh=mesh,
                             use_kernels=backend == "pallas")
    bx = pack_bucketed(index)
    single = make_engine(bx, backend=backend)
    eng_q, sharded_q, qerr = None, None, 0.0
    if lay is not None:
        sharded_q = ShardPlanner(args.shards, tol=args.shard_tol,
                                 layout=lay).build(index, plan)
        eng_q = ShardedQueryEngine(sharded_q, mesh=mesh,
                                   use_kernels=backend == "pallas")
        qerr = max(float(np.asarray(b.qerr)) for b in sharded_q.shards)

    per = sharded.per_shard_bytes()
    print(f"sharded: {args.shards} shards over "
          f"{len({str(d) for d in eng.router.devices})} device(s) "
          f"(runtime has {len(jax.devices())}), "
          f"plan moves={plan.moves}")
    print(f"  bytes: total={sharded.device_bytes() / 1e6:.2f} MB "
          f"(single-device {bx.device_bytes() / 1e6:.2f} MB), "
          f"imbalance={sharded.imbalance():.3f}")

    qs = uniform_queries(scene, graph, args.queries, seed=33,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)

    srv1 = PathServer(single, batch_size=args.batch)
    srv1.warmup()
    ref = srv1.query(s, t)
    srv2 = PathServer(eng, batch_size=args.batch)
    srv2.warmup()
    out = srv2.query(s, t)
    print(f"  single-device: {srv1.stats.us_per_query:.1f} us/query; "
          f"sharded: {srv2.stats.us_per_query:.1f} us/query "
          f"({srv2.stats.qps:,.0f} qps)")
    for st in srv2.stats.per_shard:
        print(f"  shard {st.shard} [{st.device}]: regions={st.regions} "
              f"bytes={st.device_bytes / 1e6:.2f}MB occ={st.occupancy:.0%} "
              f"batches={st.batches} slots={st.slots} "
              f"gathers_out={st.gathers_out} "
              f"{st.us_per_slot:.1f} us/slot")

    failures = []
    fin = np.isfinite(ref)
    if not (np.array_equal(fin, np.isfinite(out))
            and np.array_equal(np.where(fin, ref, 0),
                               np.where(fin, out, 0))):
        bad = int((np.where(fin, ref, 0) != np.where(fin, out, 0)).sum())
        failures.append(f"{bad} answers differ from single-device engine")
    cap = args.shard_tol * sharded.device_bytes() / args.shards
    if max(per) > cap:
        failures.append(f"max shard {max(per)}B over per-device cap "
                        f"{cap:.0f}B")
    if eng_q is not None:
        drop = sharded.device_bytes() / sharded_q.device_bytes()
        print(f"  quantized[{args.quantize}]: "
              f"{sharded_q.device_bytes() / 1e6:.2f} MB total "
              f"({drop:.2f}x smaller), qerr={qerr:.2e}")
        if drop < args.quantize_min_drop:
            failures.append(f"quantized byte drop {drop:.2f}x < required "
                            f"{args.quantize_min_drop:.2f}x")
        failures += check_quantized(eng_q, eng, s, t, qerr)
    if args.serve_async:
        failures += check_async(srv2, s, t, "sharded")
    if args.metrics:
        failures += dump_metrics(args, srv2.telemetry,
                                 expect_shards=args.shards)
    if failures:
        print("SHARDED SMOKE FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print(f"sharded smoke OK: {len(s)} answers bitwise-identical, "
          f"per-shard bytes within {args.shard_tol:.2f}x of fair share")


def run_adaptive(args, backend: str) -> None:
    """Closed-loop demo: the served workload shifts mid-run and the index
    manager recompresses + hot-swaps to follow it, holding the device-byte
    budget throughout.  Exits nonzero unless >= --min-swaps swaps happened
    with answers stable across every swap boundary (the CI smoke gate)."""
    scene = make_map(args.map, seed=0)
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    lay = None if args.quantize == "off" else slab_layout(args.quantize)
    budget = int(bucketed_device_bytes(index) * args.budget)
    shard_kw = {}
    if args.shards > 1:
        from repro.sharding import sharded_overhead_bytes
        over_kw = dict(layout=lay) if lay is not None else {}
        budget += sharded_overhead_bytes(index, args.shards, **over_kw)
        shard_kw = dict(num_shards=args.shards,
                        mesh=serving_mesh_or_none(args.shards),
                        shard_tol=args.shard_tol)

    # validate_tol=0: a candidate only goes live if the probe answers are
    # *bitwise* identical, so the smoke gate below (np.array_equal across
    # every swap boundary) is checking the same criterion the manager
    # enforces — merging/splitting preserves each winning label's exact
    # float arithmetic, so zero tolerance is attainable, and any candidate
    # that misses it is aborted rather than published
    # (quantized layouts widen the manager's effective probe tolerance by
    # the generations' quantization-error bounds — the *argmin* stays exact
    # via the residual rescue, but reported distances carry the bound)
    # one Telemetry bundle across the manager and the server, so swap /
    # drift events and serve-side series land in the same export
    tel = obs.Telemetry()
    mgr = IndexManager(index, budget, backend=backend,
                       batch_size=args.batch,
                       min_queries=max(64, args.queries // 4),
                       replan_threshold=0.10, min_dwell=1, probe_n=64,
                       seed=17, validate_tol=0.0, layout=lay,
                       telemetry=tel, **shard_kw)
    uniform_engine = mgr.engine.current    # generation-0 uniform-score ref
    srv = PathServer(mgr.engine, batch_size=args.batch,
                     recorder=mgr.recorder, telemetry=tel)
    srv.warmup()
    print(f"adaptive: budget={budget / 1e6:.2f} MB "
          f"(x{args.budget:.2f} of uncompressed artifact), "
          f"initial device={mgr.device_bytes() / 1e6:.2f} MB, "
          f"backend={backend}")

    k = max(2, args.clusters)
    half = max(1, args.rounds // 2)
    phases = [cluster_queries(scene, graph, k, args.queries, seed=101,
                              require_path=False),
              cluster_queries(scene, graph, k, args.queries, seed=202,
                              require_path=False)]
    failures = []
    lat = {0: [], 1: []}
    for rnd in range(args.rounds):
        phase = 0 if rnd < half else 1
        qs = phases[phase]
        srv.stats.seconds = 0.0
        srv.stats.queries = 0
        srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
        lat[phase].append(srv.stats.us_per_query)

        probe_pre = mgr.probe_answers()
        qe_pre = mgr._qerr_of(mgr.engine.artifact) if lay is not None else 0.0
        if args.async_swap:
            mgr.maybe_adapt(block=False)
            mgr.join()                      # bound the demo's swap count
            swapped = mgr.generation > srv.stats.generation
        else:
            swapped = mgr.maybe_adapt()
        if swapped:
            probe_post = mgr.probe_answers()
            both_inf = (~np.isfinite(probe_pre)) & (~np.isfinite(probe_post))
            diff = np.abs(np.where(both_inf, 0.0, probe_post - probe_pre))
            # quantized: two exact-equal generations may differ by the sum
            # of their 2*qerr distance bounds; f32 stays bitwise (tol 0)
            swap_tol = 0.0
            if lay is not None:
                swap_tol = 2.0 * (qe_pre
                                  + mgr._qerr_of(mgr.engine.artifact))
            stable = bool(np.all(diff <= swap_tol))
            if not stable:
                failures.append(f"round {rnd}: probe answers changed "
                                "across swap boundary")
            if mgr.device_bytes() > budget:
                failures.append(f"round {rnd}: swapped-in artifact "
                                f"{mgr.device_bytes()}B over budget")
        rec = mgr.history[-1] if swapped else None
        print(f"round {rnd} phase {phase}: "
              f"{srv.stats.us_per_query:7.1f} us/query  "
              f"device={mgr.device_bytes() / 1e6:5.2f} MB  "
              f"gen={mgr.generation}"
              + (f"  SWAP[{rec.kind}] drift={rec.drift:.2f} "
                 f"build={rec.build_s:.2f}s pack={rec.pack_s:.2f}s "
                 f"probe_err={rec.probe_max_err:.1e}" if swapped else ""))

    qs2 = phases[1]
    s2 = qs2.s.astype(np.float32)
    t2 = qs2.t.astype(np.float32)
    jc_adapt = expected_join_cost(mgr.engine.current, s2, t2)
    jc_uni = expected_join_cost(uniform_engine, s2, t2)
    p50 = {ph: float(np.median(v)) for ph, v in lat.items() if v}
    st = mgr.stats()
    print(f"phase p50 latency: {p50} us/query")
    print(f"post-swap join cost on shifted workload: adapted={jc_adapt:.0f} "
          f"vs uniform-score={jc_uni:.0f} (mean dispatch width^2; "
          f"{'better' if jc_adapt <= jc_uni else 'WORSE'})")
    print(f"lifecycle: {st}")
    print(f"serve stats: gen={srv.stats.generation} swaps={srv.stats.swaps} "
          f"stale_batches={srv.stats.stale_batches}")

    if mgr.swaps < args.min_swaps:
        failures.append(f"only {mgr.swaps} swaps, need >= {args.min_swaps}")
    if mgr.validation_failures:
        failures.append(f"{mgr.validation_failures} probe validations "
                        "failed (swap aborted)")
    if args.serve_async:
        failures += check_async(srv, s2, t2, "adaptive")
    if args.metrics:
        failures += dump_metrics(
            args, tel, expect_swaps=args.min_swaps,
            expect_shards=args.shards if args.shards > 1 else 0)
    if failures:
        print("ADAPTIVE SMOKE FAILED:\n  " + "\n  ".join(failures))
        sys.exit(1)
    print(f"adaptive smoke OK: {mgr.swaps} hot-swap(s), answers stable, "
          f"budget held")


if __name__ == "__main__":
    main()
