"""End-to-end serving driver: EHL* index answering batched ESPP queries.

Builds the index under a memory budget (workload-aware if --clusters > 0),
then serves a stream of query batches through the jitted engine and reports
throughput — the paper's online phase as a service.

    PYTHONPATH=src python examples/pathfind_serve.py --budget 0.2 --clusters 2
"""

import argparse

import numpy as np

from repro.core import build_ehl, build_visgraph, compress_to_fraction
from repro.core.maps import make_map
from repro.core.packed import pack_index
from repro.core.workload import (cluster_queries, uniform_queries,
                                 workload_scores)
from repro.serving.engine import PathServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--map", default="rooms-M")
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--clusters", type=int, default=0)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--kernels", action="store_true",
                    help="route through the Pallas kernels (interpret on CPU)")
    args = ap.parse_args()

    scene = make_map(args.map, seed=0)
    graph = build_visgraph(scene)
    index = build_ehl(scene, cell_size=2.0, graph=graph)
    full_mb = index.label_memory() / 1e6

    scores, alpha = None, 0.0
    if args.clusters > 0:
        hist = cluster_queries(scene, graph, args.clusters, 2000, seed=9,
                               require_path=False)
        scores, alpha = workload_scores(index, hist), 0.2
    stats = compress_to_fraction(index, args.budget, cell_scores=scores,
                                 alpha=alpha)
    print(f"index: {full_mb:.1f} MB -> {stats.final_bytes / 1e6:.1f} MB "
          f"({args.budget:.0%} budget, workload-aware={args.clusters > 0})")

    pk = pack_index(index)
    print(f"packed: {pk.num_regions} regions x {pk.label_width} labels, "
          f"{pk.device_bytes() / 1e6:.1f} MB on device")

    if args.clusters > 0:
        qs = cluster_queries(scene, graph, args.clusters, args.queries,
                             seed=33, require_path=False)
    else:
        qs = uniform_queries(scene, graph, args.queries, seed=33,
                             require_path=False)
    srv = PathServer(pk, batch_size=args.batch, use_kernels=args.kernels)
    srv.warmup()
    d = srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    print(f"served {srv.stats.queries} queries in {srv.stats.seconds:.3f}s "
          f"-> {srv.stats.us_per_query:.1f} us/query "
          f"({srv.stats.qps:,.0f} qps); {np.isfinite(d).sum()} reachable")


if __name__ == "__main__":
    main()
