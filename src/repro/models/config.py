"""Unified model configuration covering the 10 assigned architectures.

One frozen dataclass parameterises every family: dense / MoE (incl. MLA) /
SSM (mamba2 SSD) / hybrid (parallel attn+SSM) / enc-dec (whisper) / VLM
backbone (M-RoPE).  ``reduced()`` returns the CPU-smoke-test scale of the
same family (same code paths, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD dims (state-space duality block)."""
    state_dim: int = 128         # N
    head_dim: int = 64           # P
    n_heads: int = 24            # d_inner / P
    expand: int = 2
    chunk: int = 128             # SSD block length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention flavour
    attn: str = "gqa"            # gqa | mla | none
    mla: Optional[MLAConfig] = None
    local_window: int = 0        # sliding-window size for local layers
    global_every: int = 0        # k -> layers with (l+1) % k == 0 are global
    softcap_attn: float = 0.0    # gemma2 attn-logit softcap
    softcap_logits: float = 0.0  # gemma2 final-logit softcap
    rope_theta: float = 10000.0
    rope: str = "rope"           # rope | mrope | none
    qk_norm: bool = False

    # mlp flavour
    act: str = "silu_glu"        # silu_glu | gelu_glu | gelu | relu2

    # MoE
    moe: bool = False
    n_experts: int = 0
    topk: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0      # leading dense layers (deepseek: 3)
    router: str = "softmax"      # softmax | sigmoid
    capacity_factor: float = 1.25
    mtp: bool = False            # deepseek multi-token-prediction head

    # SSM / hybrid
    ssm: Optional[SSMConfig] = None

    # enc-dec (whisper): decoder uses the fields above; encoder below
    encdec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500          # whisper: 30 s of 100 Hz frames, conv-stub /2

    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma: embeddings * sqrt(d_model)
    norm_eps: float = 1e-6
    remat: str = "full"          # full | none — activation checkpoint policy

    # lowering controls (roofline calibration sets unroll_layers=True with
    # single-block attention/CE so XLA's while-body-counted-once
    # cost_analysis sees every flop; production uses scan + chunking)
    unroll_layers: bool = False
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    ce_chunk: int = 512

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid state decode)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # every assigned arch has an autoregressive decoder

    def layer_is_global(self, l: int) -> bool:
        if self.local_window == 0:
            return True
        if self.global_every <= 0:
            return False
        return (l + 1) % self.global_every == 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, H, K, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        per_layer = 0
        if self.attn == "gqa":
            per_layer += d * H * hd + 2 * d * K * hd + H * hd * d
        elif self.attn == "mla":
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += (d * m.q_lora_rank + m.q_lora_rank * H * qk
                          + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                          + m.kv_lora_rank * H * (m.qk_nope_head_dim
                                                  + m.v_head_dim)
                          + H * m.v_head_dim * d)
        if self.ssm is not None:
            s = self.ssm
            d_in = s.n_heads * s.head_dim
            # in_proj emits (z, x, B, C, dt): B/C are group-shared [N], not
            # per-head; + depthwise conv + out_proj
            per_layer += d * (2 * d_in + 2 * s.state_dim + s.n_heads) \
                + d_in * d + s.conv_width * (d_in + 2 * s.state_dim) \
                + 3 * s.n_heads + d_in
        n_moe_layers = 0
        dense_ffn = lambda ff: (3 if "glu" in self.act else 2) * self.d_model * ff
        if self.moe:
            n_moe_layers = self.n_layers - self.n_dense_layers
            per_expert = dense_ffn(self.moe_d_ff)
            moe_per_layer = (self.n_experts + self.n_shared) * per_expert \
                + self.d_model * self.n_experts
            total_ffn = (self.n_dense_layers * dense_ffn(self.d_ff)
                         + n_moe_layers * moe_per_layer)
        else:
            total_ffn = self.n_layers * dense_ffn(self.d_ff)
        total = self.n_layers * (per_layer + 2 * self.d_model) + total_ffn
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.encdec:
            enc_layer = 4 * d * d + dense_ffn(self.d_ff) + 2 * d
            total += self.enc_layers * enc_layer + self.n_layers * 4 * d * d
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        dense_ffn = lambda ff: (3 if "glu" in self.act else 2) * self.d_model * ff
        n_moe_layers = self.n_layers - self.n_dense_layers
        inactive = n_moe_layers * (self.n_experts - self.topk) \
            * dense_ffn(self.moe_d_ff)
        return int(full - inactive)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        if self.mla:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=8, qk_rope_head_dim=8,
                                  v_head_dim=8)
        if self.ssm:
            kw["ssm"] = SSMConfig(state_dim=16, head_dim=8, n_heads=4,
                                  expand=2, chunk=16, conv_width=4)
        kw.update(n_layers=min(self.n_layers, 4) if not self.moe else 2,
                  d_model=64,
                  n_heads=4 if self.n_heads else 0,
                  n_kv_heads=2 if self.n_kv_heads else 0,
                  head_dim=16 if self.n_heads else 0,
                  d_ff=128, vocab=256,
                  local_window=8 if self.local_window else 0,
                  global_every=self.global_every and 2,
                  n_experts=4 if self.moe else 0,
                  topk=min(self.topk, 2), n_shared=min(self.n_shared, 1),
                  moe_d_ff=64 if self.moe else 0,
                  # no token dropping at smoke scale: decode == forward
                  capacity_factor=8.0 if self.moe else self.capacity_factor,
                  n_dense_layers=min(self.n_dense_layers, 1),
                  enc_layers=2 if self.encdec else 0,
                  enc_seq=32 if self.encdec else 0)
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = (
    ShapeSpec("train_4k", "train", 4096, 256),
    ShapeSpec("prefill_32k", "prefill", 32768, 32),
    ShapeSpec("decode_32k", "decode", 32768, 128),
    ShapeSpec("long_500k", "decode", 524288, 1),
)


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason) — the skip policy recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention global layers: 524k-token KV exceeds "
                       "pod HBM and attention is quadratic — skipped per brief")
    return True, ""
