"""Unified decoder LM + enc-dec — one code path for all 10 architectures.

Layer stack layout:

* ``dense_blocks`` — the leading ``n_dense_layers`` blocks (DeepSeek's first
  3 layers are dense even in MoE configs), unrolled.
* ``blocks`` — the remaining homogeneous blocks, parameters stacked on axis 0
  and executed with ``jax.lax.scan`` (+ optional per-block remat).  Per-layer
  heterogeneity (gemma's local/global alternation) rides along as a traced
  ``windows[L]`` vector, not as separate code paths.
* families: dense/moe/vlm -> attention blocks; ssm -> mamba2 mixer blocks;
  hybrid -> parallel attention + mamba2 heads sharing the block input
  (Hymba); audio -> whisper-style encoder + cross-attention decoder.

Public entry points: ``init_params``, ``forward`` (train/prefill),
``init_cache`` + ``decode_step`` (serving), ``loss_fn``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers as ll
from . import ssm as ssm_mod
from .config import ModelConfig
from repro.distributed.hints import hint

BATCH_AXES = ("pod", "data")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block_group(cfg: ModelConfig, key, L: int, dtype, moe: bool):
    """One stacked group of L identical blocks."""
    ks = ll.split_keys(key, 6)
    p = {"ln1": jnp.zeros((L, cfg.d_model), dtype),
         "ln2": jnp.zeros((L, cfg.d_model), dtype)}
    if cfg.attn == "gqa":
        p["attn"] = ll.init_gqa(cfg, ks[0], L, dtype)
    elif cfg.attn == "mla":
        p["attn"] = ll.init_mla(cfg, ks[0], L, dtype)
    if cfg.ssm is not None:
        p["ssm"] = ssm_mod.init_ssm(cfg, ks[1], L, dtype)
    if moe:
        p["moe"] = ll.init_moe(cfg, ks[2], L, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = ll.init_mlp(cfg, ks[2], L, dtype)
    if cfg.encdec:
        d = cfg.d_model
        p["xattn"] = dict(
            wq=ll.dense_init(ks[3], (L, d, cfg.n_heads * cfg.head_dim), dtype),
            wk=ll.dense_init(ks[4], (L, d, cfg.n_kv_heads * cfg.head_dim), dtype),
            wv=ll.dense_init(ks[5], (L, d, cfg.n_kv_heads * cfg.head_dim), dtype),
            wo=ll.dense_init(jax.random.fold_in(ks[3], 7),
                             (L, cfg.n_heads * cfg.head_dim, d), dtype),
        )
        p["lnx"] = jnp.zeros((L, d), dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    ks = ll.split_keys(key, 8)
    params = {
        "embed": ll.dense_init(ks[0], (cfg.vocab, cfg.d_model), dtype,
                               scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else 0
    n_plain = cfg.n_layers - n_moe
    if cfg.moe:
        if n_plain:
            params["dense_blocks"] = _init_block_group(
                cfg, ks[1], n_plain, dtype, moe=False)
        params["blocks"] = _init_block_group(cfg, ks[2], n_moe, dtype, moe=True)
    else:
        params["blocks"] = _init_block_group(
            cfg, ks[2], cfg.n_layers, dtype, moe=False)
    if not cfg.tie_embeddings:
        params["unembed"] = ll.dense_init(ks[3], (cfg.d_model, cfg.vocab), dtype)
    if cfg.mtp:
        params["mtp_proj"] = ll.dense_init(ks[4], (2 * cfg.d_model,
                                                   cfg.d_model), dtype)
        params["mtp_block"] = _init_block_group(cfg, ks[5], 1, dtype, moe=False)
    if cfg.encdec:
        params["encoder"] = {
            "blocks": _init_encoder_blocks(cfg, ks[6], dtype),
            "norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


def _init_encoder_blocks(cfg, key, dtype):
    d = cfg.d_model
    L = cfg.enc_layers
    ks = ll.split_keys(key, 5)
    return dict(
        ln1=jnp.zeros((L, d), dtype), ln2=jnp.zeros((L, d), dtype),
        wq=ll.dense_init(ks[0], (L, d, cfg.n_heads * cfg.head_dim), dtype),
        wk=ll.dense_init(ks[1], (L, d, cfg.n_heads * cfg.head_dim), dtype),
        wv=ll.dense_init(ks[2], (L, d, cfg.n_heads * cfg.head_dim), dtype),
        wo=ll.dense_init(ks[3], (L, cfg.n_heads * cfg.head_dim, d), dtype),
        mlp=ll.init_mlp(dataclasses.replace(cfg, act="gelu"), ks[4], L, dtype),
    )


def _windows(cfg: ModelConfig, L: int, offset: int = 0) -> jnp.ndarray:
    """Per-layer sliding-window vector (0 = full attention)."""
    return jnp.array(
        [0 if cfg.layer_is_global(l + offset) else cfg.local_window
         for l in range(L)], dtype=jnp.int32)


# ---------------------------------------------------------------------------
# blocks (shared by forward and decode)
# ---------------------------------------------------------------------------

def _attn_full(cfg, p, x, positions, window):
    if cfg.attn == "mla":
        q, k, v = ll.mla_qkv(cfg, p, x, positions)
    else:
        q, k, v = ll.gqa_qkv(cfg, p, x, positions)
    o = ll.flash_attention(q, k, v, causal=True, window=window,
                           softcap=cfg.softcap_attn,
                           q_chunk=cfg.attn_q_chunk,
                           kv_chunk=cfg.attn_kv_chunk,
                           unroll=cfg.unroll_layers)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


def _xattn_full(cfg, p, x, enc_out):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, enc_out.shape[1], K, hd)
    v = (enc_out @ p["wv"]).reshape(B, enc_out.shape[1], K, hd)
    o = ll.flash_attention(q, k, v, causal=False, window=0,
                           q_chunk=cfg.attn_q_chunk,
                           kv_chunk=cfg.attn_kv_chunk,
                           unroll=cfg.unroll_layers)
    return o.reshape(B, S, -1) @ p["wo"]


def _block_fwd(cfg: ModelConfig, p, x, positions, window, moe: bool,
               capacity: int, enc_out=None):
    # NOTE: a Megatron-style sequence-parallel carry hint was measured here
    # and REGRESSED peak memory 164->442 GiB on deepseek train_4k (XLA
    # re-materializes the gathered activations around each attention) —
    # recorded as a refuted hypothesis in EXPERIMENTS.md §Perf.
    h = ll.rmsnorm(x, p["ln1"], cfg.norm_eps)
    delta = jnp.zeros_like(x)
    if "attn" in p:
        delta = delta + _attn_full(cfg, p["attn"], h, positions, window)
    if "ssm" in p:
        d_ssm, _ = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                       unroll=cfg.unroll_layers)
        delta = delta + d_ssm
    if "attn" in p and "ssm" in p:
        delta = delta * 0.5          # hymba: mean-combine parallel heads
    x = x + delta
    if "xattn" in p:
        hx = ll.rmsnorm(x, p["lnx"], cfg.norm_eps)
        x = x + _xattn_full(cfg, p["xattn"], hx, enc_out)
    if moe:
        from repro.distributed.moe_ep import moe_block_ep
        h2 = ll.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_block_ep(cfg, p["moe"], h2, capacity)
    elif "mlp" in p:
        h2 = ll.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + ll.mlp(cfg, p["mlp"], h2)
    return x


def _remat(cfg: ModelConfig, body):
    """Activation-checkpoint policy for the layer scan.

    'full'  — save only the carry; recompute everything (min memory, but the
              recomputed forward re-triggers every FSDP weight all-gather);
    'dots'  — save matmul outputs (jax dots_with_no_batch_dims_saveable):
              backward skips the matmul recompute and its weight gathers —
              the collective-term lever for gather-bound cells (§Perf 4.4);
    'none'  — no checkpointing.
    """
    if cfg.remat == "full":
        return jax.checkpoint(body)
    if cfg.remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return body


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    if not cfg.moe:
        return 0
    c = int(n_tokens * cfg.topk / cfg.n_experts * cfg.capacity_factor) + 1
    return max(8, -(-c // 8) * 8)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, enc_frames):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend per the brief: conv downsampling happens upstream)."""
    eb = params["encoder"]["blocks"]
    B, S, d = enc_frames.shape
    pos = jnp.arange(S)
    half = d // 2
    freqs = 10000 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    pe = jnp.concatenate([jnp.sin(pos[:, None] * freqs),
                          jnp.cos(pos[:, None] * freqs)], axis=1)
    x = enc_frames + pe[None].astype(enc_frames.dtype)

    def enc_block(x, bp):
        h = ll.rmsnorm(x, bp["ln1"], cfg.norm_eps)
        H, hd = cfg.n_heads, cfg.head_dim
        q = (h @ bp["wq"]).reshape(B, S, H, hd)
        k = (h @ bp["wk"]).reshape(B, S, H, hd)
        v = (h @ bp["wv"]).reshape(B, S, H, hd)
        o = ll.flash_attention(q, k, v, causal=False, window=0,
                               q_chunk=cfg.attn_q_chunk,
                               kv_chunk=cfg.attn_kv_chunk,
                               unroll=cfg.unroll_layers)
        x = x + o.reshape(B, S, -1) @ bp["wo"]
        h2 = ll.rmsnorm(x, bp["ln2"], cfg.norm_eps)
        c2 = dataclasses.replace(cfg, act="gelu")
        return x + ll.mlp(c2, bp["mlp"], h2), None

    if cfg.unroll_layers:
        for l in range(cfg.enc_layers):
            x, _ = enc_block(x, jax.tree.map(lambda a: a[l], eb))
    else:
        fn = jax.checkpoint(enc_block) if cfg.remat == "full" else enc_block
        x, _ = jax.lax.scan(fn, x, eb)
    return ll.rmsnorm(x, params["encoder"]["norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, tokens, enc_frames=None,
            positions=None, embeds=None, return_hidden=False):
    """tokens [B,S] -> logits [B,S,V].  enc_frames for enc-dec configs;
    ``embeds`` overrides the token embedding (VLM patch-stub path)."""
    B, S = tokens.shape
    x = params["embed"][tokens] if embeds is None else embeds
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = hint(x, BATCH_AXES, None, None)
    positions = (jnp.broadcast_to(jnp.arange(S), (B, S))
                 if positions is None else positions)
    enc_out = encode(cfg, params, enc_frames) if cfg.encdec else None
    cap = _capacity(cfg, B * S)

    if "dense_blocks" in params:
        db = params["dense_blocks"]
        Ld = db["ln1"].shape[0]
        for l in range(Ld):
            bp = jax.tree.map(lambda a: a[l], db)
            x = _block_fwd(cfg, bp, x, positions, _windows(cfg, 1, l)[0],
                           moe=False, capacity=0, enc_out=enc_out)
        off = Ld
    else:
        off = 0

    blocks = params["blocks"]
    Lm = blocks["ln1"].shape[0]
    wins = _windows(cfg, Lm, off)

    def body(x, inp):
        bp, w = inp
        return _block_fwd(cfg, bp, x, positions, w, moe=cfg.moe,
                          capacity=cap, enc_out=enc_out), None

    if cfg.unroll_layers:
        bfn = _remat(cfg, body)
        for l in range(Lm):
            x, _ = bfn(x, (jax.tree.map(lambda a: a[l], blocks), wins[l]))
    else:
        x, _ = jax.lax.scan(_remat(cfg, body), x, (blocks, wins))

    xn = ll.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, xn)
    return (logits, x) if return_hidden else logits


def forward_hidden(cfg: ModelConfig, params, tokens, enc_frames=None,
                   positions=None, embeds=None):
    """Like ``forward`` but stops at the final-normed hidden state —
    the memory-sane entry for chunked losses and serving prefill (no
    [B, S, V] logits tensor is ever materialized)."""
    _, x = forward(cfg, params, tokens, enc_frames=enc_frames,
                   positions=positions, embeds=embeds, return_hidden=True)
    return ll.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def prefill(cfg: ModelConfig, params, tokens, enc_frames=None):
    """Serving prefill: run the stack over the prompt, emit ONLY the
    last-position logits (what a decode loop actually consumes)."""
    h = forward_hidden(cfg, params, tokens, enc_frames=enc_frames)
    return _unembed(cfg, params, h[:, -1:])


CE_CHUNK = 512   # default; cfg.ce_chunk overrides


def _chunked_ce(cfg, params, hidden, targets, mask):
    """Mean CE over valid targets, computed in CE_CHUNK-token slices so the
    [B, chunk, V] logits tile (sharded over model) is the only vocab-sized
    live tensor; jax.checkpoint recomputes it in the backward pass."""
    B, S, d = hidden.shape
    c = min(cfg.ce_chunk, S)
    pad = (-S) % c
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    t = jnp.pad(targets, ((0, 0), (0, pad)))
    m = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (S + pad) // c
    h = jnp.moveaxis(h.reshape(B, nc, c, d), 1, 0)       # [nc,B,c,d]
    t = jnp.moveaxis(t.reshape(B, nc, c), 1, 0)
    m = jnp.moveaxis(m.reshape(B, nc, c), 1, 0)

    @jax.checkpoint
    def body(tot, inp):
        hc, tc, mc = inp
        logits = _unembed(cfg, params, hc)               # [B,c,V] f32
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(nll * mc), None

    if cfg.unroll_layers:
        total = jnp.zeros((), jnp.float32)
        for i in range(nc):
            total, _ = body(total, (h[i], t[i], m[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, t, m))
    return total / jnp.maximum(mask.sum(), 1)


def _unembed(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.softcap_logits > 0:
        logits = jnp.tanh(logits / cfg.softcap_logits) * cfg.softcap_logits
    return hint(logits, BATCH_AXES, None, "model")


def mtp_logits(cfg: ModelConfig, params, hidden, tokens):
    """DeepSeek MTP head: depth-1 extra block predicting token t+2 from
    [h_t ; emb(t+1)] — returns logits aligned to targets shifted by 2."""
    B, S = tokens.shape
    emb_next = params["embed"][tokens[:, 1:]]              # [B,S-1,d]
    h = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1) @ params["mtp_proj"]
    bp = jax.tree.map(lambda a: a[0], params["mtp_block"])
    pos = jnp.broadcast_to(jnp.arange(S - 1), (B, S - 1))
    h = _block_fwd(cfg, bp, h, pos, jnp.int32(0), moe=False, capacity=0)
    return _unembed(cfg, params, ll.rmsnorm(h, params["final_norm"],
                                            cfg.norm_eps))


def loss_fn(cfg: ModelConfig, params, tokens, enc_frames=None,
            mtp_weight: float = 0.3):
    """Next-token CE (+ DeepSeek MTP auxiliary loss when configured).

    Uses the chunked CE (see ``_chunked_ce``) — the full [B,S,V] logits
    tensor is never materialized, which is what keeps the 4k x 256 train
    cells inside per-device HBM at 32k..262k vocab sizes.
    """
    _, hidden = forward(cfg, params, tokens, enc_frames=enc_frames,
                        return_hidden=True)
    hn = ll.rmsnorm(hidden, params["final_norm"], cfg.norm_eps)
    B, S = tokens.shape
    tgt = tokens[:, 1:]
    mask = jnp.ones_like(tgt, jnp.float32)
    loss = _chunked_ce(cfg, params, hn[:, :-1], tgt, mask)
    if cfg.mtp:
        emb_next = params["embed"][tokens[:, 1:]]
        h2 = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1) \
            @ params["mtp_proj"]
        bp = jax.tree.map(lambda a: a[0], params["mtp_block"])
        pos = jnp.broadcast_to(jnp.arange(S - 1), (B, S - 1))
        h2 = _block_fwd(cfg, bp, h2, pos, jnp.int32(0), moe=False, capacity=0)
        h2 = ll.rmsnorm(h2, params["final_norm"], cfg.norm_eps)
        tgt2 = tokens[:, 2:]
        m2 = jnp.ones_like(tgt2, jnp.float32)
        loss = loss + mtp_weight * _chunked_ce(cfg, params, h2[:, :-1],
                                               tgt2, m2)
    return loss


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_out=None, params=None):
    """Stacked per-layer cache pytree sized for ``max_len`` positions."""
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else 0
    n_plain = cfg.n_layers - n_moe

    def attn_cache(L):
        if cfg.attn == "mla":
            m = cfg.mla
            return dict(
                c=jnp.zeros((L, batch, max_len, m.kv_lora_rank), dtype),
                k_rope=jnp.zeros((L, batch, max_len, m.qk_rope_head_dim),
                                 dtype))
        return dict(
            k=jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                        dtype),
            v=jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                        dtype))

    def ssm_cache(L):
        s = cfg.ssm
        conv_ch = s.n_heads * s.head_dim + 2 * s.state_dim
        return dict(
            conv=jnp.zeros((L, batch, s.conv_width - 1, conv_ch), dtype),
            state=jnp.zeros((L, batch, s.n_heads, s.state_dim, s.head_dim),
                            jnp.float32))

    def group_cache(L):
        c = {}
        if cfg.attn != "none":
            c["attn"] = attn_cache(L)
        if cfg.ssm is not None:
            c["ssm"] = ssm_cache(L)
        if cfg.encdec:
            assert enc_out is not None and params is not None
            eb = params["blocks"]["xattn"]
            Se = enc_out.shape[1]
            k = jnp.einsum("bsd,ldk->lbsk", enc_out, eb["wk"])
            v = jnp.einsum("bsd,ldk->lbsk", enc_out, eb["wv"])
            K, hd = cfg.n_kv_heads, cfg.head_dim
            c["xk"] = k.reshape(L, batch, Se, K, hd)
            c["xv"] = v.reshape(L, batch, Se, K, hd)
        return c

    cache = {"step": jnp.zeros((), jnp.int32)}
    if n_plain and cfg.moe:
        cache["dense"] = group_cache(n_plain)
        cache["main"] = group_cache(n_moe)
    else:
        cache["main"] = group_cache(cfg.n_layers if not cfg.moe else n_moe)
    return cache


def _attn_decode(cfg, p, h, cache_l, pos, window):
    """h [B,1,d]; cache_l holds this layer's slabs; returns (out, new cache)."""
    from repro.distributed import hints
    from repro.distributed.flash_decode import (
        decode_attention_dist, seq_sharded_decode_applicable)

    B = h.shape[0]
    if cfg.attn == "mla":
        return _mla_decode(cfg, p, h, cache_l, pos, window)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    posv = jnp.full((B, 1), pos)
    q, k, v = ll.gqa_qkv(cfg, p, h, posv)
    Smax = cache_l["k"].shape[1]
    if seq_sharded_decode_applicable(hints.current_mesh(), B, Smax, K):
        o, kc, vc = decode_attention_dist(
            q, cache_l["k"], cache_l["v"], k, v, pos,
            window=window, softcap=cfg.softcap_attn)
        return o.reshape(B, 1, -1) @ p["wo"], dict(k=kc, v=vc)
    kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, pos, axis=1)
    o = ll.decode_attention(q, kc, vc, pos + 1, window=window,
                            softcap=cfg.softcap_attn)
    return o.reshape(B, 1, -1) @ p["wo"], dict(k=kc, v=vc)


def _mla_decode(cfg, p, h, cache_l, pos, window):
    """Absorbed-matrix MLA decode: attention runs in the latent space, the
    cache stores only (c, k_rope) — the MLA serving memory win."""
    m = cfg.mla
    B = h.shape[0]
    H = cfg.n_heads
    nope, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    posv = jnp.full((B, 1), pos)

    q = ll.rmsnorm(h @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    q = q.reshape(B, 1, H, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = ll.rope_angles(posv, rd, cfg.rope_theta)
    q_rope = ll.apply_rope(q_rope, cos, sin)

    dkv = h @ p["wdkv"]
    c_new = ll.rmsnorm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope_new = ll.apply_rope(
        dkv[..., m.kv_lora_rank:].reshape(B, 1, 1, rd), cos, sin
    ).reshape(B, 1, rd)

    cc = jax.lax.dynamic_update_slice_in_dim(cache_l["c"], c_new, pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache_l["k_rope"], k_rope_new,
                                             pos, axis=1)

    wukv = p["wukv"].reshape(m.kv_lora_rank, H, nope + vd)
    wuk, wuv = wukv[..., :nope], wukv[..., nope:]
    q_eff = jnp.einsum("bqhn,khn->bqhk", q_nope, wuk)       # [B,1,H,kvlora]
    s = (jnp.einsum("bqhk,bsk->bhs", q_eff.astype(jnp.float32),
                    cc.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhs", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * (nope + rd) ** -0.5
    Smax = cc.shape[1]
    posi = jnp.arange(Smax)
    s = jnp.where((posi <= pos)[None, None, :], s, ll.NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", pweights, cc.astype(jnp.float32))
    o = jnp.einsum("bhk,khv->bhv", ctx, wuv.astype(jnp.float32))
    out = o.reshape(B, 1, H * vd).astype(h.dtype) @ p["wo"]
    return out, dict(c=cc, k_rope=kr)


def _block_decode(cfg, p, cache_l, x, pos, window):
    h = ll.rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_cache = dict(cache_l)
    delta = jnp.zeros_like(x)
    if "attn" in p:
        o, new_cache["attn"] = _attn_decode(cfg, p["attn"], h,
                                            cache_l["attn"], pos, window)
        delta = delta + o
    if "ssm" in p:
        o, conv, st = ssm_mod.ssm_decode_step(cfg, p["ssm"], h,
                                              cache_l["ssm"]["conv"],
                                              cache_l["ssm"]["state"])
        new_cache["ssm"] = dict(conv=conv, state=st)
        delta = delta + o
    if "attn" in p and "ssm" in p:
        delta = delta * 0.5
    x = x + delta
    if "xattn" in p:
        hx = ll.rmsnorm(x, p["lnx"], cfg.norm_eps)
        B = x.shape[0]
        H, hd = cfg.n_heads, cfg.head_dim
        q = (hx @ p["xattn"]["wq"]).reshape(B, 1, H, hd)
        o = ll.decode_attention(q, cache_l["xk"], cache_l["xv"],
                                cache_l["xk"].shape[1], window=0)
        x = x + o.reshape(B, 1, -1) @ p["xattn"]["wo"]
    if "moe" in p:
        h2 = ll.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + ll.moe_block(cfg, p["moe"], h2, _capacity(cfg, x.shape[0]))
    elif "mlp" in p:
        h2 = ll.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + ll.mlp(cfg, p["mlp"], h2)
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One serving step: tokens [B,1] -> (logits [B,1,V], new cache)."""
    pos = cache["step"]
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    new_cache = {"step": pos + 1}
    if "dense" in cache:
        db = params["dense_blocks"]
        Ld = db["ln1"].shape[0]
        groups = []
        for l in range(Ld):
            bp = jax.tree.map(lambda a: a[l], db)
            cl = jax.tree.map(lambda a: a[l], cache["dense"])
            x, ncl = _block_decode(cfg, bp, cl, x, pos, _windows(cfg, 1, l)[0])
            groups.append(ncl)
        new_cache["dense"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *groups)
        off = Ld
    else:
        off = cfg.n_dense_layers if cfg.moe else 0

    blocks = params["blocks"]
    Lm = blocks["ln1"].shape[0]
    wins = _windows(cfg, Lm, off)

    def body(x, inp):
        bp, cl, w = inp
        x, ncl = _block_decode(cfg, bp, cl, x, pos, w)
        return x, ncl

    if cfg.unroll_layers:
        outs = []
        for l in range(Lm):
            x, ncl = body(x, (jax.tree.map(lambda a: a[l], blocks),
                              jax.tree.map(lambda a: a[l], cache["main"]),
                              wins[l]))
            outs.append(ncl)
        main_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, main_cache = jax.lax.scan(body, x, (blocks, cache["main"], wins))
    new_cache["main"] = main_cache

    x = ll.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, x), new_cache
