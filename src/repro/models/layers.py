"""Model building blocks — pure JAX, parameter pytrees, no framework deps.

Conventions
-----------
* Layer parameters are STACKED on axis 0 ([L, ...]) so the decoder runs as a
  single ``jax.lax.scan`` over layers — one compiled block regardless of
  depth (compile time, HLO size, and remat policy all benefit).
* Compute dtype is configurable (bf16 on TPU, f32 for CPU smoke tests);
  norms, softmax and rope run in f32.
* Attention is the flash pattern in pure JAX: query chunks mapped, KV chunks
  scanned with a running (max, denom, acc) — activation memory is
  O(q_chunk * kv_chunk), never O(S^2), which is what makes the 32k cells
  lowerable.  Sliding windows and logit softcap are masks/transforms on the
  chunk tile.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope_angles(positions, head_dim, theta):
    """positions [...,S] -> cos/sin [...,S, head_dim//2] (f32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin [..., S, hd//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


def apply_mrope(x, positions3, theta, sections):
    """Qwen2-VL M-RoPE: three position streams rotate disjoint sections.

    x [B,S,H,hd]; positions3 [3,B,S]; sections: per-stream pair counts
    summing to hd//2 (text-only inputs pass identical streams).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    cs, ss = [], []
    off = 0
    for i, sec in enumerate(sections):
        freqs = theta ** (-(jnp.arange(off, off + sec, dtype=jnp.float32))
                          / half)
        ang = positions3[i].astype(jnp.float32)[..., None] * freqs
        cs.append(jnp.cos(ang))
        ss.append(jnp.sin(ang))
        off += sec
    cos = jnp.concatenate(cs, axis=-1)
    sin = jnp.concatenate(ss, axis=-1)
    return apply_rope(x, cos, sin)


# ---------------------------------------------------------------------------
# flash attention (pure JAX, chunked, f32 accumulators)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _softcap(scores, cap):
    return jnp.tanh(scores / cap) * cap if cap > 0 else scores


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, q_chunk=512, kv_chunk=1024, unroll=False):
    """q [B,Sq,H,hd], k/v [B,Skv,K,hd or vd] (GQA: H % K == 0) -> [B,Sq,H,vd].

    window > 0 limits attention to the last `window` keys (sliding window);
    q_offset shifts query positions (prefill continuation / enc-dec not
    needed: encoder passes causal=False).  unroll=True replaces the block
    loops with python loops — identical math and blocking, but every block
    appears in the HLO so cost_analysis counts all flops (roofline
    calibration; XLA counts while bodies once).
    """
    B, Sq, H, hd = q.shape
    _, Skv, K, vd = v.shape
    rep = H // K
    scale = hd ** -0.5

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq = -(-Sq // qc)
    nk = -(-Skv // kc)
    q_pad = nq * qc - Sq
    k_pad = nk * kc - Skv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))

    qp = qp.reshape(B, nq, qc, H, hd)
    kp = kp.reshape(B, nk, kc, K, hd)
    vp = vp.reshape(B, nk, kc, K, vd)

    q_pos_base = jnp.arange(qc) + q_offset
    k_pos_base = jnp.arange(kc)

    def q_block(qi, qblk):
        # qblk [B, qc, H, hd]
        q_pos = q_pos_base + qi * qc

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblk, vblk = inp
            k_pos = k_pos_base + ki * kc
            # scores [B, H, qc, kc]
            kr = jnp.repeat(kblk, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kr,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            # window may be a traced per-layer scalar (scan over layers);
            # 0 means unlimited
            w = jnp.asarray(window, jnp.int32)
            w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
            mask = (k_pos < Skv)[None, :] & jnp.ones((qc, 1), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            mask &= q_pos[:, None] - k_pos[None, :] < w_eff
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            vr = jnp.repeat(vblk, rep, axis=2)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p,
                            vr.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            return (m_new, l * corr + p.sum(-1), acc * corr[..., None] + pv), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, vd), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for ki in range(nk):
                carry, _ = kv_step(carry, (jnp.int32(ki), kp[:, ki], vp[:, ki]))
            m, l, acc = carry
        else:
            ks = jnp.arange(nk)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (ks, jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2)          # [B, qc, H, vd]

    if unroll:
        outs = [q_block(jnp.int32(qi), qp[:, qi]) for qi in range(nq)]
        out = jnp.concatenate(outs, axis=1).reshape(B, nq * qc, H, vd)[:, :Sq]
    else:
        outs = jax.lax.map(lambda t: q_block(t[0], t[1]),
                           (jnp.arange(nq), jnp.moveaxis(qp, 1, 0)))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * qc, H, vd)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window=0, softcap=0.0):
    """Single-position attention against a prefilled cache.

    q [B,1,H,hd]; k_cache/v_cache [B,Smax,K,*]; cur_len: #valid cache slots
    (the new token's position is cur_len-1).
    """
    B, Smax, K, vd = v_cache.shape
    H = q.shape[2]
    rep = H // K
    scale = q.shape[-1] ** -0.5
    kr = jnp.repeat(k_cache, rep, axis=2)
    vr = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhk", q, kr,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    pos = jnp.arange(Smax)
    cur = jnp.asarray(cur_len).reshape(-1, 1)          # [B or 1, 1]
    w = jnp.asarray(window, jnp.int32)
    w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
    mask = (pos[None, :] < cur) & (pos[None, :] > cur - 1 - w_eff)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out[:, None].astype(q.dtype)        # [B,1,H,vd]


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_gqa(cfg, key, L, dtype):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = dict(
        wq=dense_init(ks[0], (L, d, H * hd), dtype),
        wk=dense_init(ks[1], (L, d, K * hd), dtype),
        wv=dense_init(ks[2], (L, d, K * hd), dtype),
        wo=dense_init(ks[3], (L, H * hd, d), dtype),
    )
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((L, hd), dtype)
        p["k_scale"] = jnp.zeros((L, hd), dtype)
    return p


def gqa_qkv(cfg, p, x, positions):
    """x [B,S,d] -> q [B,S,H,hd], k/v [B,S,K,hd] with rope applied."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_scale"], cfg.norm_eps)
        k = rmsnorm(k, p["k_scale"], cfg.norm_eps)
    if cfg.rope == "rope":
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    elif cfg.rope == "mrope":
        half = hd // 2
        sec = (half // 4, half - half // 4 - (half - half // 4) // 2,
               (half - half // 4) // 2)
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, pos3, cfg.rope_theta, sec)
        k = apply_mrope(k, pos3, cfg.rope_theta, sec)
    return q, k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(cfg, key, L, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = split_keys(key, 5)
    return dict(
        wdq=dense_init(ks[0], (L, d, m.q_lora_rank), dtype),
        q_norm=jnp.zeros((L, m.q_lora_rank), dtype),
        wuq=dense_init(ks[1], (L, m.q_lora_rank, H * qk), dtype),
        wdkv=dense_init(ks[2], (L, d, m.kv_lora_rank + m.qk_rope_head_dim),
                        dtype),
        kv_norm=jnp.zeros((L, m.kv_lora_rank), dtype),
        wukv=dense_init(ks[3], (L, m.kv_lora_rank,
                                H * (m.qk_nope_head_dim + m.v_head_dim)),
                        dtype),
        wo=dense_init(ks[4], (L, H * m.v_head_dim, d), dtype),
    )


def mla_qkv(cfg, p, x, positions):
    """Returns q [B,S,H,qk], k [B,S,H,qk], v [B,S,H,vd].

    The compressed latent (kv_lora + rope key) is what a serving cache would
    store — ``mla_latent`` below returns it for the decode path.
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    q = q.reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = x @ p["wdkv"]
    c = rmsnorm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:].reshape(B, S, 1, rope_d)

    cos, sin = rope_angles(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    ukv = (c @ p["wukv"]).reshape(B, S, H, nope + vd)
    k_nope, v = ukv[..., :nope], ukv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, q_rope.shape)], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    return q, k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, L, dtype, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    if "glu" in cfg.act:
        return dict(wg=dense_init(ks[0], (L, d, ff), dtype),
                    wu=dense_init(ks[1], (L, d, ff), dtype),
                    wd=dense_init(ks[2], (L, ff, d), dtype))
    return dict(wu=dense_init(ks[0], (L, d, ff), dtype),
                wd=dense_init(ks[1], (L, ff, d), dtype))


def mlp(cfg, p, x):
    if cfg.act == "silu_glu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    if cfg.act == "gelu_glu":
        return (jax.nn.gelu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    if cfg.act == "gelu":
        return jax.nn.gelu(x @ p["wu"]) @ p["wd"]
    if cfg.act == "relu2":
        h = jax.nn.relu(x @ p["wu"])
        return (h * h) @ p["wd"]
    raise ValueError(cfg.act)


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch, EP-shardable over the expert axis)
# ---------------------------------------------------------------------------

def init_moe(cfg, key, L, dtype):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = dict(
        router=dense_init(ks[0], (L, d, E), jnp.float32, scale=0.02),
        wg=dense_init(ks[1], (L, E, d, ff), dtype),
        wu=dense_init(ks[2], (L, E, d, ff), dtype),
        wd=dense_init(ks[3], (L, E, ff, d), dtype),
    )
    if cfg.n_shared:
        sub = jax.random.fold_in(ks[4], 1)
        p["shared"] = init_mlp(cfg, sub, L, dtype,
                               d_ff=cfg.moe_d_ff * cfg.n_shared)
    return p


def moe_block(cfg, p, x, capacity: int):
    """x [B,S,d] -> [B,S,d].  Top-k routing with static per-expert capacity.

    EP layout: the [E, C, d] expert buffer shards E over 'model' and the
    capacity queue over the batch axes; dispatch/combine run as k scatters /
    gathers whose [T, d] operands keep the token sharding (never the
    [T*k, d] replicated blow-up) — XLA lowers the cross-shard scatter to
    all-to-all traffic.  Overflowed tokens are dropped (capacity-factor
    semantics); the always-on shared expert keeps them covered in
    DeepSeek-style configs.
    """
    from repro.distributed.hints import hint

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.topk
    T = B * S
    xf = hint(x.reshape(T, d), ("pod", "data"), None)

    scores = (xf.astype(jnp.float32) @ p["router"])          # [T, E]
    if cfg.router == "sigmoid":
        probs = jax.nn.sigmoid(scores)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
    gate_v, exp_i = jax.lax.top_k(probs, k)                  # [T, k]
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # slot assignment: token-major priority over the flattened [T*k] queue
    flat_e = exp_i.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(-1)  # [T*k]
    pos = pos.reshape(T, k)
    keep = (pos >= 0) & (pos < capacity)
    pos_c = jnp.clip(pos, 0, capacity - 1)

    buf = hint(jnp.zeros((E, capacity, d), xf.dtype),
               "model", ("pod", "data"), None)
    for j in range(k):                                       # k sharded scatters
        upd = jnp.where(keep[:, j, None], xf, 0)
        buf = buf.at[exp_i[:, j], pos_c[:, j]].add(upd, mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = hint(h, "model", ("pod", "data"), None)
    out_buf = hint(jnp.einsum("ecf,efd->ecd", h, p["wd"]),
                   "model", ("pod", "data"), None)           # [E, C, d]

    y = jnp.zeros_like(xf)
    for j in range(k):                                       # k sharded gathers
        got = out_buf[exp_i[:, j], pos_c[:, j]]              # [T, d]
        w = (keep[:, j] * gate_v[:, j]).astype(xf.dtype)
        y = y + got * w[:, None]
    y = hint(y, ("pod", "data"), None)

    if cfg.n_shared:
        y = y + mlp(cfg, p["shared"], xf)
    return y.reshape(B, S, d)
