"""Mamba-2 SSD block (state-space duality) — chunked scan + step decode.

The SSD forward is the blocked algorithm of Dao & Gu (2024): sequence split
into chunks; *intra-chunk* terms computed as a masked attention-like matmul
(MXU-friendly), *inter-chunk* terms carried through a ``lax.scan`` over a
[B,H,N,P] state.  The per-token recurrence is

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t

``ssd_sequential`` is the O(S) reference the chunked form is tested against;
``ssm_decode_step`` is the O(1)-per-token serving path (the whole point of
the long_500k shape: state is [B,H,N,P], no KV cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm, split_keys


def init_ssm(cfg, key, L, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.n_heads * s.head_dim
    conv_ch = d_in + 2 * s.state_dim          # x, B, C share the causal conv
    ks = split_keys(key, 4)
    return dict(
        in_proj=dense_init(ks[0], (L, d, 2 * d_in + 2 * s.state_dim
                                   + s.n_heads), dtype),
        conv_w=dense_init(ks[1], (L, s.conv_width, conv_ch), dtype,
                          scale=s.conv_width ** -0.5),
        conv_b=jnp.zeros((L, conv_ch), dtype),
        A_log=jnp.zeros((L, s.n_heads), jnp.float32),
        dt_bias=jnp.zeros((L, s.n_heads), jnp.float32),
        D=jnp.ones((L, s.n_heads), jnp.float32),
        norm=jnp.zeros((L, d_in), dtype),
        out_proj=dense_init(ks[2], (L, d_in, d), dtype),
    )


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_in = s.n_heads * s.head_dim
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * s.state_dim]
    dt = proj[..., -s.n_heads:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv1d, width W.  xBC [B,S,C]; w [W,C]; b [C].

    state (decode): [B, W-1, C] previous inputs; returns (out, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(xBC.dtype), xBC], axis=1)
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(W)) + b
    new_state = pad[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out), new_state


def _gates(cfg, p_dt_bias, p_A_log, dt_raw):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p_dt_bias)   # [B,S,H]
    A = -jnp.exp(p_A_log)                                          # [H]
    return dt, A * dt                                              # dt, logdecay


def ssd_chunked(cfg, x, Bm, Cm, dt, log_dA, init_state=None, unroll=False):
    """Blocked SSD scan.

    x [B,S,H,P]; Bm/Cm [B,S,N]; dt/log_dA [B,S,H].
    Returns (y [B,S,H,P], final_state [B,H,N,P]).

    The intra-chunk quadratic form lives INSIDE the chunk scan, so the live
    working set is one [B,Q,Q,H] tile (~MBs), never the [B,nc,Q,Q,H]
    all-chunks tensor (measured 84.5 -> 15.7 GiB peak on hymba train_4k;
    EXPERIMENTS.md §Perf iteration 1).  ``unroll`` replaces the scan with a
    python loop for roofline calibration (cost_analysis counts while bodies
    once).
    """
    s = cfg.ssm
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(s.chunk, S)
    pad = (-S) % Q
    if pad:
        # identity pads: dt=0 and log_dA=0 contribute nothing to y or state
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_dA = jnp.pad(log_dA, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xc = jnp.moveaxis(x.reshape(B, nc, Q, H, P), 1, 0)      # [nc,B,Q,H,P]
    Bc = jnp.moveaxis(Bm.reshape(B, nc, Q, N), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(B, nc, Q, N), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, nc, Q, H), 1, 0)
    ldc = jnp.moveaxis(log_dA.reshape(B, nc, Q, H), 1, 0)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        xq, Bq, Cq, dtq, ldq = inp               # one chunk: [B,Q,...]
        cum = jnp.cumsum(ldq, axis=1)            # [B,Q,H] inclusive
        # intra: scores[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j <= i
        scores = jnp.einsum("bqn,bkn->bqk", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))
        decay = cum[:, :, None, :] - cum[:, None, :, :]     # [B,Q,Q,H]
        w = jnp.where(causal[None, :, :, None],
                      jnp.exp(decay) * dtq[:, None, :, :], 0.0)
        y = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, w,
                       xq.astype(jnp.float32))
        # inter: contribution of the carried state
        y = y + jnp.einsum("bqn,bqh,bhnp->bqhp", Cq.astype(jnp.float32),
                           jnp.exp(cum), state)
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum) * dtq          # [B,Q,H]
        c_state = jnp.einsum("bqh,bqn,bqhp->bhnp", tail,
                             Bq.astype(jnp.float32), xq.astype(jnp.float32))
        state = state * jnp.exp(cum[:, -1, :])[..., None, None] + c_state
        return state, y

    init = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    if unroll:
        state, ys = init, []
        for c in range(nc):
            state, yc = chunk_step(state, (xc[c], Bc[c], Cc[c], dtc[c],
                                           ldc[c]))
            ys.append(yc)
        final_state = state
        y = jnp.stack(ys, axis=0)
    else:
        final_state, y = jax.lax.scan(chunk_step, init,
                                      (xc, Bc, Cc, dtc, ldc))
    y = jnp.moveaxis(y, 0, 1).reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state


def ssd_sequential(cfg, x, Bm, Cm, dt, log_dA, init_state=None):
    """O(S) per-token reference recurrence (oracle for tests)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    init = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))

    def step(h, inp):
        xt, Bt, Ct, dtt, ldt = inp
        h = h * jnp.exp(ldt)[..., None, None] + \
            jnp.einsum("bh,bn,bhp->bhnp", dtt, Bt.astype(jnp.float32),
                       xt.astype(jnp.float32))
        y = jnp.einsum("bn,bhnp->bhp", Ct.astype(jnp.float32), h)
        return h, y

    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(Bm, 1, 0),
         jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(log_dA, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssm_forward(cfg, p, x, *, chunked=True, init_state=None, unroll=False):
    """Full mamba2 mixer: in_proj -> conv -> SSD -> gated norm -> out_proj.

    x [B,S,d] -> (y [B,S,d], (conv_state, ssd_state)) for decode handoff.
    """
    s = cfg.ssm
    B, S, _ = x.shape
    z, xBC, dt_raw = _split_proj(cfg, x @ p["in_proj"])
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    d_in = s.n_heads * s.head_dim
    xs = xBC[..., :d_in].reshape(B, S, s.n_heads, s.head_dim)
    Bm = xBC[..., d_in:d_in + s.state_dim]
    Cm = xBC[..., d_in + s.state_dim:]
    dt, log_dA = _gates(cfg, p["dt_bias"], p["A_log"], dt_raw)
    if chunked:
        y, state = ssd_chunked(cfg, xs, Bm, Cm, dt, log_dA,
                               init_state=init_state, unroll=unroll)
    else:
        y, state = ssd_sequential(cfg, xs, Bm, Cm, dt, log_dA,
                                  init_state=init_state)
    y = y + (p["D"][None, None, :, None] * xs.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, state)


def ssm_decode_step(cfg, p, x, conv_state, ssd_state):
    """One-token step.  x [B,1,d]; states from prefill.  O(1) in seq len."""
    s = cfg.ssm
    B = x.shape[0]
    z, xBC, dt_raw = _split_proj(cfg, x @ p["in_proj"])
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                   state=conv_state)
    d_in = s.n_heads * s.head_dim
    xs = xBC[..., :d_in].reshape(B, 1, s.n_heads, s.head_dim)[:, 0]
    Bm = xBC[:, 0, d_in:d_in + s.state_dim]
    Cm = xBC[:, 0, d_in + s.state_dim:]
    dt, log_dA = _gates(cfg, p["dt_bias"], p["A_log"], dt_raw[:, 0])

    h = ssd_state * jnp.exp(log_dA)[..., None, None] + \
        jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32),
                   xs.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, h
