"""Model zoo: unified decoder/enc-dec covering the 10 assigned archs."""
from .config import (LM_SHAPES, MLAConfig, ModelConfig, ShapeSpec,  # noqa: F401
                     SSMConfig, shape_applicable)
from .transformer import (decode_step, forward, init_cache, init_params,    # noqa: F401
                          loss_fn, encode)
