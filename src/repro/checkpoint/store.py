"""Distributed checkpointing: manifest + per-host npz shards, pure JAX.

Layout::

    <dir>/step_000042/
        manifest.json       # tree structure, shapes, dtypes, mesh, step
        host_000.npz        # this host's addressable shards, keyed by path

Every host writes only its addressable shards; restore re-assembles global
arrays with ``jax.make_array_from_callback`` under the *restore* mesh, so a
checkpoint taken on one mesh can be loaded onto another (elastic resize —
see tests/test_checkpoint.py::test_elastic_remesh_roundtrip).

Failure semantics: writes go to a temp dir, fsynced, then atomically
renamed — a crash mid-save never corrupts the latest complete checkpoint.
``latest_step`` scans for complete manifests only.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): leaf for path, leaf in flat}, treedef


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         n_hosts: int = 1) -> str:
    """Save a pytree of (possibly sharded) jax arrays. Returns final path."""
    flat, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    shard_payload = {}
    meta = {}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        shard_payload[name] = arr
        meta[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, f"host_{host_id:03d}.npz"), **shard_payload)
    if host_id == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_hosts": n_hosts, "leaves": meta},
                      f, indent=1)
    os.makedirs(ckpt_dir, exist_ok=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp0") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *, mesh=None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    shardings: optional pytree of NamedShardings (possibly for a DIFFERENT
    mesh than the save-time one) — arrays are re-sharded on load.
    """
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host_000.npz"))

    flat_like, treedef = _flatten(tree_like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else (None, None)

    out = {}
    for name in flat_like:
        arr = data[name]
        want = manifest["leaves"][name]
        assert list(arr.shape) == want["shape"], (name, arr.shape, want)
        if flat_sh is not None:
            out[name] = jax.device_put(arr, flat_sh[name])
        elif mesh is not None:
            out[name] = jax.device_put(arr, NamedSharding(mesh, P()))
        else:
            out[name] = jax.numpy.asarray(arr)
    leaves = [out[name] for name in flat_like]
    return jax.tree_util.tree_unflatten(treedef, leaves)
