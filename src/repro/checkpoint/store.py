"""Distributed checkpointing: manifest + per-host npz shards, pure JAX.

Layout::

    <dir>/step_000042/
        manifest.json       # tree structure, shapes, dtypes, mesh, step
        host_000.npz        # this host's addressable shards, keyed by path

Every host writes only its addressable shards; restore re-assembles global
arrays with ``jax.make_array_from_callback`` under the *restore* mesh, so a
checkpoint taken on one mesh can be loaded onto another (elastic resize —
see tests/test_checkpoint.py::test_elastic_remesh_roundtrip).

Failure semantics: writes go to a temp dir, fsynced, then atomically
renamed — a crash mid-save never corrupts the latest complete checkpoint.
``latest_step`` scans for complete manifests only.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): leaf for path, leaf in flat}, treedef


# ---------------------------------------------------------------------------
# EHL index blobs — the offline phase as a cacheable artifact
# ---------------------------------------------------------------------------

def save_ehl_index(path: str, index) -> str:
    """Serialize an ``EHLIndex``'s merge state (mapper + regions) to one npz.

    The geometry (scene/visgraph/hub labels) is NOT stored — it is cheap to
    key on and expensive to serialize; :func:`load_ehl_index` reattaches it.
    What IS stored is exactly what the offline phase (``build_ehl`` +
    ``compress``) computes: the cell->region mapper and each region's
    cells / label keys / hub ids / score.  Writes are atomic (tmp +
    ``os.replace``), matching the checkpoint semantics above.
    """
    live = sorted(index.regions)
    cells, keys, hubs, scores = [], [], [], []
    cells_off, keys_off, hubs_off = [0], [0], [0]
    for rid in live:
        r = index.regions[rid]
        cells.append(np.asarray(r.cells, dtype=np.int64))
        keys.append(np.asarray(r.keys, dtype=np.int64))
        hubs.append(np.asarray(r.hubs, dtype=np.int64))
        scores.append(r.score)
        cells_off.append(cells_off[-1] + len(r.cells))
        keys_off.append(keys_off[-1] + r.keys.size)
        hubs_off.append(hubs_off[-1] + r.hubs.size)
    payload = dict(
        cell_size=np.float64(index.cell_size),
        nx=np.int64(index.nx), ny=np.int64(index.ny),
        mapper=np.asarray(index.mapper, dtype=np.int64),
        rids=np.asarray(live, dtype=np.int64),
        scores=np.asarray(scores, dtype=np.float64),
        cells=np.concatenate(cells) if cells else np.zeros(0, np.int64),
        keys=np.concatenate(keys) if keys else np.zeros(0, np.int64),
        hubs=np.concatenate(hubs) if hubs else np.zeros(0, np.int64),
        cells_off=np.asarray(cells_off, dtype=np.int64),
        keys_off=np.asarray(keys_off, dtype=np.int64),
        hubs_off=np.asarray(hubs_off, dtype=np.int64))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_ehl_index(path: str, scene, graph, hl):
    """Reconstruct an ``EHLIndex`` from :func:`save_ehl_index` + geometry.

    The caller supplies the scene / visibility graph / hub labels the blob
    was built from (cache keys must guarantee this — see
    ``benchmarks.common.ehl_star_cached``).
    """
    from repro.core.grid import EHLIndex, Region

    z = np.load(path)
    regions = {}
    rids = z["rids"]
    co, ko, ho = z["cells_off"], z["keys_off"], z["hubs_off"]
    for i, rid in enumerate(rids):
        regions[int(rid)] = Region(
            rid=int(rid),
            cells=list(z["cells"][co[i]:co[i + 1]]),
            keys=z["keys"][ko[i]:ko[i + 1]],
            hubs=z["hubs"][ho[i]:ho[i + 1]],
            score=float(z["scores"][i]))
    return EHLIndex(scene=scene, graph=graph, hl=hl,
                    cell_size=float(z["cell_size"]),
                    nx=int(z["nx"]), ny=int(z["ny"]),
                    mapper=z["mapper"].copy(), regions=regions)


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         n_hosts: int = 1) -> str:
    """Save a pytree of (possibly sharded) jax arrays. Returns final path."""
    flat, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    shard_payload = {}
    meta = {}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        shard_payload[name] = arr
        meta[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, f"host_{host_id:03d}.npz"), **shard_payload)
    if host_id == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_hosts": n_hosts, "leaves": meta},
                      f, indent=1)
    os.makedirs(ckpt_dir, exist_ok=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp0") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *, mesh=None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    shardings: optional pytree of NamedShardings (possibly for a DIFFERENT
    mesh than the save-time one) — arrays are re-sharded on load.
    """
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "host_000.npz"))

    flat_like, treedef = _flatten(tree_like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else (None, None)

    out = {}
    for name in flat_like:
        arr = data[name]
        want = manifest["leaves"][name]
        assert list(arr.shape) == want["shape"], (name, arr.shape, want)
        if flat_sh is not None:
            out[name] = jax.device_put(arr, flat_sh[name])
        elif mesh is not None:
            out[name] = jax.device_put(arr, NamedSharding(mesh, P()))
        else:
            out[name] = jax.numpy.asarray(arr)
    leaves = [out[name] for name in flat_like]
    return jax.tree_util.tree_unflatten(treedef, leaves)
