from . import store  # noqa: F401
from .store import load_ehl_index, save_ehl_index  # noqa: F401
