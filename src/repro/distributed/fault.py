"""Fault tolerance: step guard, straggler policy, elastic re-mesh planning.

On a real multi-host deployment the JAX runtime surfaces device/host
failures as exceptions out of the step function (and slow hosts as barrier
timeouts).  This module packages the control-plane reaction:

* ``StepGuard`` — wraps the train step; on failure it restores the latest
  complete checkpoint and replays (the data pipeline is stateless in step,
  so replay is exact).  Retries are bounded; repeated failure at the same
  step triggers an elastic resize request.
* ``plan_remesh`` — given the healthy-device count, pick the largest
  (data, model) mesh that preserves the model axis (TP degree is a property
  of the lowered program; DP shrinks freely).  Checkpoints restore onto the
  new mesh via repro.checkpoint.store (shardings argument).
* ``StragglerPolicy`` — deterministic per-host data shards mean a straggler
  only delays its own shard; the policy records per-step durations and
  flags hosts slower than ``threshold`` x median over a window, feeding the
  resize decision (drop-and-redistribute).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str          # "device" | "timeout" | "nan"
    detail: str = ""


class SimulatedFault(RuntimeError):
    """Raised by tests / chaos hooks to emulate a device loss."""


def plan_remesh(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid with the same TP degree."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_devices} devices")
    return (n_devices // model_parallel, model_parallel)


@dataclasses.dataclass
class StragglerPolicy:
    window: int = 20
    threshold: float = 2.0
    _durations: dict = dataclasses.field(default_factory=dict)

    def record(self, host: int, seconds: float):
        self._durations.setdefault(host, []).append(seconds)
        if len(self._durations[host]) > self.window:
            self._durations[host].pop(0)

    def stragglers(self) -> list[int]:
        if not self._durations:
            return []
        med = sorted(sum(self._durations.values(), []))
        med = med[len(med) // 2]
        return [h for h, ds in self._durations.items()
                if len(ds) >= 3 and sorted(ds)[len(ds) // 2] > self.threshold * med]


class StepGuard:
    """Checkpoint-restart wrapper around a step callable."""

    def __init__(self, ckpt_dir: str, save_every: int, *,
                 max_retries: int = 2, on_resize=None):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.on_resize = on_resize
        self.events: list[FailureEvent] = []

    def run(self, step_fn, state, step: int, restore_fn):
        """Execute step_fn(state, step); on failure restore + replay."""
        for attempt in range(self.max_retries + 1):
            try:
                return step_fn(state, step)
            except SimulatedFault as e:      # device loss
                self.events.append(FailureEvent(step, "device", str(e)))
                if attempt == self.max_retries:
                    if self.on_resize is not None:
                        state = self.on_resize(state)
                        return step_fn(state, step)
                    raise
                state = restore_fn()
        raise AssertionError("unreachable")
