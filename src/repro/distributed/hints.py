"""Activation sharding hints — with_sharding_constraint that degrades to a
no-op off-mesh.

The model code calls ``hint(x, ("pod", "data"), None, "model")`` at the few
places GSPMD propagation needs an anchor (post-embedding residual stream,
unembedding logits).  When no mesh is registered (CPU unit tests) or an axis
doesn't exist / doesn't divide, the axis is dropped — the same model code
runs everywhere.
"""

from __future__ import annotations

import contextlib

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh_hints(mesh) -> None:
    global _MESH
    _MESH = mesh


@contextlib.contextmanager
def mesh_hints(mesh):
    global _MESH
    old = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = old


def current_mesh():
    return _MESH


def hint(x, *axes):
    """Constrain array sharding; silently drops impossible axes."""
    if _MESH is None:
        return x
    names = set(_MESH.axis_names)

    def live(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            t = tuple(b for b in a if b in names)
            return t if t else None
        return a if a in names else None

    fixed = []
    for i, a in enumerate(axes[:x.ndim]):
        a = live(a)
        if a is None:
            fixed.append(None)
            continue
        size = int(np.prod([_MESH.shape[b] for b in
                            (a if isinstance(a, tuple) else (a,))]))
        fixed.append(a if x.shape[i] % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*fixed)))
