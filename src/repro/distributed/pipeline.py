"""Pipeline parallelism over the ``pod`` axis — GPipe schedule in shard_map.

Opt-in (the default multipod config keeps ``pod`` as outer data parallelism):
layer-stacked block parameters are sharded over ``pod`` on the LAYER axis, so
each pod holds a contiguous stage of L/n_stages blocks; microbatches stream
through the stages with ``lax.ppermute`` handoffs.  The schedule runs
T = n_micro + n_stages - 1 ticks; tick t lets stage s work on microbatch
t - s (the classic GPipe trapezoid with bubble fraction
(n_stages-1)/T).  Differentiable end-to-end: ppermute's transpose is the
reverse permute, so jax.grad produces the standard 1F1B-equivalent backward
sweep without extra code.

``pipeline_forward`` is deliberately family-agnostic: it takes the SAME
stacked block pytree the scan path uses, so any dense/ssm/hybrid config can
be staged (MoE stages would additionally reshard experts per stage — out of
scope here and documented).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import hints


def pipeline_forward(cfg, params, tokens, *, n_micro: int,
                     axis: str = "pod"):
    """Decoder forward with blocks staged over ``axis``.

    tokens [B, S] sharded over 'data'; embed/unembed replicated per stage
    (they are cheap relative to the stack); returns final hidden [B, S, d].
    """
    from repro.models import transformer as T
    from repro.models import layers as ll

    mesh = hints.current_mesh()
    assert mesh is not None and axis in mesh.axis_names, "pipeline needs mesh"
    n_stages = int(mesh.shape[axis])
    blocks = params["blocks"]
    L = blocks["ln1"].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B, S = tokens.shape
    assert B % n_micro == 0, (B, n_micro)

    wins = T._windows(cfg, L)
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def stage_fn(stage_blocks, stage_wins, h, pos):
        def body(h, inp):
            bp, w = inp
            return T._block_fwd(cfg, bp, h, pos, w, moe=False,
                                capacity=0), None
        h, _ = jax.lax.scan(body, h, (stage_blocks, stage_wins))
        return h

    d = cfg.d_model
    mb = B // n_micro

    def inner(stage_blocks, stage_wins, x, positions):
        s = jax.lax.axis_index(axis)
        micro_x = x.reshape(n_micro, mb, S, d)
        micro_p = positions.reshape(n_micro, mb, S)
        Tt = n_micro + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            prev_out = carry                       # my output from tick t-1
            recv = jax.lax.ppermute(prev_out, axis, fwd_perm)
            m = t - s
            valid = (m >= 0) & (m < n_micro)
            mi = jnp.clip(m, 0, n_micro - 1)
            inp = jnp.where(s == 0, micro_x[mi], recv)
            pos = micro_p[mi]
            out = stage_fn(stage_blocks, stage_wins, inp, pos)
            out = jnp.where(valid, out, jnp.zeros_like(out))
            emit = jnp.where((s == n_stages - 1) & valid, out, 0)
            return out, emit

        _, emits = jax.lax.scan(tick, jnp.zeros((mb, S, d), x.dtype),
                                jnp.arange(Tt))
        # final-stage outputs live at ticks t = (n_stages-1) + m; every other
        # stage emitted zeros -> a psum over the axis broadcasts the result
        picked = emits[n_stages - 1:]
        picked = jax.lax.psum(picked, axis)
        return picked.reshape(B, S, d)

    y = shard_map(
        inner, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), blocks), P(axis),
                  P(None, None, None), P(None, None)),
        out_specs=P(None, None, None),
        check_rep=False,
    )(blocks, wins, x, positions)

    from repro.models.layers import rmsnorm
    return rmsnorm(y, params["final_norm"], cfg.norm_eps)
