from . import fault, sharding  # noqa: F401
from .hints import set_mesh_hints  # noqa: F401
