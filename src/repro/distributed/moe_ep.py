"""Expert-parallel MoE dispatch — explicit all-to-all inside shard_map.

GSPMD cannot shard the scatter/gather dispatch of a capacity-based MoE well
(it replicates the [E, C, d] buffer and the [T*k, d] update — 450 GiB/device
for DeepSeek-V3 at 1M tokens; measured, see EXPERIMENTS.md §Perf).  This
module implements the production pattern instead:

1. tokens are already sharded over the batch axes; inside shard_map each
   model-rank takes its 1/n_mp slice of the local tokens (expert-sequence
   split), so every device routes T/(n_dp*n_mp) tokens;
2. each device scatters its tokens into a send buffer laid out
   [n_mp destination ranks, E_loc, C2, d] and a single **all-to-all over the
   model axis** moves every token to the rank that owns its expert;
3. expert FFNs run on [E_loc, n_mp*C2, d] with FSDP-sharded weights gathered
   just-in-time over the data axis (all-gather, freed after the layer);
4. the reverse all-to-all + local combine + all-gather over model restores
   the token layout.

Per-device live memory: send/recv buffers T2*k*d*cf bytes (~0.6 GB for
DeepSeek-V3 train_4k) instead of replicated 150 GB buffers.  Differentiable
end-to-end (all_to_all/all_gather have exact transposes).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import hints


def _round8(x: int) -> int:
    return max(8, -(-x // 8) * 8)


def moe_block_ep(cfg, p, x, capacity_global: int):
    """Drop-in for layers.moe_block: EP path when a mesh is active and the
    token count divides; plain GSPMD path otherwise (decode, CPU tests)."""
    from repro.models.layers import moe_block, mlp

    mesh = hints.current_mesh()
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.topk
    T = B * S
    if mesh is None or "model" not in mesh.axis_names:
        return moe_block(cfg, p, x, capacity_global)

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    n_mp = int(mesh.shape["model"])
    if T % (n_dp * n_mp) != 0 or E % n_mp != 0 or d % n_dp != 0:
        return moe_block(cfg, p, x, capacity_global)   # decode-sized inputs

    T_loc = T // n_dp
    T2 = T_loc // n_mp
    C2 = _round8(int(T2 * k / E * cfg.capacity_factor))
    E_loc = E // n_mp
    dp_axis = dp if len(dp) > 1 else dp[0]

    def inner(xf, router, wg, wu, wd):
        # xf [T_loc, d] (replicated over model); weights [E_loc, d/n_dp, ff]
        j = jax.lax.axis_index("model")
        xj = jax.lax.dynamic_slice_in_dim(xf, j * T2, T2, axis=0)  # [T2,d]

        scores = xj.astype(jnp.float32) @ router                  # [T2,E]
        probs = (jax.nn.sigmoid(scores) if cfg.router == "sigmoid"
                 else jax.nn.softmax(scores, axis=-1))
        gate_v, exp_i = jax.lax.top_k(probs, k)                   # [T2,k]
        gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(exp_i.reshape(-1), E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(-1).reshape(T2, k)
        keep = (pos >= 0) & (pos < C2)
        pos_c = jnp.clip(pos, 0, C2 - 1)

        send = jnp.zeros((E, C2, d), xj.dtype)
        for kk in range(k):
            upd = jnp.where(keep[:, kk, None], xj, 0)
            send = send.at[exp_i[:, kk], pos_c[:, kk]].add(upd, mode="drop")

        # ---- all-to-all: token ranks -> expert ranks ----
        send = send.reshape(n_mp, E_loc, C2, d)
        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=False)
        work = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_mp * C2, d)

        # ---- expert FFN with just-in-time FSDP weight gather ----
        wg_f = jax.lax.all_gather(wg, dp_axis, axis=1, tiled=True)
        wu_f = jax.lax.all_gather(wu, dp_axis, axis=1, tiled=True)
        wd_f = jax.lax.all_gather(wd, dp_axis, axis=1, tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", work, wg_f)) * \
            jnp.einsum("ecd,edf->ecf", work, wu_f)
        out = jnp.einsum("ecf,efd->ecd", h, wd_f)         # [E_loc, n_mp*C2, d]

        # ---- reverse all-to-all: expert ranks -> token ranks ----
        back = jnp.moveaxis(out.reshape(E_loc, n_mp, C2, d), 1, 0)
        ret = jax.lax.all_to_all(back, "model", 0, 0, tiled=False)
        ret = ret.reshape(E, C2, d)

        yj = jnp.zeros_like(xj)
        for kk in range(k):
            got = ret[exp_i[:, kk], pos_c[:, kk]]                 # [T2,d]
            w = (keep[:, kk] * gate_v[:, kk]).astype(xj.dtype)
            yj = yj + got * w[:, None]
        return jax.lax.all_gather(yj, "model", axis=0, tiled=True)

    xf = x.reshape(T, d)
    y = shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_axis, None), P(), P("model", dp_axis, None),
                  P("model", dp_axis, None), P("model", dp_axis, None)),
        out_specs=P(dp_axis, None),
        check_rep=False,
    )(xf, p["router"], p["wg"], p["wu"], p["wd"])

    if cfg.n_shared:
        y = y + mlp(cfg, p["shared"], xf)
    return y.reshape(B, S, d)
