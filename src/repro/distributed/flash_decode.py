"""Distributed flash-decode: single-token attention over a KV cache whose
SEQUENCE axis is sharded across the ``model`` mesh axis.

Why: GQA serving caches are [B, S, K, hd].  When K (kv heads) doesn't divide
the model axis (nemotron-4: K=8 on a 16-way axis), GSPMD can only replicate
the cache over 'model' — 154 GiB/device at 32k x 128 batch (measured,
EXPERIMENTS.md §Perf iteration 2).  Sharding S instead needs a distributed
softmax, which GSPMD won't invent; this module writes it explicitly:

1. each model-rank scores its local cache slice and computes the partial
   (row-max m, exp-sum l, weighted value acc) — the flash-attention
   invariant triple;
2. one ``pmax`` + two ``psum`` of [B,H]/[B,H,vd] tiles combine the partials
   exactly (softmax is associative under max/sum renormalisation);
3. the cache update (dynamic_update_slice at the new position) is applied
   by the one rank whose slice contains the slot — no traffic.

Collective volume per layer: B*H*(2 + vd) floats instead of the full
B*S*K*hd cache gather — the measured collective term drops accordingly.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import hints
from repro.models.layers import NEG_INF, _softcap


def seq_sharded_decode_applicable(mesh, B, Smax, K) -> bool:
    """Use the explicit path iff heads can't shard but the sequence can."""
    if mesh is None or "model" not in mesh.axis_names:
        return False
    m = int(mesh.shape["model"])
    return K % m != 0 and Smax % m == 0


def decode_attention_dist(q, k_cache, v_cache, k_new, v_new, pos, *,
                          window=0, softcap=0.0):
    """q [B,1,H,hd]; caches [B,Smax,K,*] seq-sharded over 'model';
    k_new/v_new [B,1,K,*] this step's KV; pos: scalar write position.

    Returns (out [B,1,H,vd], new_k_cache, new_v_cache).
    """
    mesh = hints.current_mesh()
    B, Smax, K, vd = v_cache.shape
    H = q.shape[2]
    hd = q.shape[3]
    rep = H // K
    m_sz = int(mesh.shape["model"])
    S_loc = Smax // m_sz
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    da = (dp if len(dp) > 1 else dp[0]) if B % n_dp == 0 else None

    def inner(q, kc, vc, kn, vn, pos):
        # kc/vc local [B, S_loc, K, *]
        j = jax.lax.axis_index("model")
        local = pos - j * S_loc
        ok = (local >= 0) & (local < S_loc)
        li = jnp.clip(local, 0, S_loc - 1)
        kc_upd = jax.lax.dynamic_update_slice_in_dim(kc, kn, li, axis=1)
        vc_upd = jax.lax.dynamic_update_slice_in_dim(vc, vn, li, axis=1)
        kc = jnp.where(ok, kc_upd, kc)
        vc = jnp.where(ok, vc_upd, vc)

        kr = jnp.repeat(kc, rep, axis=2)
        vr = jnp.repeat(vc, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhk", q, kr,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        s = _softcap(s, softcap)
        slot = j * S_loc + jnp.arange(S_loc)
        w = jnp.asarray(window, jnp.int32)
        w_eff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
        mask = (slot <= pos) & (slot > pos - w_eff)
        s = jnp.where(mask[None, None, :], s, NEG_INF)

        m_loc = s.max(axis=-1)                               # [B,H]
        p = jnp.exp(s - m_loc[..., None])
        l_loc = p.sum(axis=-1)
        acc = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))

        m_glob = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m_glob)
        l = jax.lax.psum(l_loc * corr, "model")
        acc = jax.lax.psum(acc * corr[..., None], "model")
        out = (acc / jnp.maximum(l[..., None], 1e-30))[:, None]
        return out.astype(q.dtype), kc, vc

    qs = P(da, None, None, None)
    cs = P(da, "model", None, None)
    out, kc, vc = shard_map(
        inner, mesh=mesh,
        in_specs=(qs, cs, cs, qs, qs, P()),
        out_specs=(qs, cs, cs),
        check_rep=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)
    return out, kc, vc
