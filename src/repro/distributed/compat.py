"""Version-tolerant shims over the moving jax.sharding API surface.

Mirrors ``repro.kernels.compat`` for the distributed side: JAX has moved
mesh-construction details across releases (``jax.sharding.AxisType`` and the
``axis_types=`` kwarg of ``jax.make_mesh`` exist only on newer lines;
``jax.make_mesh`` itself is absent on very old ones).  Every mesh in this
repo — training, serving, tests — is built through :func:`make_mesh` so the
call sites stay pinned to one spelling and the test suite stops erroring on
whichever jax the container ships.

True-TPU-only features have no shim: code that genuinely needs them must
skip with a reason (see ``requires_axis_types``).
"""

from __future__ import annotations

import jax


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on jax lines that have it, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * n


def requires_axis_types() -> str | None:
    """Skip-reason string when explicit axis types are unavailable.

    Returns None when ``jax.sharding.AxisType`` exists; otherwise a message
    suitable for ``pytest.skip`` — used by tests that exercise the explicit
    Auto/Explicit sharding mode itself rather than merely building a mesh.
    """
    if getattr(jax.sharding, "AxisType", None) is None:
        return ("jax.sharding.AxisType not available on this jax "
                f"({jax.__version__}); explicit axis-type semantics "
                "need a newer release")
    return None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` across API drift; axis types are Auto when spellable.

    Order of attempts: new API with ``axis_types``, new API without, then
    the legacy ``jax.sharding.Mesh`` over ``mesh_utils.create_device_mesh``.
    """
    axis_shapes = tuple(int(s) for s in axis_shapes)
    axis_names = tuple(axis_names)
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        at = auto_axis_types(len(axis_names))
        if at is not None:
            try:
                return mk(axis_shapes, axis_names, axis_types=at, **kw)
            except TypeError:
                pass        # this jax.make_mesh predates axis_types=
        return mk(axis_shapes, axis_names, **kw)
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return jax.sharding.Mesh(devs, axis_names)
