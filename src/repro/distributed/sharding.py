"""Sharding rules: parameter / activation / cache PartitionSpecs per arch.

Strategy (GSPMD via jit in/out shardings):

* **FSDP + TP**: every weight matrix shards its feature axes over ``model``
  (TP) and, when the remaining axis is large, over ``data`` (ZeRO-3-style
  FSDP) — XLA inserts the all-gathers and overlaps them with the layer scan.
* **EP**: MoE expert tensors [L, E, d, f] shard E over ``model`` — expert
  parallelism; the dispatch scatter lowers to an all-to-all.
* **SP**: long-context activations shard the sequence axis over ``model``
  (norms/MLP are pointwise over tokens; attention gathers KV per chunk).
* **pod** joins the batch axes (pure DP across the DCN) unless pipeline
  mode assigns it to stages (repro.distributed.pipeline).

Rules are name-pattern based over the param pytree path — one table drives
all 10 architectures.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# (path regex, spec builder) — first match wins.  `dp` is the FSDP axis name
# tuple, `mp` the tensor axis name.  Layer-stacked leaves have a leading L
# axis (never sharded).
def _rules(dp, mp):
    return [
        # embeddings / unembed: vocab over model, feature over data
        (r"embed$",               P(mp, dp)),
        (r"unembed$",             P(dp, mp)),
        # MoE: experts over model (EP), expert-internal over data (FSDP)
        (r"moe/router$",          P(None, dp, None)),
        (r"moe/w[gud]$",          P(None, mp, dp, None)),
        (r"moe/shared/w[gud]$",   P(None, dp, mp)),
        # attention projections: [L, d, H*hd] -> feature over model
        (r"attn/w[qkv]$",         P(None, dp, mp)),
        (r"attn/wo$",             P(None, mp, dp)),
        (r"xattn/w[qkv]$",        P(None, dp, mp)),
        (r"xattn/wo$",            P(None, mp, dp)),
        # MLA factorizations
        (r"attn/wdq$",            P(None, dp, mp)),
        (r"attn/wuq$",            P(None, dp, mp)),
        (r"attn/wdkv$",           P(None, dp, mp)),
        (r"attn/wukv$",           P(None, dp, mp)),
        # SSM mixers
        (r"ssm/in_proj$",         P(None, dp, mp)),
        (r"ssm/out_proj$",        P(None, mp, dp)),
        (r"ssm/conv_w$",          P(None, None, mp)),
        (r"ssm/conv_b$",          P(None, mp)),
        # dense MLPs: [L, d, ff] / [L, ff, d]
        (r"mlp/w[gu]$",           P(None, dp, mp)),
        (r"mlp/wd$",              P(None, mp, dp)),
        (r"encoder/blocks/w[qkv]$", P(None, dp, mp)),
        (r"encoder/blocks/wo$",   P(None, mp, dp)),
        (r"encoder/blocks/mlp/w[gu]$", P(None, dp, mp)),
        (r"encoder/blocks/mlp/wd$", P(None, mp, dp)),
        (r"mtp_proj$",            P(dp, mp)),
        # norms / scales / biases: replicated
        (r".*",                   None),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def param_specs(params, mesh, *, fsdp: bool = True):
    """PartitionSpec pytree for a parameter pytree (pattern table above)."""
    dp = "data" if fsdp else None
    mp = "model"
    rules = [(re.compile(pat), spec) for pat, spec in _rules(dp, mp)]

    def assign(path, leaf):
        s = _path_str(path)
        for pat, spec in rules:
            if pat.search(s):
                if spec is None:
                    return P()
                # drop axes that don't divide the dim (small tensors)
                dims = list(spec)
                shape = leaf.shape
                fixed = []
                for i, ax in enumerate(dims[:len(shape)]):
                    if ax is None:
                        fixed.append(None)
                        continue
                    size = np.prod([mesh.shape[a] for a in
                                    (ax if isinstance(ax, tuple) else (ax,))])
                    fixed.append(ax if shape[i] % size == 0 else None)
                return P(*fixed)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def param_shardings(params, mesh, **kw):
    specs = param_specs(params, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, seq_sharded: bool = False) -> P:
    """[B, S] token sharding: batch over (pod+)data; seq over model (SP)."""
    from repro.launch.mesh import data_axes
    da = data_axes(mesh)
    da = da[0] if len(da) == 1 else da
    return P(da, "model" if seq_sharded else None)


def cache_specs(cache, mesh, seq_axis_sharded: bool = True):
    """KV-cache shardings for serving: batch over data when it divides,
    otherwise shard the sequence axis of the KV slabs over data
    (flash-decode layout for long-context, B=1 cells); heads/latent over
    model when divisible."""
    from repro.launch.mesh import data_axes
    da = data_axes(mesh)
    da = da[0] if len(da) == 1 else da
    dsize = np.prod([mesh.shape[a] for a in (da if isinstance(da, tuple)
                                             else (da,))])
    msize = mesh.shape["model"]

    def assign(path, leaf):
        s = _path_str(path)
        if s.endswith("step"):
            return P()
        shape = leaf.shape
        if "ssm" in s:
            # [L,B,...] state: batch over data if divisible
            return P(None, da) if shape[1] % dsize == 0 else P()
        # attention slabs [L, B, S, K, hd] or [L, B, S, latent]
        b_ok = shape[1] % dsize == 0
        spec = [None, da if b_ok else None, None]
        if len(shape) >= 4:
            heads_ok = shape[3] % msize == 0
            spec.append("model" if heads_ok else None)
            spec.extend([None] * (len(shape) - 4))
            if not heads_ok and seq_axis_sharded and shape[2] % msize == 0:
                spec[2] = "model"   # flash-decode: shard the sequence axis
        else:
            spec[2] = None
        if not b_ok and seq_axis_sharded and spec[2] is None \
                and shape[2] % dsize == 0:
            spec[2] = da            # B=1 long-context: seq over data
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache)
