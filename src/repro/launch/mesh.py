"""Production mesh construction (function, not module constant — importing
this module never touches jax device state).

All meshes go through :func:`repro.distributed.compat.make_mesh`, which
absorbs the ``jax.sharding.AxisType`` / ``axis_types=`` API drift across
jax releases.
"""

from __future__ import annotations

import jax

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (v5e-256) or 2x16x16 multi-pod mesh.

    Axes: ``pod`` spans the DCN link between pods (data-parallel by default,
    pipeline stages opt-in); ``data`` is batch/FSDP; ``model`` is
    tensor/expert parallel.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist — tests / CPU smoke runs."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_serving_mesh(num_shards: int):
    """1-D ``shard`` mesh over the first ``num_shards`` devices.

    The sharded query engine (``repro.sharding``) places one region-shard's
    bucket slabs per mesh device and routes batches by (shard, bucket).
    Raises when the runtime has fewer devices than shards — callers that
    want oversubscription (tests on a single CPU device) pass ``mesh=None``
    to the router, which round-robins shards onto the available devices.
    """
    devs = jax.devices()
    if num_shards > len(devs):
        raise ValueError(f"need {num_shards} devices for a serving mesh, "
                         f"runtime has {len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count="
                         f"{num_shards} for host smoke runs)")
    return make_mesh((num_shards,), ("shard",), devices=devs[:num_shards])


def shard_devices(mesh, num_shards: int) -> list:
    """Per-shard device placement: mesh devices, or round-robin fallback.

    With a mesh, shard ``k`` lives on ``mesh.devices.flat[k]`` (one shard
    per device, the production regime).  Without one, shards wrap onto
    whatever devices exist — same routing/merging code paths, so the whole
    subsystem is testable on a single CPU device.
    """
    if mesh is not None:
        devs = list(mesh.devices.flat)
        if len(devs) < num_shards:
            raise ValueError(f"mesh has {len(devs)} devices for "
                             f"{num_shards} shards")
        return devs[:num_shards]
    devs = jax.devices()
    return [devs[k % len(devs)] for k in range(num_shards)]


def data_axes(mesh) -> tuple:
    """The axes a global batch shards over (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
