"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (v5e-256) or 2x16x16 multi-pod mesh.

    Axes: ``pod`` spans the DCN link between pods (data-parallel by default,
    pipeline stages opt-in); ``data`` is batch/FSDP; ``model`` is
    tensor/expert parallel.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist — tests / CPU smoke runs."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    """The axes a global batch shards over (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
