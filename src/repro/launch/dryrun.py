import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 v5e chips
(the XLA_FLAGS line above MUST precede every other import — jax locks the
device count on first init).

Per cell we lower the real step function (train_step for train shapes,
forward for prefill, decode_step against a full-length cache for decode),
``.compile()`` it for the production mesh, and record:

* ``memory_analysis()``  — per-device bytes (proves it fits / flags OOM),
* ``cost_analysis()``    — HLO flops & bytes for the roofline terms,
* a collective-bytes breakdown parsed from the compiled HLO
  (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute are not in cost_analysis).

Artifacts land in benchmarks/dryrun_artifacts/*.json; benchmarks.roofline
and EXPERIMENTS.md consume them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, input_specs, ARCH_IDS
from repro.distributed.sharding import (batch_spec, cache_specs,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import LM_SHAPES, shape_applicable
from repro.obs.timing import Stopwatch
from repro.optim import adamw

ART_DIR = os.path.join(os.path.dirname(__file__),
                       "../../../benchmarks/dryrun_artifacts")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum data volume per collective kind from compiled HLO.

    Per instruction we take the max shape mentioned on the line (result for
    all-gather, operand for reduce-scatter — max covers both) and count it
    once; tuples contribute their largest member per element.
    """
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        kind = None
        for k in COLLECTIVES:
            if re.search(rf"= .*\b{k}(-start|-done)?\(", ls) or \
                    re.search(rf"^\S+ = \S+ {k}", ls):
                kind = k
                break
        if kind is None or f"{kind}-done" in ls:
            continue
        sizes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(
            ls.split("(", 1)[0])]
        if sizes:
            out[kind] += max(sizes)
            counts[kind] += 1
    return {"bytes": out, "counts": counts}


def build_step(cfg, shape, mesh):
    """Returns (fn, arg_specs (ShapeDtypeStructs), in_shardings)."""
    specs = input_specs(cfg, shape)
    dtype = jnp.bfloat16
    pshapes = jax.eval_shape(
        lambda k: T.init_params(cfg, k, dtype=dtype), jax.random.PRNGKey(0))
    pshard = param_shardings(pshapes, mesh)
    # batch sharding: drop axes the global batch doesn't divide (B=1 decode
    # replicates tokens; its KV cache shards the sequence axis instead)
    bs = batch_spec(mesh)
    da = bs[0] if bs else None
    ndata = int(np.prod([mesh.shape[a] for a in
                         (da if isinstance(da, tuple) else (da,))])) \
        if da else 1
    B = shape.global_batch
    bsh = NamedSharding(mesh, bs if B % ndata == 0 else P())
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        ocfg = adamw.AdamWConfig(moment_dtype=jnp.bfloat16)
        oshapes = jax.eval_shape(lambda p: adamw.init_state(p, ocfg), pshapes)
        oshard = jax.tree.map(
            lambda l, s: s, oshapes,
            {"step": repl,
             "m": pshard, "v": pshard})

        if cfg.encdec:
            def fn(params, opt_state, tokens, enc_frames):
                l, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, tokens,
                                        enc_frames=enc_frames))(params)
                params, opt_state, m = adamw.apply_updates(
                    params, g, opt_state, ocfg)
                return params, opt_state, l
            args = (pshapes, oshapes, specs["tokens"], specs["enc_frames"])
            in_sh = (pshard, oshard, bsh, bsh)
        else:
            def fn(params, opt_state, tokens):
                l, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, tokens))(params)
                params, opt_state, m = adamw.apply_updates(
                    params, g, opt_state, ocfg)
                return params, opt_state, l
            args = (pshapes, oshapes, specs["tokens"])
            in_sh = (pshard, oshard, bsh)
        return fn, args, in_sh

    if shape.kind == "prefill":
        if cfg.encdec:
            def fn(params, tokens, enc_frames):
                return T.prefill(cfg, params, tokens, enc_frames=enc_frames)
            return (fn, (pshapes, specs["tokens"], specs["enc_frames"]),
                    (pshard, bsh, bsh))

        def fn(params, tokens):
            return T.prefill(cfg, params, tokens)
        return fn, (pshapes, specs["tokens"]), (pshard, bsh)

    # decode: serve_step with a cache of seq_len positions.
    # Serving sharding: params TP-only (fsdp=False) when the TP shard fits —
    # FSDP'd weights are all-gathered in full on EVERY token step (measured
    # 25.8 GB/step on gemma3 decode_32k -> 1 MB with TP-only; §Perf 2b).
    # Past ~8 GB/device (nemotron-4: replication blew peak 47 -> 157 GiB)
    # the gather is the lesser evil and FSDP stays on.
    tp_bytes = cfg.param_count() * 2 / mesh.shape["model"]
    pshard = param_shardings(pshapes, mesh, fsdp=tp_bytes > 8e9)
    B, S = shape.global_batch, shape.seq_len
    if cfg.encdec:
        enc_out_shape = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                             dtype)
        cshapes = jax.eval_shape(
            lambda p, e: T.init_cache(cfg, B, S, dtype=dtype, enc_out=e,
                                      params=p), pshapes, enc_out_shape)
    else:
        cshapes = jax.eval_shape(
            lambda: T.init_cache(cfg, B, S, dtype=dtype))
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          cache_specs(cshapes, mesh),
                          is_leaf=lambda x: isinstance(x, P))

    def fn(params, cache, tokens):
        return T.decode_step(cfg, params, cache, tokens)

    return (fn, (pshapes, cshapes, specs["tokens"]),
            (pshard, cshard, bsh))


def run_cell(arch: str, shape, mesh_kind: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    runs, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_kind,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch}
    if not runs:
        rec.update(status="skipped", reason=reason)
        return rec

    from repro.distributed.hints import set_mesh_hints
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    set_mesh_hints(mesh)
    n_dev = mesh.devices.size
    sw = Stopwatch()
    try:
        with mesh:
            fn, args, in_sh = build_step(cfg, shape, mesh)
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)  # repolint: disable=jit-registry -- AOT dryrun compile, not a serving trace point
            t_lower = sw.lap()
            compiled = lowered.compile()
            t_compile = sw.lap()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            coll = collective_bytes(compiled.as_text())
        rec.update(
            status="ok",
            devices=n_dev,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            arg_bytes=mem.argument_size_in_bytes,
            out_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_device_bytes=(mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
            collectives=coll,
            model_params=cfg.param_count(),
            model_params_active=cfg.active_param_count(),
        )
    except Exception as e:   # noqa: BLE001 — record, don't die mid-matrix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape.name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = (LM_SHAPES if (args.all or args.shape is None)
              else [s for s in LM_SHAPES if s.name == args.shape])
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                path = os.path.join(args.out,
                                    f"{arch}__{shape.name}__{mk}.json")
                if args.skip_existing and os.path.exists(path):
                    rec = json.load(open(path))
                    if rec.get("status") in ("ok", "skipped"):
                        results.append(rec)
                        print(f"[cached] {arch} {shape.name} {mk}: "
                              f"{rec['status']}")
                        continue
                print(f"[dryrun] {arch} {shape.name} {mk} ...", flush=True)
                rec = run_cell(arch, shape, mk, args.out)
                results.append(rec)
                msg = rec["status"]
                if rec["status"] == "ok":
                    msg += (f" flops={rec['flops']:.3g} "
                            f"peak={rec['peak_device_bytes'] / 2**30:.2f}GiB "
                            f"compile={rec['compile_s']}s")
                elif rec["status"] == "error":
                    msg += " " + rec["error"][:200]
                print(f"[dryrun] {arch} {shape.name} {mk}: {msg}", flush=True)
    bad = [r for r in results if r["status"] == "error"]
    print(f"\n{len(results)} cells: {len(results) - len(bad)} ok/skipped, "
          f"{len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
