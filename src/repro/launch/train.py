"""Training driver: sharded init -> jit train step -> guarded loop.

Runs the real thing on any mesh: ``--mesh host`` trains a reduced config on
the local devices (CI / examples); on a pod the same code takes the
production mesh.  Fault tolerance: periodic checkpoints + StepGuard
restore/replay; ``--fault-inject N`` kills step N once to exercise the path.

Usage (CPU example, also examples/train_lm.py):
    python -m repro.launch.train --arch tinyllama-1.1b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.distributed.fault import SimulatedFault, StepGuard
from repro.distributed.sharding import batch_spec, param_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.obs.timing import Stopwatch
from repro.optim import adamw
from repro.optim.compression import compress_psum_tree, init_residuals


def build_train_step(cfg, ocfg, mesh, *, grad_compress: bool = False):
    """Returns jitted (params, opt_state, batch) -> (params, opt_state,
    metrics).  Gradient compression wraps the DP all-reduce in shard_map."""

    def loss(params, batch):
        return T.loss_fn(cfg, params, batch)

    if not grad_compress:
        def step(params, opt_state, batch):
            l, grads = jax.value_and_grad(loss)(params, batch)
            params, opt_state, m = adamw.apply_updates(
                params, grads, opt_state, ocfg)
            m["loss"] = l
            return params, opt_state, m
        return step

    from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import data_axes
    da = data_axes(mesh)

    def step(params, opt_state, batch):
        # per-DP-shard grads, then error-feedback int8 all-reduce
        def local_grads(params, batch):
            l, g = jax.value_and_grad(loss)(params, batch)
            return l, g

        l, grads = local_grads(params, batch)   # jit/GSPMD grads (already
        # mean over batch); compression path quantizes the DP psum of the
        # *per-shard* grads — modeled in shard_map for the collective:
        residuals = opt_state.setdefault("residuals",
                                         init_residuals(grads))
        def comm(g, r):
            return compress_psum_tree(g, r, da)
        gspec = jax.tree.map(lambda _: P(), grads)
        comp = shard_map(comm, mesh=mesh, in_specs=(gspec, gspec),
                         out_specs=(gspec, gspec))
        grads, opt_state["residuals"] = comp(grads, residuals)
        params, opt_state, m = adamw.apply_updates(
            params, grads, opt_state, ocfg)
        m["loss"] = l
        return params, opt_state, m

    return step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fault-inject", type=int, default=-1)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32

    mesh = {"host": make_host_mesh,
            "pod": make_production_mesh,
            "multipod": partial(make_production_mesh, multi_pod=True)}[
        args.mesh]()
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                             total_steps=args.steps)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)

    with mesh:
        pshapes = jax.eval_shape(
            lambda k: T.init_params(cfg, k, dtype=dtype),
            jax.random.PRNGKey(0))
        pshard = param_shardings(pshapes, mesh)
        # repolint: disable=jit-registry -- training launcher, outside the serving taxonomy
        init = jax.jit(lambda k: T.init_params(cfg, k, dtype=dtype),
                       out_shardings=pshard)
        params = init(jax.random.PRNGKey(0))
        opt_state = adamw.init_state(params, ocfg)
        bspec = NamedSharding(mesh, batch_spec(mesh))
        step_fn = build_train_step(cfg, ocfg, mesh,
                                   grad_compress=args.grad_compress)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))  # repolint: disable=jit-registry -- training step, outside the serving taxonomy

        start = 0
        if args.resume:
            last = store.latest_step(args.ckpt_dir)
            if last is not None:
                state = store.restore(args.ckpt_dir, last,
                                      {"p": params, "o": opt_state},
                                      mesh=mesh)
                params, opt_state = state["p"], state["o"]
                start = last
                print(f"resumed from step {start}")

        guard = StepGuard(args.ckpt_dir, args.ckpt_every)
        faults_left = {"n": 1 if args.fault_inject >= 0 else 0}

        def one_step(carry, step):
            params, opt_state = carry
            if faults_left["n"] and step == args.fault_inject:
                faults_left["n"] -= 1
                raise SimulatedFault(f"injected at step {step}")
            batch = jax.device_put(synthetic_batch(dcfg, step), bspec)
            params, opt_state, m = jstep(params, opt_state, batch)
            return (params, opt_state), m

        def restore_fn():
            last = store.latest_step(args.ckpt_dir)
            if last is None:
                return (params, opt_state)
            st = store.restore(args.ckpt_dir, last,
                               {"p": params, "o": opt_state}, mesh=mesh)
            print(f"  [guard] restored step {last}")
            return (st["p"], st["o"])

        carry = (params, opt_state)
        sw = Stopwatch()
        for step in range(start, args.steps):
            sw.lap()
            carry, m = guard.run(one_step, carry, step, restore_fn)
            if step % args.ckpt_every == 0 or step == args.steps - 1:
                store.save(args.ckpt_dir, step,
                           {"p": carry[0], "o": carry[1]})
            print(f"step {step:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                  f"{sw.lap():.2f}s")
        return float(m["loss"])


if __name__ == "__main__":
    main()
