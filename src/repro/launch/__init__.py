from .mesh import make_host_mesh, make_production_mesh  # noqa: F401

__all__ = ["make_host_mesh", "make_production_mesh",
           "build_step", "collective_bytes"]


def __getattr__(name):
    # dryrun forces XLA_FLAGS to 512 host devices at import time (it
    # must precede jax init), so it may only load when actually asked
    # for — importing repro.launch must never change the device count.
    if name in ("build_step", "collective_bytes"):
        from . import dryrun
        return getattr(dryrun, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
