"""Adaptive index lifecycle: live workload capture -> budgeted recompression
-> zero-downtime hot-swap (DESIGN.md §8).

The paper's workload-aware EHL* assumes the query distribution is known
offline; this subsystem discovers it from live traffic and keeps the serving
artifact continuously re-optimized under a device-byte budget:

* :class:`WorkloadRecorder` — decayed per-cell endpoint histogram (bounded
  memory, O(1) per query) that ``PathServer`` feeds;
* :class:`BudgetPlanner`   — drift detection + incremental-vs-replan policy
  over ``core.compression``'s resumable merge loop;
* :class:`SwappableEngine` — generation-counted double-buffered engine
  indirection (in-flight requests drain on the old artifact);
* :class:`IndexManager`    — orchestration: build off the serving path,
  probe-set validation, atomic swap.
"""

from .recorder import WorkloadRecorder                      # noqa: F401
from .planner import BudgetPlanner, PlanDecision            # noqa: F401
from .swap import SwappableEngine                           # noqa: F401
from .manager import (IndexManager, SwapRecord,             # noqa: F401
                      engine_answers)
