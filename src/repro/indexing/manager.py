"""Adaptive index lifecycle — traffic in, re-optimized artifact out.

:class:`IndexManager` owns the closed loop the rest of the subsystem plugs
into:

1. **capture** — ``PathServer`` feeds every answered query into the
   manager's :class:`~repro.indexing.recorder.WorkloadRecorder`;
2. **plan** — :meth:`maybe_adapt` asks the
   :class:`~repro.indexing.planner.BudgetPlanner` whether the recorded
   distribution / budget warrants recompression (incremental resume or
   replan-from-snapshot, see planner docs);
3. **build** — the host-side merge loop + repack run *off* the serving path
   (inline or on a background thread), reusing the device-resident edge
   tensors (``pack_bucketed(reuse_edges_from=...)``) and the per-region
   pack caches;
4. **validate** — the candidate artifact answers a fixed probe query set
   and must match the live artifact (compression preserves optimality, so
   any disagreement beyond float tolerance aborts the swap);
5. **swap** — the candidate's jit entries are warmed at the serving batch
   shape, then :class:`~repro.indexing.swap.SwappableEngine` publishes it
   atomically; in-flight requests drain on the old artifact before its
   device buffers drop.

The budget is a device-byte budget on the packed artifact — what serving
actually allocates — and is enforced on every candidate before it goes live.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro import obs
from repro.core.grid import EHLIndex
from repro.obs.locks import make_lock
from repro.core.packed import pack_bucketed
from repro.serving.query_engine import make_engine

from .planner import BudgetPlanner, PlanDecision
from .recorder import WorkloadRecorder
from .swap import SwappableEngine


def engine_answers(engine, s: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Route a batch through any ``QueryEngine`` with exact shapes.

    Engines with a full-pipeline ``query`` (the sharded engine) use it;
    otherwise the batch is grouped by ``buckets_of`` and dispatched per
    routing key — the same calls ``query_batch_bucketed`` makes for a
    device engine, so probe validation stays bitwise-comparable across
    engine kinds and generations.
    """
    fn = getattr(engine, "query", None)
    if fn is not None:
        return np.asarray(fn(s, t))
    keys = engine.buckets_of(s, t)
    out = np.empty(len(s), np.float32)
    for k in np.unique(keys):
        m = keys == k
        out[m] = np.asarray(engine.batch(s[m], t[m], bucket=int(k)))
    return out


@dataclasses.dataclass
class SwapRecord:
    """One adaptation attempt (successful swap or aborted candidate)."""
    generation: int         # generation the attempt produced (or would have)
    kind: str               # planner decision kind
    drift: float
    reason: str
    merges: int
    regions: int
    label_bytes: int
    device_bytes: int
    build_s: float          # recompression (host merge loop)
    pack_s: float           # repack + engine warmup
    validate_s: float
    probe_max_err: float
    swapped: bool
    abort_reason: str = ""  # non-empty iff the candidate was rejected


class IndexManager:
    """Budgeted, self-adapting index behind a hot-swappable engine.

    ``index``: the freshly built (uncompressed) host ``EHLIndex`` — the
    manager snapshots its singleton region set as the replan base, performs
    the initial budget fit with uniform scores, and packs the first serving
    artifact.  Wire ``manager.engine`` and ``manager.recorder`` into a
    ``PathServer`` and call :meth:`maybe_adapt` between serving rounds (or
    with ``block=False`` to build/validate/swap on a background thread).
    """

    def __init__(self, index: EHLIndex, device_budget_bytes: int,
                 backend: str = "jnp", lane: int = 128, alpha: float = 0.2,
                 batch_size: int = 256, probe=None, probe_n: int = 64,
                 validate_tol: float = 1e-4, min_queries: int = 256,
                 replan_threshold: float = 0.15,
                 exit_threshold: float | None = None, min_dwell: int = 2,
                 halflife: float = 4000.0, warm_argmin: bool = False,
                 num_shards: int = 0, mesh=None, shard_tol: float = 1.15,
                 seed: int = 0, layout=None, telemetry=None):
        if backend not in ("jnp", "pallas"):
            raise ValueError("IndexManager serves packed artifacts; "
                             f"backend must be jnp|pallas, got {backend!r}")
        from repro.core.compression import compress_to_device_budget
        from repro.core.packed import (LAYOUT_F32, bucketed_device_bytes,
                                       slab_layout)

        self.host_index = index
        self._base = index.snapshot_regions()
        # lifecycle event sink (DESIGN.md §12): drift decisions, swaps /
        # aborts and quantization loud-fallbacks all land here.  Share one
        # Telemetry with the PathServer so serving + indexing events
        # interleave in a single JSONL stream.
        self.telemetry = obs.Telemetry() if telemetry is None else telemetry
        self.backend = backend
        self.lane = lane
        self.batch_size = batch_size
        self.validate_tol = float(validate_tol)
        self.warm_argmin = warm_argmin
        # slab layout ("f32" | "bf16" | "f16" | SlabLayout): quantized
        # layouts shrink the per-slot cost, so the same device budget admits
        # a finer region partition — every candidate of this manager's
        # lifetime packs (and is budget-measured) under this layout
        if isinstance(layout, str):
            layout = slab_layout(layout)
        self.layout = layout if layout is not None else LAYOUT_F32
        # sharded serving (repro.sharding): the budget stays a *total*
        # device-byte budget; each shard replicates the mapper + edge
        # tensors, so the compressible slab budget shrinks by that overhead
        # and candidates are additionally held to a per-device cap
        self.num_shards = int(num_shards)
        self.mesh = mesh
        self.shard_tol = float(shard_tol)
        self._shard_planner = None
        overhead = 0
        if self.num_shards > 1:
            from repro.sharding import ShardPlanner, sharded_overhead_bytes
            self._shard_planner = ShardPlanner(self.num_shards, lane=lane,
                                               tol=shard_tol,
                                               layout=self.layout)
            overhead = sharded_overhead_bytes(index, self.num_shards, lane,
                                              layout=self.layout)
            if overhead >= device_budget_bytes:
                raise ValueError(
                    f"device budget {device_budget_bytes}B is infeasible "
                    f"for {self.num_shards} shards: replicated mapper + "
                    f"edge tensors alone cost {overhead}B")
        self._shard_overhead = overhead
        slab_budget = device_budget_bytes - overhead
        self.recorder = WorkloadRecorder.for_index(index, halflife=halflife)
        self.planner = BudgetPlanner(slab_budget, alpha=alpha,
                                     min_queries=min_queries,
                                     replan_threshold=replan_threshold,
                                     exit_threshold=exit_threshold,
                                     min_dwell=min_dwell, lane=lane,
                                     layout=self.layout)
        # planner decision/execution records join the same structured
        # event stream as swaps and drift (DESIGN.md §13)
        self.planner.events = self.telemetry.events
        # initial fit: uniform scores (no traffic observed yet)
        if bucketed_device_bytes(index, lane,
                                 layout=self.layout) > slab_budget:
            compress_to_device_budget(index, slab_budget, lane=lane,
                                      layout=self.layout)
        art0 = self._pack()
        if art0.device_bytes() > device_budget_bytes:
            raise ValueError(
                f"device budget {device_budget_bytes}B is infeasible: after "
                f"budget-driven merging the artifact still needs "
                f"{art0.device_bytes()}B (mapper + edge tensors are a fixed "
                "floor no amount of merging removes)")
        self.engine = SwappableEngine(self._make_engine(art0))
        if probe is not None:
            self._probe_s = np.asarray(probe[0], np.float32)
            self._probe_t = np.asarray(probe[1], np.float32)
        else:
            from repro.core.geometry import random_free_points
            rng = np.random.default_rng(seed)
            pts = random_free_points(index.scene, 2 * probe_n, rng)
            self._probe_s = pts[:probe_n].astype(np.float32)
            self._probe_t = pts[probe_n:].astype(np.float32)
        self.history: list[SwapRecord] = []
        self.validation_failures = 0
        self._thread: threading.Thread | None = None
        self._adapt_lock = make_lock("indexing.adapt")

    # ------------------------------------------------------------- queries
    @property
    def generation(self) -> int:
        return self.engine.generation

    @property
    def swaps(self) -> int:
        return self.engine.swaps

    def device_bytes(self) -> int:
        return self.engine.device_bytes()

    def device_budget_bytes(self) -> int:
        """Total budget (slab budget + per-shard replication overhead)."""
        return self.planner.device_budget_bytes + self._shard_overhead

    def set_budget(self, device_budget_bytes: int) -> None:
        self.planner.set_budget(device_budget_bytes - self._shard_overhead)

    def probe_set(self) -> tuple[np.ndarray, np.ndarray]:
        """The fixed probe queries swap validation runs against."""
        return self._probe_s, self._probe_t

    def probe_answers(self) -> np.ndarray:
        """Current live engine's answers on the probe set."""
        return engine_answers(self.engine.current,
                              self._probe_s, self._probe_t)

    # ------------------------------------------------------------- packing
    def _pack(self, reuse_from=None):
        """Freeze host_index into the serving artifact (sharded or not)."""
        if self._shard_planner is not None:
            return self._shard_planner.build(self.host_index,
                                             reuse_edges_from=reuse_from)
        return pack_bucketed(self.host_index, lane=self.lane,
                             reuse_edges_from=reuse_from, layout=self.layout)

    @staticmethod
    def _qerr_of(artifact) -> float:
        """Worst-case per-label quantization error of a packed artifact."""
        shards = getattr(artifact, "shards", None) or (artifact,)
        return max((float(np.asarray(bx.qerr)) if bx.qerr is not None
                    else 0.0) for bx in shards)

    def _make_engine(self, artifact):
        if self._shard_planner is not None:
            from repro.sharding import ShardedQueryEngine
            eng = ShardedQueryEngine(artifact, mesh=self.mesh,
                                     use_kernels=self.backend == "pallas")
            eng.bind_telemetry(self.telemetry)
            return eng
        return make_engine(artifact, backend=self.backend)

    def _emit_quant_fallbacks(self, artifact, generation: int) -> None:
        """Loud-fallback events: any bucket whose slab could not take the
        quantized encoding (and silently pays f32/i32 widths) is a
        capacity/accuracy signal the operator should see."""
        if not self.layout.quantized:
            return
        for shard, bx in enumerate(getattr(artifact, "shards", None)
                                   or (artifact,)):
            qs = bx.quant_stats()
            falls = {k: [i for i, f in enumerate(qs.get(k, ())) if f]
                     for k in ("id_fallback", "vid_fallback",
                               "dist_fallback")}
            falls = {k: v for k, v in falls.items() if v}
            if falls:
                self.telemetry.events.emit(
                    "quant_fallback", generation=generation, shard=shard,
                    qerr=qs["qerr"], **falls)

    # ------------------------------------------------------------ adaptation
    def maybe_adapt(self, block: bool = True) -> bool:
        """One adaptation step; True iff a swap was published (blocking mode).

        ``block=False`` runs build/validate/swap on a background thread and
        returns immediately (False); poll :attr:`swaps` / call :meth:`join`.
        A build already in flight makes this a no-op.
        """
        if self._thread is not None and self._thread.is_alive():
            return False
        # one stopwatch carries the whole attempt (DESIGN.md §13): every
        # stage boundary is a lap() on it, so the BUILD_STAGES spans
        # telescope to end-to-end exactly — including the thread handoff
        # of an async build, which lands inside the "compress" lap
        sw = obs.Stopwatch()
        decision = self.planner.decide(self.recorder, self.host_index)
        plan_s = sw.lap()
        if decision.kind == "skip":
            return False
        trace = obs.Trace(kind="build", decision=decision.kind,
                          drift=decision.drift,
                          async_build=not block)
        trace.stage("plan", plan_s)
        self.telemetry.events.emit("drift", decision=decision.kind,
                                   drift=decision.drift,
                                   reason=decision.reason,
                                   recorded_queries=self.recorder.queries)
        if block:
            return self._adapt(decision, trace, sw)
        self._thread = threading.Thread(target=self._adapt,
                                        args=(decision, trace, sw),
                                        name="index-manager-adapt",
                                        daemon=True)
        self._thread.start()
        return False

    def join(self, timeout: float | None = None) -> None:
        """Wait for a background adaptation to finish."""
        if self._thread is not None:
            self._thread.join(timeout)

    def _close_build_trace(self, trace, sw, outcome: str) -> None:
        """Publish one attempt's span tree + per-stage histograms."""
        # sw.t0 is the timestamp of the last lap, so stage_sum == e2e
        # bit-for-bit; the stopwatch's construction time is the root start
        trace.close(trace.attrs.pop("t_start"), sw.t0, outcome)
        reg = self.telemetry.registry
        for name, seconds in trace.stages.items():
            reg.histogram("build_stage_ms", stage=name).record(seconds * 1e3)
        reg.counter("builds_total", outcome=outcome).inc()
        if self.telemetry.enabled:
            self.telemetry.spans.add(trace)

    def _adapt(self, decision: PlanDecision, trace=None, sw=None) -> bool:
        if sw is None:                  # direct call (tests): self-rooted
            sw = obs.Stopwatch()
            trace = obs.Trace(kind="build", decision=decision.kind,
                              drift=decision.drift, async_build=False)
            trace.stage("plan", 0.0)
        trace.attrs["t_start"] = sw.t0 - sum(trace.stages.values())
        with self._adapt_lock:          # one rebuild at a time
            # pre-adapt snapshot: an aborted candidate must not leave
            # host_index (the unwinding mirror of the live artifact) or the
            # planner baseline describing an index that never went live
            pre = self.host_index.snapshot_regions()
            trace.attrs["device_bytes_in"] = self.engine.device_bytes()
            stats = self.planner.execute(decision, self.host_index,
                                         self.recorder, self._base)
            build_s = sw.lap()
            trace.stage("compress", build_s)

            reuse = self.engine.artifact
            if self._shard_planner is not None:
                # alias the *device-placed* per-shard edge tensors (the
                # router's copies), so the new generation's device_put is a
                # no-op for them — the host-side ShardedIndex copies would
                # be re-uploaded to every non-default device each swap
                router = getattr(self.engine.current, "router", None)
                if router is not None:
                    reuse = router.shards
            bx = self._pack(reuse_from=reuse)
            candidate = self._make_engine(bx)
            repack_s = sw.lap()
            trace.stage("repack", repack_s)

            d_live = engine_answers(self.engine.current,
                                    self._probe_s, self._probe_t)
            d_cand = engine_answers(candidate, self._probe_s, self._probe_t)
            both_inf = ~np.isfinite(d_live) & ~np.isfinite(d_cand)
            # np.max, not nanmax: a NaN-vs-finite disagreement must
            # propagate into max_err and abort, not be skipped over
            err = np.abs(np.where(both_inf, 0.0, d_cand - d_live))
            max_err = float(np.max(err)) if err.size else 0.0
            # quantized layouts: each generation's reported distance sits
            # within 2*qerr of the exact answer (one bound per endpoint
            # side), so two exact-equal generations may still disagree by
            # the sum of their bounds — widen the tolerance accordingly
            tol = self.validate_tol
            if self.layout.quantized:
                tol += 2.0 * (self._qerr_of(self.engine.artifact)
                              + self._qerr_of(bx))
            ok = bool(np.isfinite(max_err)) and max_err <= tol
            abort = "" if ok else (f"probe mismatch {max_err:.3e} > "
                                   f"{tol:.1e}")
            # the documented guarantee: no over-budget candidate goes live
            budget = self.device_budget_bytes()
            if ok and bx.device_bytes() > budget:
                ok = False
                abort = (f"candidate {bx.device_bytes()}B over device "
                         f"budget {budget}B")
            if ok and self._shard_planner is not None:
                # per-device cap: no shard may exceed its fair share of the
                # total budget by more than the balance tolerance
                cap = self.shard_tol * budget / self.num_shards
                worst = max(bx.per_shard_bytes())
                if worst > cap:
                    ok = False
                    abort = (f"shard imbalance: max shard {worst}B over "
                             f"per-device cap {cap:.0f}B "
                             f"({self.shard_tol:.2f}x budget/"
                             f"{self.num_shards})")
            validate_s = sw.lap()
            trace.stage("validate", validate_s)

            stage_s = 0.0
            if ok:
                # warm the candidate's jit entries off the serving path so
                # the first post-swap batch pays zero compile time — only
                # survivors pay it; an aborted candidate is dropped cold
                candidate.warmup(self.batch_size,
                                 want_argmin=self.warm_argmin)
                stage_s = sw.lap()
            trace.stage("stage", stage_s)

            rec = SwapRecord(
                generation=self.engine.generation + 1, kind=decision.kind,
                drift=decision.drift, reason=decision.reason,
                merges=stats.merges, regions=stats.regions,
                label_bytes=stats.final_bytes,
                device_bytes=bx.device_bytes(), build_s=build_s,
                pack_s=repack_s + stage_s, validate_s=validate_s,
                probe_max_err=max_err, swapped=ok, abort_reason=abort)
            self.history.append(rec)
            self.telemetry.events.emit(
                "swap" if ok else "swap_abort",
                **{("decision" if f.name == "kind" else f.name):
                   getattr(rec, f.name)
                   for f in dataclasses.fields(rec)})
            trace.attrs.update(
                generation=rec.generation, merges=stats.merges,
                regions_out=stats.regions,
                regions_in=stats.regions + stats.merges,
                label_bytes=stats.final_bytes,
                device_bytes_out=bx.device_bytes())
            if not ok:
                self.validation_failures += 1
                self.planner.discard()
                self.host_index.restore_regions(pre)    # roll back mirror
                trace.stage("swap", sw.lap())
                self._close_build_trace(trace, sw, "abort")
                return False
            self._emit_quant_fallbacks(bx, rec.generation)
            # validation traffic must not leak into the live serving stats
            reset = getattr(candidate, "reset_serve_counters", None)
            if reset is not None:
                reset()
            self.engine.swap(candidate)
            self.planner.commit()
            trace.stage("swap", sw.lap())
            self._close_build_trace(trace, sw, "ok")
            return True

    def stats(self) -> dict:
        """Lifecycle summary for logs / benches."""
        out = dict(generation=self.generation, swaps=self.swaps,
                   drops=self.engine.drops,
                   retired_pending=len(self.engine.retired_generations()),
                   validation_failures=self.validation_failures,
                   recorded_queries=self.recorder.queries,
                   device_bytes=self.device_bytes(),
                   device_budget_bytes=self.device_budget_bytes(),
                   attempts=len(self.history))
        if self._shard_planner is not None:
            out.update(num_shards=self.num_shards,
                       per_shard_bytes=self.engine.per_shard_bytes(),
                       shard_imbalance=round(self.engine.imbalance(), 4))
        return out
