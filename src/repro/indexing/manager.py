"""Adaptive index lifecycle — traffic in, re-optimized artifact out.

:class:`IndexManager` owns the closed loop the rest of the subsystem plugs
into:

1. **capture** — ``PathServer`` feeds every answered query into the
   manager's :class:`~repro.indexing.recorder.WorkloadRecorder`;
2. **plan** — :meth:`maybe_adapt` asks the
   :class:`~repro.indexing.planner.BudgetPlanner` whether the recorded
   distribution / budget warrants recompression (incremental resume or
   replan-from-snapshot, see planner docs);
3. **build** — the host-side merge loop + repack run *off* the serving path
   (inline or on a background thread), reusing the device-resident edge
   tensors (``pack_bucketed(reuse_edges_from=...)``) and the per-region
   pack caches;
4. **validate** — the candidate artifact answers a fixed probe query set
   and must match the live artifact (compression preserves optimality, so
   any disagreement beyond float tolerance aborts the swap);
5. **swap** — the candidate's jit entries are warmed at the serving batch
   shape, then :class:`~repro.indexing.swap.SwappableEngine` publishes it
   atomically; in-flight requests drain on the old artifact before its
   device buffers drop.

The budget is a device-byte budget on the packed artifact — what serving
actually allocates — and is enforced on every candidate before it goes live.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core.grid import EHLIndex
from repro.core.packed import pack_bucketed, query_batch_bucketed
from repro.serving.query_engine import make_engine

from .planner import BudgetPlanner, PlanDecision
from .recorder import WorkloadRecorder
from .swap import SwappableEngine


@dataclasses.dataclass
class SwapRecord:
    """One adaptation attempt (successful swap or aborted candidate)."""
    generation: int         # generation the attempt produced (or would have)
    kind: str               # planner decision kind
    drift: float
    reason: str
    merges: int
    regions: int
    label_bytes: int
    device_bytes: int
    build_s: float          # recompression (host merge loop)
    pack_s: float           # repack + engine warmup
    validate_s: float
    probe_max_err: float
    swapped: bool
    abort_reason: str = ""  # non-empty iff the candidate was rejected


class IndexManager:
    """Budgeted, self-adapting index behind a hot-swappable engine.

    ``index``: the freshly built (uncompressed) host ``EHLIndex`` — the
    manager snapshots its singleton region set as the replan base, performs
    the initial budget fit with uniform scores, and packs the first serving
    artifact.  Wire ``manager.engine`` and ``manager.recorder`` into a
    ``PathServer`` and call :meth:`maybe_adapt` between serving rounds (or
    with ``block=False`` to build/validate/swap on a background thread).
    """

    def __init__(self, index: EHLIndex, device_budget_bytes: int,
                 backend: str = "jnp", lane: int = 128, alpha: float = 0.2,
                 batch_size: int = 256, probe=None, probe_n: int = 64,
                 validate_tol: float = 1e-4, min_queries: int = 256,
                 replan_threshold: float = 0.15, halflife: float = 4000.0,
                 warm_argmin: bool = False, seed: int = 0):
        if backend not in ("jnp", "pallas"):
            raise ValueError("IndexManager serves packed artifacts; "
                             f"backend must be jnp|pallas, got {backend!r}")
        from repro.core.compression import compress_to_device_budget
        from repro.core.packed import bucketed_device_bytes

        self.host_index = index
        self._base = index.snapshot_regions()
        self.backend = backend
        self.lane = lane
        self.batch_size = batch_size
        self.validate_tol = float(validate_tol)
        self.warm_argmin = warm_argmin
        self.recorder = WorkloadRecorder.for_index(index, halflife=halflife)
        self.planner = BudgetPlanner(device_budget_bytes, alpha=alpha,
                                     min_queries=min_queries,
                                     replan_threshold=replan_threshold,
                                     lane=lane)
        # initial fit: uniform scores (no traffic observed yet)
        if bucketed_device_bytes(index, lane) > device_budget_bytes:
            compress_to_device_budget(index, device_budget_bytes, lane=lane)
        bx0 = pack_bucketed(index, lane=lane)
        if bx0.device_bytes() > device_budget_bytes:
            raise ValueError(
                f"device budget {device_budget_bytes}B is infeasible: after "
                f"budget-driven merging the artifact still needs "
                f"{bx0.device_bytes()}B (mapper + edge tensors are a fixed "
                "floor no amount of merging removes)")
        self.engine = SwappableEngine(make_engine(bx0, backend=backend))
        if probe is not None:
            self._probe_s = np.asarray(probe[0], np.float32)
            self._probe_t = np.asarray(probe[1], np.float32)
        else:
            from repro.core.geometry import random_free_points
            rng = np.random.default_rng(seed)
            pts = random_free_points(index.scene, 2 * probe_n, rng)
            self._probe_s = pts[:probe_n].astype(np.float32)
            self._probe_t = pts[probe_n:].astype(np.float32)
        self.history: list[SwapRecord] = []
        self.validation_failures = 0
        self._thread: threading.Thread | None = None
        self._adapt_lock = threading.Lock()

    # ------------------------------------------------------------- queries
    @property
    def generation(self) -> int:
        return self.engine.generation

    @property
    def swaps(self) -> int:
        return self.engine.swaps

    def device_bytes(self) -> int:
        return self.engine.device_bytes()

    def device_budget_bytes(self) -> int:
        return self.planner.device_budget_bytes

    def set_budget(self, device_budget_bytes: int) -> None:
        self.planner.set_budget(device_budget_bytes)

    def probe_set(self) -> tuple[np.ndarray, np.ndarray]:
        """The fixed probe queries swap validation runs against."""
        return self._probe_s, self._probe_t

    def probe_answers(self) -> np.ndarray:
        """Current live artifact's answers on the probe set."""
        return self._answers(self.engine.artifact)

    def _answers(self, artifact) -> np.ndarray:
        return np.asarray(query_batch_bucketed(
            artifact, self._probe_s, self._probe_t,
            use_kernels=self.engine.use_kernels))

    # ------------------------------------------------------------ adaptation
    def maybe_adapt(self, block: bool = True) -> bool:
        """One adaptation step; True iff a swap was published (blocking mode).

        ``block=False`` runs build/validate/swap on a background thread and
        returns immediately (False); poll :attr:`swaps` / call :meth:`join`.
        A build already in flight makes this a no-op.
        """
        if self._thread is not None and self._thread.is_alive():
            return False
        decision = self.planner.decide(self.recorder, self.host_index)
        if decision.kind == "skip":
            return False
        if block:
            return self._adapt(decision)
        self._thread = threading.Thread(target=self._adapt, args=(decision,),
                                        name="index-manager-adapt",
                                        daemon=True)
        self._thread.start()
        return False

    def join(self, timeout: float | None = None) -> None:
        """Wait for a background adaptation to finish."""
        if self._thread is not None:
            self._thread.join(timeout)

    def _adapt(self, decision: PlanDecision) -> bool:
        with self._adapt_lock:          # one rebuild at a time
            # pre-adapt snapshot: an aborted candidate must not leave
            # host_index (the unwinding mirror of the live artifact) or the
            # planner baseline describing an index that never went live
            pre = self.host_index.snapshot_regions()
            t0 = time.perf_counter()
            stats = self.planner.execute(decision, self.host_index,
                                         self.recorder, self._base)
            build_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            bx = pack_bucketed(self.host_index, lane=self.lane,
                               reuse_edges_from=self.engine.artifact)
            candidate = make_engine(bx, backend=self.backend)
            # warm the candidate's jit entries off the serving path so the
            # first post-swap batch pays zero compile time
            candidate.warmup(self.batch_size, want_argmin=self.warm_argmin)
            pack_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            d_live = self._answers(self.engine.artifact)
            d_cand = self._answers(bx)
            both_inf = ~np.isfinite(d_live) & ~np.isfinite(d_cand)
            # np.max, not nanmax: a NaN-vs-finite disagreement must
            # propagate into max_err and abort, not be skipped over
            err = np.abs(np.where(both_inf, 0.0, d_cand - d_live))
            max_err = float(np.max(err)) if err.size else 0.0
            ok = bool(np.isfinite(max_err)) and max_err <= self.validate_tol
            abort = "" if ok else (f"probe mismatch {max_err:.3e} > "
                                   f"{self.validate_tol:.1e}")
            # the documented guarantee: no over-budget candidate goes live
            budget = self.planner.device_budget_bytes
            if ok and bx.device_bytes() > budget:
                ok = False
                abort = (f"candidate {bx.device_bytes()}B over device "
                         f"budget {budget}B")
            validate_s = time.perf_counter() - t0

            rec = SwapRecord(
                generation=self.engine.generation + 1, kind=decision.kind,
                drift=decision.drift, reason=decision.reason,
                merges=stats.merges, regions=stats.regions,
                label_bytes=stats.final_bytes,
                device_bytes=bx.device_bytes(), build_s=build_s,
                pack_s=pack_s, validate_s=validate_s,
                probe_max_err=max_err, swapped=ok, abort_reason=abort)
            self.history.append(rec)
            if not ok:
                self.validation_failures += 1
                self.planner.discard()
                self.host_index.restore_regions(pre)    # roll back mirror
                return False
            self.engine.swap(candidate)
            self.planner.commit()
            return True

    def stats(self) -> dict:
        """Lifecycle summary for logs / benches."""
        return dict(generation=self.generation, swaps=self.swaps,
                    drops=self.engine.drops,
                    retired_pending=len(self.engine.retired_generations()),
                    validation_failures=self.validation_failures,
                    recorded_queries=self.recorder.queries,
                    device_bytes=self.device_bytes(),
                    device_budget_bytes=self.planner.device_budget_bytes,
                    attempts=len(self.history))
