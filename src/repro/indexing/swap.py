"""Zero-downtime engine hot-swap — generation-counted double buffering.

:class:`SwappableEngine` is a :class:`~repro.serving.query_engine.QueryEngine`
that delegates to a *current* engine and can atomically replace it while
requests are in flight:

* ``pin()`` (used by ``PathServer._dispatch``) hands out the current
  (generation, engine) pair under a lock and refcounts it — every call of a
  multi-call request (bucket routing + batches) resolves against one
  consistent artifact;
* ``swap(new_engine)`` publishes the replacement and bumps the generation;
  requests pinned to the old generation finish on the old artifact, which is
  retired and **dropped only when its last pin drains** — that release is
  what frees the superseded index's device buffers;
* unpinned single calls (``batch``/``buckets_of`` outside ``pin``) always
  see the latest engine.

No request ever waits on a swap and no swap ever waits on a request longer
than the lock's pointer flip — zero downtime by construction.
"""

from __future__ import annotations

import contextlib

import numpy as np

from repro.obs.locks import make_lock
from repro.serving.query_engine import QueryEngine


class SwappableEngine(QueryEngine):
    """Engine indirection with atomic generation-counted replacement."""

    name = "swappable"

    def __init__(self, engine: QueryEngine):
        self._lock = make_lock("engine.swap")
        self._current = engine
        engine.generation = 0   # each wrapped engine is 1:1 with its
        self._gen = 0           # generation (stamped here and in swap())
        self._pins: dict[int, int] = {}        # generation -> active pins
        self._retired: dict[int, QueryEngine] = {}
        self.swaps = 0
        self.drops = 0          # superseded artifacts fully drained + freed

    # ----------------------------------------------------------- properties
    @property
    def generation(self) -> int:
        return self._gen

    @property
    def current(self) -> QueryEngine:
        return self._current

    @property
    def artifact(self):
        """The current engine's packed index (None for host engines)."""
        return getattr(self._current, "index", None)

    @property
    def static_shapes(self) -> bool:
        return self._current.static_shapes

    @property
    def num_buckets(self) -> int:
        return self._current.num_buckets

    @property
    def use_kernels(self) -> bool:
        return getattr(self._current, "use_kernels", False)

    # ------------------------------------------------------------- pinning
    @contextlib.contextmanager
    def pin(self):
        with self._lock:
            gen, eng = self._gen, self._current
            self._pins[gen] = self._pins.get(gen, 0) + 1
        try:
            yield eng
        finally:
            self._release(gen)

    def _release(self, gen: int) -> None:
        with self._lock:
            self._pins[gen] -= 1
            if self._pins[gen] == 0:
                del self._pins[gen]
                if self._retired.pop(gen, None) is not None:
                    self.drops += 1     # last ref gone -> device buffers free

    def retired_generations(self) -> list:
        """Generations superseded but still pinned by in-flight requests."""
        with self._lock:
            return sorted(self._retired)

    # --------------------------------------------------------------- swap
    def swap(self, new_engine: QueryEngine) -> int:
        """Publish ``new_engine`` atomically; returns the new generation.

        The superseded engine is dropped immediately if nothing is pinned to
        it, otherwise parked until its pins drain.
        """
        with self._lock:
            old, old_gen = self._current, self._gen
            new_engine.generation = old_gen + 1   # see pin(): a request
            self._current = new_engine            # reads the generation it
            self._gen = old_gen + 1               # actually pinned
            self.swaps += 1
            if self._pins.get(old_gen):
                self._retired[old_gen] = old
            else:
                self.drops += 1
        return self._gen

    # ------------------------------------------------- QueryEngine protocol
    def buckets_of(self, s, t) -> np.ndarray:
        return self._current.buckets_of(s, t)

    def bucket_width(self, bucket: int) -> int:
        return getattr(self._current, "bucket_width", lambda b: 0)(bucket)

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return self._current.batch(s, t, bucket=bucket)

    def batch_argmin(self, s, t, bucket: int = 0):
        return self._current.batch_argmin(s, t, bucket=bucket)

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        self._current.warmup(batch_size, want_argmin=want_argmin)

    def device_bytes(self) -> int:
        """Bytes of the *current* artifact (retired ones are draining)."""
        return self._current.device_bytes()

    def __getattr__(self, name):
        """Delegate engine-specific surface (e.g. the sharded engine's
        ``shard_stats``/``per_shard_bytes``/``query``) to the current
        engine.  Unpinned like ``batch`` — multi-call consistency goes
        through ``pin()``.

        ``index`` is deliberately NOT delegated: long-lived holders (e.g.
        ``PathServer.__init__``'s ``getattr(engine, "index", None)``) would
        capture one generation's artifact and keep its device buffers alive
        across every future swap, defeating the drop-after-drain release.
        ``artifact`` is the sanctioned (momentary) accessor.
        """
        if name.startswith("_") or name == "index":
            raise AttributeError(name)
        return getattr(self._current, name)
