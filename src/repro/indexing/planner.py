"""Budgeted replanning — when and how to re-optimize the index for traffic.

The planner closes the gap between the paper's one-shot workload-aware
compression and a live system: it compares the recorded workload against the
one the serving artifact was last compressed under and picks the cheapest
sufficient action:

* ``skip``        — distribution stable and the artifact fits the budget;
* ``incremental`` — the artifact overflows a (possibly shrunk) budget but
  the distribution is stable: resume Algorithm 1 from the *current* region
  set (``compress_incremental``), no rebuild;
* ``replan``      — the distribution drifted past threshold: restore the
  base singleton-region snapshot and recompress with fresh Eq. 5 scores.
  Merges are irreversible, so re-splitting regions that earlier merges
  coarsened requires re-entering the loop from the snapshot — still far
  cheaper than ``build_ehl`` (no visibility polygons, no hub labels).

Drift is total-variation distance between normalized workloads; the budget
is a **device-byte** budget on the packed bucketed artifact
(``compress_to_device_budget``), i.e. what serving actually allocates.

**Hysteresis.**  A replan is expensive (host merge loop + repack + probe
validation) and resets the drift baseline, so a workload hovering *at* the
threshold would otherwise re-trigger on every noise excursion — swap churn.
Two guards stop it:

* enter/exit thresholds (a Schmitt trigger): the drift alarm raises at
  ``replan_threshold`` and stays latched until drift falls to
  ``exit_threshold`` — a brief dip back under the enter threshold neither
  clears the alarm nor re-fires it;
* min-dwell: after a *committed* replan, ``min_dwell`` further eligible
  ``decide()`` calls must pass before the next replan, bounding the replan
  rate regardless of how the drift signal oscillates.

Budget-overflow ``incremental`` decisions bypass both guards — holding the
device budget is a correctness property, churn control is not allowed to
defer it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.compression import (CompressionStats,
                                    compress_to_device_budget)


@dataclasses.dataclass
class PlanDecision:
    kind: str           # "skip" | "incremental" | "replan"
    drift: float        # TV distance vs. the last planned-under workload
    reason: str


class BudgetPlanner:
    """Decide + execute recompression against a recorded workload."""

    def __init__(self, device_budget_bytes: int, alpha: float = 0.2,
                 min_queries: int = 256, replan_threshold: float = 0.15,
                 exit_threshold: float | None = None, min_dwell: int = 2,
                 lane: int = 128, layout=None):
        from repro.core.packed import LAYOUT_F32

        self.device_budget_bytes = int(device_budget_bytes)
        self.layout = layout if layout is not None else LAYOUT_F32
        self.alpha = float(alpha)
        self.min_queries = int(min_queries)
        self.replan_threshold = float(replan_threshold)
        # hysteresis: alarm clears only below exit (default half of enter);
        # min_dwell eligible decide() calls must pass between replans
        self.exit_threshold = (float(exit_threshold)
                               if exit_threshold is not None
                               else self.replan_threshold / 2.0)
        if self.exit_threshold > self.replan_threshold:
            raise ValueError("exit_threshold must be <= replan_threshold")
        self.min_dwell = int(min_dwell)
        self.lane = int(lane)
        self._planned_dist: np.ndarray | None = None
        self._planned_at_queries = 0
        self._pending: tuple | None = None
        self._alarm = False
        self._dwell_left = 0
        # drift-trigger observability (DESIGN.md §12): decision mix,
        # live drift and alarm state as per-planner registry series
        self._obs_labels = {"planner": obs.next_instance_id("p")}
        # structured decision/execution records (DESIGN.md §13): the
        # manager points this at its Telemetry's EventLog; standalone
        # planners leave it None and skip the records
        self.events: obs.EventLog | None = None
        self._last_dev = 0

    # ------------------------------------------------------------ decisions
    def drift(self, recorder) -> float:
        """TV distance between recorder state and the last plan's workload."""
        if self._planned_dist is None:
            return 1.0
        return 0.5 * float(np.abs(recorder.distribution()
                                  - self._planned_dist).sum())

    def decide(self, recorder, index) -> PlanDecision:
        d = self._decide(recorder, index)
        reg = obs.REGISTRY
        reg.counter("planner_decisions_total", kind=d.kind,
                    **self._obs_labels).inc()
        reg.gauge("planner_drift", **self._obs_labels).set(d.drift)
        reg.gauge("planner_alarm", **self._obs_labels).set(int(self._alarm))
        if self.events is not None and d.kind != "skip":
            # skips fire every serving block — only actionable decisions
            # become structured records (budget pressure + alarm state)
            self.events.emit("plan_decision", decision=d.kind, drift=d.drift,
                             reason=d.reason,
                             budget_bytes=self.device_budget_bytes,
                             device_bytes=self._last_dev,
                             alarm=self._alarm,
                             dwell_left=self._dwell_left)
        return d

    def _decide(self, recorder, index) -> PlanDecision:
        from repro.core.packed import bucketed_device_bytes

        dev = bucketed_device_bytes(index, self.lane, layout=self.layout)
        self._last_dev = int(dev)
        fresh = recorder.queries - self._planned_at_queries
        if fresh < self.min_queries:
            if dev > self.device_budget_bytes:
                return PlanDecision("incremental", 0.0,
                                    f"artifact {dev}B over budget "
                                    f"{self.device_budget_bytes}B")
            return PlanDecision("skip", 0.0,
                                f"only {fresh} queries since last plan")
        d = self.drift(recorder)
        # min-dwell: every *eligible* decide() call (enough fresh traffic)
        # burns one dwell credit, alarmed or calm — a long calm stretch
        # after a replan uses the window up, so a genuine later shift is
        # not penalized for churn that never happened
        dwelling = self._dwell_left > 0
        if dwelling:
            self._dwell_left -= 1
        # Schmitt trigger: raise at enter, clear only at exit — the alarm
        # latches across dips into the (exit, enter) band
        if not self._alarm and d >= self.replan_threshold:
            self._alarm = True
        elif self._alarm and d <= self.exit_threshold:
            self._alarm = False
        if self._alarm and dwelling:
            if dev > self.device_budget_bytes:
                return PlanDecision("incremental", d,
                                    f"artifact {dev}B over budget "
                                    f"{self.device_budget_bytes}B")
            return PlanDecision(
                "skip", d, f"drift {d:.3f} alarmed but dwelling "
                f"({self._dwell_left + 1} more decisions before replan)")
        if self._alarm:
            return PlanDecision("replan", d,
                                f"workload drift {d:.3f} >= "
                                f"{self.replan_threshold} (alarm latched)")
        if dev > self.device_budget_bytes:
            return PlanDecision("incremental", d,
                                f"artifact {dev}B over budget "
                                f"{self.device_budget_bytes}B")
        return PlanDecision("skip", d,
                            f"drift {d:.3f} below enter threshold "
                            f"{self.replan_threshold}")

    # ------------------------------------------------------------ execution
    def execute(self, decision: PlanDecision, index, recorder,
                base_snapshot: dict | None = None) -> CompressionStats:
        """Mutate ``index`` per the decision; returns compression stats.

        ``replan`` needs the base snapshot (singleton regions, taken right
        after ``build_ehl``); ``incremental`` resumes in place.

        The plan is *pending* until :meth:`commit` — drift keeps being
        measured against the last **published** plan, so an aborted swap
        (validation failure) doesn't trick the planner into thinking the
        workload was already served.  Call :meth:`discard` on abort.
        """
        scores = recorder.scores()
        if decision.kind == "replan":
            if base_snapshot is None:
                raise ValueError("replan needs the base region snapshot")
            index.restore_regions(base_snapshot)
            stats = compress_to_device_budget(
                index, self.device_budget_bytes, cell_scores=scores,
                alpha=self.alpha, lane=self.lane, layout=self.layout)
        elif decision.kind == "incremental":
            stats = compress_to_device_budget(
                index, self.device_budget_bytes, cell_scores=scores,
                alpha=self.alpha, lane=self.lane, layout=self.layout)
        else:
            raise ValueError(f"nothing to execute for {decision.kind!r}")
        self._pending = (recorder.distribution(), recorder.queries)
        if self.events is not None:
            # the budget-in/out + regions-admitted/evicted record the
            # attribution layer joins against the swap's BUILD_STAGES span
            self.events.emit(
                "plan_execute", decision=decision.kind,
                budget_bytes=self.device_budget_bytes,
                label_bytes_in=stats.initial_bytes,
                label_bytes_out=stats.final_bytes,
                device_bytes=stats.device_bytes,
                regions_in=stats.regions + stats.merges,
                regions_admitted=stats.regions,
                regions_evicted=stats.merges,
                hit_single_region=stats.hit_single_region)
        return stats

    def commit(self) -> None:
        """Adopt the pending plan's workload as the planned-under baseline
        (call after the artifact built from it was published).

        Publishing also clears the drift alarm (drift vs the new baseline
        restarts near zero) and arms the min-dwell window: the next replan
        needs ``min_dwell`` further eligible ``decide()`` calls first.
        """
        if self._pending is not None:
            self._planned_dist, self._planned_at_queries = self._pending
            self._pending = None
            self._alarm = False
            self._dwell_left = self.min_dwell

    def discard(self) -> None:
        """Drop the pending plan (the candidate was rejected)."""
        self._pending = None

    def set_budget(self, device_budget_bytes: int) -> None:
        """Tighten/relax the budget at runtime (next decide() sees it)."""
        self.device_budget_bytes = int(device_budget_bytes)
