"""Live workload capture — the ``w_c`` histogram of workload-aware EHL*.

The paper's workload-aware mode (``s(c) = 1 + w_c``, Eq. 5) assumes the
query distribution is known offline.  In the serving stack the distribution
is *discovered*: every answered query's endpoints are folded into a decayed
per-cell histogram, which the :class:`~repro.indexing.planner.BudgetPlanner`
reads back as compression scores.

Properties:

* **O(1) per endpoint** — the same floor-divide cell mapping the online
  query phase uses for point location, vectorised over the batch;
* **bounded memory** — one float64 per grid cell (the [C] vector), no
  per-query state, regardless of traffic volume;
* **recency-weighted** — exponential decay with a configurable half-life
  measured in *queries*, so a shifted workload overtakes the old mass after
  ~a few half-lives instead of being averaged against all of history;
* **thread-safe** — the serving loop records while the manager's background
  build reads a consistent copy.
"""

from __future__ import annotations

import numpy as np

from repro.obs.locks import make_lock


class WorkloadRecorder:
    """Decayed per-cell endpoint histogram over the index grid."""

    def __init__(self, nx: int, ny: int, cell_size: float,
                 halflife: float = 4000.0):
        self.nx = int(nx)
        self.ny = int(ny)
        self.cell_size = float(cell_size)
        self.halflife = float(halflife)
        # decay applied per recorded *query* (two endpoints)
        self._decay = 0.5 ** (1.0 / halflife) if halflife > 0 else 1.0
        self.w = np.zeros(self.nx * self.ny, dtype=np.float64)
        self.queries = 0            # total queries ever recorded
        self._lock = make_lock("workload.recorder")

    @classmethod
    def for_index(cls, index, **kw) -> "WorkloadRecorder":
        """Recorder over an ``EHLIndex``'s (or packed artifact's) grid."""
        return cls(index.nx, index.ny, index.cell_size, **kw)

    # ------------------------------------------------------------------ I/O
    def _cells(self, pts: np.ndarray) -> np.ndarray:
        pts = np.asarray(pts, dtype=np.float64).reshape(-1, 2)
        ix = np.clip((pts[:, 0] / self.cell_size).astype(np.int64),
                     0, self.nx - 1)
        iy = np.clip((pts[:, 1] / self.cell_size).astype(np.int64),
                     0, self.ny - 1)
        return iy * self.nx + ix

    def record(self, s: np.ndarray, t: np.ndarray) -> None:
        """Fold a served batch's endpoints into the histogram."""
        cells = np.concatenate([self._cells(s), self._cells(t)])
        n = cells.size // 2
        if n == 0:
            return
        counts = np.bincount(cells, minlength=self.w.size).astype(np.float64)
        with self._lock:
            self.w *= self._decay ** n      # age existing mass
            self.w += counts
            self.queries += n

    # ------------------------------------------------------------- read-out
    def workload(self) -> np.ndarray:
        """[C] decayed endpoint counts w_c (a consistent copy)."""
        with self._lock:
            return self.w.copy()

    def scores(self) -> np.ndarray:
        """Paper's workload-aware initialisation: s(c) = 1 + w_c."""
        return 1.0 + self.workload()

    def distribution(self) -> np.ndarray:
        """[C] normalized workload (uniform if nothing recorded yet)."""
        w = self.workload()
        tot = w.sum()
        if tot <= 0.0:
            return np.full(w.size, 1.0 / w.size)
        return w / tot

    def reset(self) -> None:
        with self._lock:
            self.w[:] = 0.0
            self.queries = 0
