"""Sharded serving behind the standard ``QueryEngine`` protocol.

:class:`ShardedQueryEngine` fronts a :class:`~repro.serving.shard_router.
ShardRouter` with the exact interface ``PathServer`` already speaks —
``buckets_of`` returns composite (shard_s, shard_t, width) routing keys
instead of bucket ids, and ``batch``/``batch_argmin`` decode them — so the
whole serving stack (fixed-shape batching, per-bucket stats, pinning,
``SwappableEngine`` hot-swap, the adaptive ``IndexManager``) runs unchanged
over a mesh-sharded index.

Atomic multi-shard swap falls out of the object model: the engine *is* the
full shard set, so ``SwappableEngine.swap(new ShardedQueryEngine)`` flips
every shard under one generation — a pinned request keeps the entire old
shard set alive until it drains; no mixed-generation batch is expressible.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro import obs
from repro.core.grid import EHLIndex
from repro.core.packed import LAYOUT_F32, splice_rescue
from repro.serving.query_engine import QueryEngine
from repro.serving.shard_router import ShardRouter

from .planner import ShardedIndex, ShardPlanner


class ShardStats(obs.StatsView):
    """Per-shard serving + occupancy counters (surfaced via ``ServeStats``).

    Registry-backed view (DESIGN.md §12): traffic counters are labeled
    series keyed by engine instance + shard, so per-shard series appear
    in the Prometheus export and survive the view object itself."""

    _COUNTERS = {
        "batches": ("shard_batches_total", int),   # sub-batches joined here
        # query slots dispatched here (incl. padding)
        "slots": ("shard_slots_total", int),
        "seconds": ("shard_seconds_total", float),
        # label rows gathered here for another shard
        "gathers_out": ("shard_gathers_out_total", int),
        # covis verdicts computed here for another shard's join
        # (distributed s->t visibility over clipped edges, §10)
        "covis_assists": ("shard_covis_assists_total", int),
    }

    def __init__(self, shard: int, device: str, regions: int,
                 device_bytes: int, used_slots: int, total_slots: int,
                 registry=None, labels=None):
        self.shard = shard
        self.device = device
        self.regions = regions
        self.device_bytes = device_bytes
        self.used_slots = used_slots    # label slots holding real labels
        self.total_slots = total_slots  # label slots allocated (slab area)
        lbl = dict(labels or {})
        lbl.setdefault("shard", shard)
        self._bind(registry, lbl, row_prefix="sh")
        for name, v in (("shard_regions", regions),
                        ("shard_device_bytes", device_bytes),
                        ("shard_used_slots", used_slots),
                        ("shard_total_slots", total_slots)):
            self.registry.gauge(name, **self.labels).set(v)

    @property
    def occupancy(self) -> float:
        """Real labels / allocated slab slots (packing efficiency)."""
        return self.used_slots / max(1, self.total_slots)

    @property
    def us_per_slot(self) -> float:
        return 1e6 * self.seconds / max(1, self.slots)


def shard_imbalance(stats: list) -> float:
    """max/mean of per-shard device bytes across a ``ShardStats`` list."""
    b = np.array([s.device_bytes for s in stats], dtype=np.float64)
    return float(b.max() / max(1.0, b.mean()))


class ShardedQueryEngine(QueryEngine):
    """Region-sharded slabs over a device mesh, one ``QueryEngine``.

    ``index``: a planned :class:`ShardedIndex`, or a host ``EHLIndex`` that
    is planned + packed here (``num_shards`` required).  ``mesh``: a
    ``launch.mesh.make_serving_mesh`` mesh; ``None`` round-robins shards
    onto the available devices (single-device test mode — identical code
    paths, the transfers just degenerate to same-device copies).
    """

    name = "sharded"
    static_shapes = True

    def __init__(self, index, num_shards: int | None = None, mesh=None,
                 use_kernels: bool = False, lane: int = 128,
                 tol: float = 1.15, reuse_edges_from=None,
                 layout=LAYOUT_F32):
        if isinstance(index, EHLIndex):
            if not num_shards or num_shards < 1:
                raise ValueError("building from a host index needs "
                                 "num_shards >= 1")
            planner = ShardPlanner(num_shards, lane=lane, tol=tol,
                                   layout=layout)
            index = planner.build(index, reuse_edges_from=reuse_edges_from)
        if not isinstance(index, ShardedIndex):
            raise TypeError(f"unsupported artifact: {type(index)!r}")
        self.index = index
        self.use_kernels = use_kernels
        self.router = ShardRouter(index, mesh=mesh, use_kernels=use_kernels)
        self._telemetry = None      # bound by PathServer / IndexManager
        eng_id = obs.next_instance_id("e")
        self._stats = [
            ShardStats(
                shard=k, device=str(dev), regions=bx.num_regions,
                device_bytes=bx.device_bytes(),
                used_slots=bx.label_slots()[0],
                total_slots=bx.label_slots()[1],
                labels={"eng": eng_id, "shard": k})
            for k, (bx, dev) in enumerate(zip(index.shards,
                                              self.router.devices))]

    def bind_telemetry(self, telemetry) -> None:
        """Attach an event sink (cross-shard covis-assist events); the
        metrics registry is process-wide, so per-shard series are already
        exported without binding."""
        self._telemetry = telemetry

    # ------------------------------------------------- QueryEngine protocol
    @property
    def num_buckets(self) -> int:
        """Size of the composite key space (routing keys index into it)."""
        s = self.index.num_shards
        return s * s * len(self.index.width_classes)

    def buckets_of(self, s, t) -> np.ndarray:
        return self.router.route_keys(s, t)

    def bucket_width(self, bucket: int) -> int:
        """Join width of a routing key — the W^2 a query at this key pays."""
        return self.router.key_width(bucket)

    def _note_dispatch(self, staged, n: int) -> None:
        """Traffic counters for one dispatched group (no blocking)."""
        st = self._stats[staged.i]
        st.batches += 1
        st.slots += n
        if staged.j != staged.i:
            self._stats[staged.j].gathers_out += n
        assists = [k for k in staged.parts if k != staged.i]
        for k in assists:
            self._stats[k].covis_assists += n
        if assists and self._telemetry is not None:
            self._telemetry.events.emit("covis_assist", home=staged.i,
                                        helpers=assists, n=n)

    def _finish_argmin(self, staged, res6) -> tuple:
        """Quantized argmin epilogue: rescue ambiguous-margin rows against
        the exact residual so winners match the f32 sharded engine bitwise.
        """
        # repolint: disable=hot-path-sync -- documented rescue trigger: one flag word, the exactness contract pays this sync
        if bool(np.asarray(res6[5]).any()):
            return splice_rescue(res6, self.router.rescue(staged))
        # repolint: disable=hot-path-sync -- argmin epilogue returns host arrays by contract
        return tuple(np.asarray(r) for r in res6[:5])

    def _run(self, s, t, key: int, want_argmin: bool):
        t0 = time.perf_counter()
        # repolint: disable=hot-path-sync -- _run backs the synchronous batch()/batch_argmin() API; the staged path bypasses it
        staged = self.router.stage(np.asarray(s, np.float32),
                                   np.asarray(t, np.float32), int(key))  # repolint: disable=hot-path-sync -- host-input normalization in the synchronous path
        res = self.router.join_staged(staged, want_argmin=want_argmin)
        jax.block_until_ready(res)  # repolint: disable=hot-path-sync -- terminal join of the synchronous path
        if want_argmin and self.router.quantized:
            res = self._finish_argmin(staged, res)
        self._stats[staged.i].seconds += time.perf_counter() - t0
        self._note_dispatch(staged, len(s))
        return res

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return self._run(s, t, bucket, want_argmin=False)

    def batch_argmin(self, s, t, bucket: int = 0):
        return self._run(s, t, bucket, want_argmin=True)

    # ------------------------------------------------ split-phase (async)
    def stage(self, s, t, bucket: int = 0):
        """Pre-join transfers for one routed group (cross-shard gathers,
        covis dispatch) — overlaps the in-flight group's join under the
        continuous batcher."""
        # repolint: disable=hot-path-sync -- normalizes host inputs before the H2D enqueue; nothing lives on device yet
        return self.router.stage(np.asarray(s, np.float32),
                                 np.asarray(t, np.float32), int(bucket))  # repolint: disable=hot-path-sync -- same host-input normalization as the line above

    def dispatch_staged(self, staged, bucket: int = 0,
                        want_argmin: bool = False) -> tuple:
        """Non-blocking join over a staged group; the batcher owns
        synchronization (per-shard seconds land via note_batch_seconds)."""
        res = self.router.join_staged(staged, want_argmin=want_argmin)
        if want_argmin and self.router.quantized:
            # The amb verdict must be inspected host-side before results can
            # be scattered, so quantized argmin groups synchronize here; the
            # distance-only path stays fully asynchronous.
            res = self._finish_argmin(staged, res)
        self._note_dispatch(staged, int(staged.s_dev.shape[0]))
        return tuple(res) if want_argmin else (res,)

    def note_batch_seconds(self, bucket: int, seconds: float) -> None:
        """Async-path latency attribution to the key's home shard."""
        i, _, _ = self.router.decode_key(int(bucket))
        self._stats[i].seconds += seconds

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        self.router.warmup(batch_size, want_argmin=want_argmin)

    def device_bytes(self) -> int:
        """Total across the mesh; ``per_shard_bytes`` has the HBM view."""
        return self.index.device_bytes()

    # --------------------------------------------------------- observability
    def per_shard_bytes(self) -> list:
        return self.index.per_shard_bytes()

    def shard_stats(self) -> list:
        return self._stats

    def reset_serve_counters(self) -> None:
        """Zero the traffic counters (occupancy/bytes stay — they describe
        the artifact).  The IndexManager calls this after probe validation
        so a freshly swapped-in engine reports only real serving traffic."""
        for st in self._stats:
            st.batches = 0
            st.slots = 0
            st.seconds = 0.0
            st.gathers_out = 0
            st.covis_assists = 0

    def imbalance(self) -> float:
        return shard_imbalance(self._stats)

    # ------------------------------------------------------------- serving
    def query(self, s, t, want_argmin: bool = False):
        """Route + dispatch + in-order merge for a whole batch (exact
        shapes, no padding) — validation/bench/test entry.  Same dispatch
        path as ``batch`` so per-shard stats record either way."""
        from repro.core.packed import empty_results

        s = np.asarray(s, np.float32)
        t = np.asarray(t, np.float32)
        n = len(s)
        outs = empty_results(n, want_argmin)
        keys = self.buckets_of(s, t) if n else np.zeros(0, np.int32)
        for key in np.unique(keys):
            m = keys == key
            res = self._run(s[m], t[m], int(key), want_argmin)
            for o, r in zip(outs, res if want_argmin else (res,)):
                o[m] = np.asarray(r)
        return tuple(outs) if want_argmin else outs[0]
