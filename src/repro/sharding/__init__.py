"""Sharded serving: region-sharded bucket slabs over a device mesh
(DESIGN.md §9).

For maps whose *budgeted* artifact still exceeds one accelerator's HBM, the
index is placed rather than shrunk further:

* :class:`ShardPlanner`       — byte-balanced, locality-aware region ->
  shard placement (Morton-order bin-pack + bounded rebalance);
* :class:`ShardedIndex`       — per-shard ``BucketedIndex`` slabs plus the
  host-side (cell) -> (shard, bucket, row) routing table;
* :class:`ShardedQueryEngine` — the ``QueryEngine`` implementation routing
  per-(shard, bucket) sub-batches over the mesh with cross-shard label
  gathers, answers bitwise-identical to the single-device engine;
* :class:`ShardStats`         — per-shard occupancy/latency/imbalance,
  surfaced through ``ServeStats.per_shard``.

The dispatch mechanics live in :mod:`repro.serving.shard_router`.
"""

from .planner import (ShardPlan, ShardPlanner, ShardedIndex,  # noqa: F401
                      region_centroids, sharded_overhead_bytes)
from .engine import (ShardStats, ShardedQueryEngine,  # noqa: F401
                     shard_imbalance)
