"""Region -> shard placement: byte-balanced, locality-aware bin-packing.

EHL* budgets the index so it fits one device; past the point where merging
would destroy query performance, the remaining option is to *place* the
bucketed slabs across a device mesh.  The placement objective (DESIGN.md §9):

* **balance** — every shard's packed slab bytes within ``tol`` of the mean,
  so the per-device HBM budget is ``total / num_shards`` and no device is
  the memory straggler;
* **locality** — spatially adjacent cells co-locate, so clustered traffic
  (the workloads EHL*'s workload-aware mode optimizes for) resolves both
  endpoints on one shard and skips the cross-shard label gather.

The two are served in order: regions are walked in Morton (Z-curve) order
of their cell centroids and cut into ``num_shards`` contiguous runs sized
by slab bytes; a bounded refinement pass then moves boundary-adjacent
regions off the heaviest shard (toward the shard whose centroid is
nearest) until the balance tolerance holds.  Slab bytes per region are
exact — ``bucket_width(labels) * bytes_per_slot`` — because a region's
bucket width is invariant under sharding (see ``pack_bucketed_split``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.grid import EHLIndex
from repro.core.packed import (LAYOUT_F32, SlabLayout, _grid_bytes,
                               bucket_width, dtype_bytes,
                               pack_bucketed_split, padded_edge_count)


def _morton(ix: np.ndarray, iy: np.ndarray, bits: int = 16) -> np.ndarray:
    """Interleave-bit Z-curve codes for integer grid coordinates."""
    code = np.zeros(ix.shape, dtype=np.int64)
    ix = ix.astype(np.int64)
    iy = iy.astype(np.int64)
    for b in range(bits):
        code |= ((ix >> b) & 1) << (2 * b)
        code |= ((iy >> b) & 1) << (2 * b + 1)
    return code


def region_centroids(index: EHLIndex) -> np.ndarray:
    """[R, 2] mean cell-center (grid coords) per live region, rid order."""
    live = sorted(index.regions.keys())
    row_of = {rid: i for i, rid in enumerate(live)}
    acc = np.zeros((len(live), 3), dtype=np.float64)     # sx, sy, n
    for ci, rid in enumerate(index.mapper):
        i = row_of[int(rid)]
        iy, ix = divmod(ci, index.nx)
        acc[i, 0] += ix + 0.5
        acc[i, 1] += iy + 0.5
        acc[i, 2] += 1.0
    return acc[:, :2] / np.maximum(acc[:, 2:3], 1.0)


@dataclasses.dataclass
class ShardPlan:
    """A placement: region -> shard, with its predicted byte profile."""
    num_shards: int
    assignment: np.ndarray      # [R] int32, live-rid order
    slab_bytes: np.ndarray      # [S] predicted packed slab bytes per shard
    moves: int                  # refinement moves the balance pass needed
    tol: float

    @property
    def imbalance(self) -> float:
        """max/mean of per-shard slab bytes (1.0 = perfectly balanced)."""
        return float(self.slab_bytes.max() / max(1.0, self.slab_bytes.mean()))


@dataclasses.dataclass
class ShardedIndex:
    """Host-side container: per-shard slabs + the (cell)->(shard,bucket,row)
    routing table.  Not a pytree — each shard's ``BucketedIndex`` is placed
    on its own device by the router; the routing arrays stay host-side."""

    shards: tuple               # per-shard BucketedIndex
    plan: ShardPlan
    region_shard: np.ndarray    # [R] region -> shard
    region_local: np.ndarray    # [R] region -> local id within its shard
    cell_shard: np.ndarray      # [C] cell -> owning shard
    cell_local: np.ndarray      # [C] cell -> local region id in that shard
    cell_bucket: np.ndarray     # [C] cell -> local bucket index
    cell_row: np.ndarray        # [C] cell -> row within that bucket's slab
    cell_width: np.ndarray      # [C] cell -> bucket width (join-width input)
    edge_masks: list            # per shard: [E] bool clipped-edge subset
    shard_rects: np.ndarray     # [S, 4] owned-cell bounding boxes (covis)
    nx: int
    ny: int
    cell_size: float
    width_classes: tuple        # sorted union of all shards' bucket widths

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def num_regions(self) -> int:
        return self.region_shard.shape[0]

    def per_shard_bytes(self) -> list:
        return [bx.device_bytes() for bx in self.shards]

    def device_bytes(self) -> int:
        """Total bytes across the mesh (mapper/edges replicated per shard)."""
        return int(sum(self.per_shard_bytes()))

    def max_shard_bytes(self) -> int:
        return int(max(self.per_shard_bytes()))

    def imbalance(self) -> float:
        b = np.array(self.per_shard_bytes(), dtype=np.float64)
        return float(b.max() / max(1.0, b.mean()))

    def bucket_stats(self) -> list:
        """Per-(shard, bucket) occupancy rows (ShardStats feeds on these)."""
        out = []
        for k, bx in enumerate(self.shards):
            for row in bx.bucket_stats():
                out.append(dict(shard=k, **row))
        return out

    def edge_bytes(self) -> list:
        """Per-shard clipped edge-tensor (+ grid) bytes — the replication
        the clip eliminated is ``num_shards * full_edge_bytes - sum(this)``.
        """
        out = []
        for bx in self.shards:
            b = int(sum(np.prod(a.shape) * a.dtype.itemsize
                        for a in (bx.edges_a, bx.edges_b, bx.edges_c)))
            out.append(b + (bx.grid.device_bytes() if bx.grid else 0))
        return out


def sharded_overhead_bytes(index: EHLIndex, num_shards: int,
                           lane: int = 128,
                           layout: SlabLayout = LAYOUT_F32) -> int:
    """Upper bound on extra device bytes sharding adds vs single-device.

    Each shard replicates the full-grid mapper; edge tensors are *clipped*
    per shard (owned-region clip boxes, ``pack_bucketed_split``), so the
    worst case — every clip keeping every edge, plus the edge grid that
    clip would carry (`_grid_bytes` mirrors the packers' attach policy) —
    is the bound used here.  The budget-driven compression targets
    ``budget - overhead``, and a conservative overhead only ever lands the
    artifact further under budget; ``ShardedIndex.edge_bytes`` reports the
    realized clip savings.
    """
    if num_shards <= 1:
        return 0
    Ep = padded_edge_count(index.scene.edges.shape[0], lane)
    # edge_grid=True: a clipped subset may attach a grid even when the full
    # edge set's auto policy stays dense, so bound with the forced grid.
    # Quantized layouts also replicate the shared [V, 2] vertex table
    # (dtype_bytes.per_vertex) on every shard.
    per_shard_fixed = (index.mapper.size * 4 + 3 * Ep * 2 * 4
                       + index.graph.num_nodes * dtype_bytes(layout).per_vertex
                       + _grid_bytes(index, lane, True))
    return (num_shards - 1) * per_shard_fixed


class ShardPlanner:
    """Plan and build region-sharded artifacts over ``num_shards`` devices."""

    def __init__(self, num_shards: int, lane: int = 128, tol: float = 1.15,
                 max_moves: int | None = None,
                 layout: SlabLayout = LAYOUT_F32):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.num_shards = int(num_shards)
        self.lane = int(lane)
        self.tol = float(tol)
        self.max_moves = max_moves
        self.layout = layout

    # ------------------------------------------------------------------ plan
    def plan(self, index: EHLIndex) -> ShardPlan:
        S = self.num_shards
        counts = index.packed_label_counts()
        R = len(counts)
        if R < S:
            raise ValueError(f"{R} regions cannot fill {S} shards — "
                             "compress less or use fewer shards")
        lb = dtype_bytes(self.layout)
        rb = np.array([bucket_width(max(1, int(c)), self.lane) * lb.per_slot
                       + lb.per_row for c in counts], dtype=np.int64)
        cent = region_centroids(index)
        order = np.argsort(
            _morton(cent[:, 0].astype(np.int64), cent[:, 1].astype(np.int64)),
            kind="stable")

        # contiguous Morton runs, each closed at the running fair share
        assignment = np.zeros(R, dtype=np.int32)
        total = int(rb.sum())
        shard, acc, spent = 0, 0, 0
        for pos, r in enumerate(order):
            remaining_regions = R - pos
            remaining_shards = S - shard
            target = (total - spent) / remaining_shards
            if shard < S - 1 and acc > 0 and (
                    acc + rb[r] / 2 >= target
                    or remaining_regions <= remaining_shards):
                shard += 1
                acc = 0
            assignment[r] = shard
            acc += int(rb[r])
            spent += int(rb[r])

        slab = np.bincount(assignment, weights=rb, minlength=S)
        # bounded rebalance: peel the heaviest shard's best-fitting region
        # toward the lightest until the tolerance holds
        moves = 0
        limit = self.max_moves if self.max_moves is not None else 4 * R
        tol_target = self.tol * slab.mean()
        while slab.max() > tol_target and moves < limit:
            hi = int(slab.argmax())
            lo = int(slab.argmin())
            members = np.nonzero(assignment == hi)[0]
            if members.size <= 1:
                break
            gap = slab[hi] - slab[lo]
            # candidates that actually shrink the gap, nearest to the
            # receiving shard's centroid first (locality-preserving)
            fits = members[rb[members] < gap]
            if fits.size == 0:
                break
            lo_cent = cent[assignment == lo].mean(axis=0)
            r = fits[np.argmin(((cent[fits] - lo_cent) ** 2).sum(axis=1))]
            assignment[r] = lo
            slab[hi] -= rb[r]
            slab[lo] += rb[r]
            moves += 1
        return ShardPlan(num_shards=S, assignment=assignment,
                         slab_bytes=slab.astype(np.int64), moves=moves,
                         tol=self.tol)

    # ----------------------------------------------------------------- build
    def build(self, index: EHLIndex, plan: ShardPlan | None = None,
              reuse_edges_from=None,
              edge_grid: bool | None = None) -> ShardedIndex:
        """Pack the planned placement into per-shard device artifacts.

        ``reuse_edges_from``: previous-generation artifact(s) whose clipped
        edge tensors are aliased where the clip is unchanged (the hot-swap
        repack fast path) — a per-shard sequence or a previous
        ``ShardedIndex`` (whose stored edge masks gate the reuse).
        """
        if plan is None:
            plan = self.plan(index)
        reuse_masks = None
        if isinstance(reuse_edges_from, ShardedIndex):
            reuse_masks = list(reuse_edges_from.edge_masks)
            reuse_edges_from = list(reuse_edges_from.shards)
        shards, route = pack_bucketed_split(
            index, plan.assignment, plan.num_shards, lane=self.lane,
            reuse_edges_from=reuse_edges_from, reuse_edge_masks=reuse_masks,
            edge_grid=edge_grid, layout=self.layout)
        classes = sorted({w for bx in shards for w in bx.widths})
        return ShardedIndex(
            shards=tuple(shards), plan=plan,
            region_shard=route["region_shard"],
            region_local=route["region_local"],
            cell_shard=route["cell_shard"],
            cell_local=route["cell_local"],
            cell_bucket=route["cell_bucket"],
            cell_row=route["cell_row"],
            cell_width=route["cell_width"],
            edge_masks=route["edge_mask"],
            shard_rects=route["shard_rects"],
            nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
            width_classes=tuple(classes))
