"""Deterministic, resumable, shardable token pipeline.

Stateless generation: batch ``i`` of host shard ``h`` is a pure function of
(seed, step, h) via threefry — so

* restart at step k reproduces the exact stream (checkpoint/restart safety),
* host shards are disjoint by construction (straggler-safe: no coordination),
* no filesystem dependency for benchmarks; a memory-mapped corpus reader is
  provided for real data.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def host_batch_size(cfg: DataConfig) -> int:
    assert cfg.global_batch % cfg.n_hosts == 0, \
        (cfg.global_batch, cfg.n_hosts)
    return cfg.global_batch // cfg.n_hosts


def synthetic_batch(cfg: DataConfig, step: int) -> np.ndarray:
    """[host_batch, seq_len] int32 tokens for this (step, host)."""
    hb = host_batch_size(cfg)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.host_id)
    # markovian-ish stream: mix of a linear ramp and noise so loss can fall
    ks = jax.random.split(key, 2)
    base = jax.random.randint(ks[0], (hb, 1), 0, cfg.vocab)
    drift = jnp.arange(cfg.seq_len)[None, :]
    noise = jax.random.randint(ks[1], (hb, cfg.seq_len), 0, 17)
    toks = (base + drift + noise) % cfg.vocab
    return np.asarray(toks, dtype=np.int32)


class CorpusReader:
    """Memory-mapped flat token corpus with deterministic sharded windows."""

    def __init__(self, path: str, cfg: DataConfig):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        hb = host_batch_size(cfg)
        n_windows = len(self.tokens) // cfg.seq_len
        rng = np.random.default_rng(cfg.seed + step)
        idx = rng.permutation(n_windows)[
            cfg.host_id * hb:(cfg.host_id + 1) * hb]
        out = np.stack([self.tokens[i * cfg.seq_len:(i + 1) * cfg.seq_len]
                        for i in idx])
        return out.astype(np.int32) % cfg.vocab


def global_batch_arrays(cfg: DataConfig, step: int, mesh, spec):
    """Host batch -> globally-sharded jax.Array via make_array_from_callback
    (multi-host path; on a single host this is a plain device_put)."""
    from jax.sharding import NamedSharding
    local = synthetic_batch(cfg, step)
    sharding = NamedSharding(mesh, spec)
    gshape = (cfg.global_batch, cfg.seq_len)

    def cb(index):
        # index is relative to the GLOBAL array; slice from the host batch
        rows = range(*index[0].indices(gshape[0]))
        sl = [r % local.shape[0] for r in rows]
        return local[sl][:, index[1]]

    return jax.make_array_from_callback(gshape, sharding, cb)
