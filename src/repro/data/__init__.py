from .pipeline import CorpusReader, DataConfig, synthetic_batch  # noqa: F401
