"""repro.obs — dependency-free serving telemetry (DESIGN.md §12).

Three primitives, one bundle:

* :class:`MetricsRegistry` — labeled counters/gauges/histograms; the
  process-wide :data:`REGISTRY` is the single source of truth that the
  ``ServeStats``/``BucketStats``/``ShardStats`` views, the Prometheus/
  JSON exporters and the benches all read.
* :class:`Trace`/:class:`TraceLog`/:class:`HeadSampler` — per-request
  span trees, head-sampled with an always-sample-on-slow override.
* :class:`EventLog` — structured ring + JSONL sink for discrete state
  changes (swaps, drift, sheds, requeues, quant fallbacks, covis).

:class:`Telemetry` bundles sampler + trace ring + event log (the
registry defaults to the shared :data:`REGISTRY`).  ``Telemetry.off()``
builds the disabled variant used by the instrumentation-overhead gate:
sampling rate 0, events suppressed — the registry stays live because it
*is* the serving stats.
"""

from .events import EventLog
from .locks import (LOCK_RANKS, LockOrderError, OrderedLock, held_locks,
                    lock_check_enabled, make_lock)
from .metrics import (DEFAULT_LATENCY_BOUNDS_MS, Counter, Gauge, Histogram,
                      MetricsRegistry, REGISTRY, log_bounds,
                      next_instance_id)
from .export import json_snapshot, parse_prometheus, prometheus_text
from .profile import (CompileCapture, CompileRecord, aot_cost,
                      disable_profile, enable_profile, normalize_cost,
                      profiled)
from .timing import Stopwatch, monotonic
from .trace import (ASYNC_STAGES, BUILD_STAGES, SYNC_STAGES, HeadSampler,
                    Span, Trace, TraceLog)
from .views import StatsView

__all__ = [
    "ASYNC_STAGES", "BUILD_STAGES", "SYNC_STAGES",
    "CompileCapture", "CompileRecord", "Counter",
    "DEFAULT_LATENCY_BOUNDS_MS",
    "EventLog", "Gauge", "HeadSampler", "Histogram", "LOCK_RANKS",
    "LockOrderError", "MetricsRegistry", "OrderedLock",
    "REGISTRY", "Span", "StatsView", "Stopwatch", "Telemetry", "Trace",
    "TraceLog",
    "aot_cost", "disable_profile", "enable_profile", "held_locks",
    "json_snapshot", "lock_check_enabled", "log_bounds", "make_lock",
    "monotonic", "next_instance_id", "normalize_cost",
    "parse_prometheus", "profiled", "prometheus_text",
]


class Telemetry:
    """Sampler + trace ring + event log over a shared metrics registry."""

    def __init__(self, registry: MetricsRegistry = None,
                 sample_rate: float = 0.05, slow_ms: float = 50.0,
                 events: EventLog = None, span_capacity: int = 1024,
                 events_path: str = None):
        self.registry = REGISTRY if registry is None else registry
        self.sampler = HeadSampler(rate=sample_rate, slow_ms=slow_ms)
        self.spans = TraceLog(capacity=span_capacity)
        self.events = EventLog(path=events_path) if events is None \
            else events

    @classmethod
    def off(cls, registry: MetricsRegistry = None) -> "Telemetry":
        """Spans and events disabled; registry recording stays on."""
        t = cls(registry=registry, sample_rate=0.0, slow_ms=0.0)
        t.events.enabled = False
        return t

    @property
    def enabled(self) -> bool:
        return (self.sampler.rate > 0.0 or self.sampler.slow_ms > 0.0
                or self.events.enabled)
