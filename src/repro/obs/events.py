"""Structured event log for discrete serving/indexing state changes.

Events are append-only dicts with a monotone sequence number, a wall
timestamp (for humans correlating with external logs) and a monotonic
timestamp (for ordering against span data).  The log keeps a bounded
in-memory ring and can optionally tee every event to a JSONL file sink.

Event taxonomy (DESIGN.md §12):

=================  ===================================================
swap               IndexManager committed a hot-swap (generation, kind,
                   drift, build/pack/validate seconds, bytes, regions)
swap_abort         validation/budget gate rejected a candidate artifact
drift              BudgetPlanner decided to act on workload drift
quant_fallback     a quantized bucket went loud (per-bucket f32 fallback
                   counts from the artifact's ``quant_stats``)
shed               backpressure dropped a submit (policy="shed")
requeue            a staged group was superseded by a swap and re-routed
                   under the live generation
covis_assist       a sharded dispatch needed cross-shard co-visibility
                   verdicts (count per staged group)
=================  ===================================================
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import List, Optional

from .locks import make_lock


class EventLog:
    """Bounded ring + optional JSONL file sink."""

    def __init__(self, capacity: int = 4096, path: Optional[str] = None,
                 enabled: bool = True):
        self._ring: deque = deque(maxlen=int(capacity))
        self._lock = make_lock("obs.events")
        self._seq = 0
        self._fh = None
        self.enabled = enabled
        self.path = None
        if path is not None:
            self.open_sink(path)

    def open_sink(self, path: str) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
            self.path = path
            self._fh = open(path, "a", buffering=1)

    def close_sink(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def emit(self, kind: str, **fields) -> Optional[dict]:
        if not self.enabled:
            return None
        # ts is wall-clock *on purpose* — it is a datum for humans
        # correlating the JSONL with external logs, never a duration
        # operand; ts_mono is what joins against span/stopwatch data.
        ev = {"kind": kind,
              "ts": time.time(),  # repolint: disable=monotonic-time -- wall time is the datum here, ts_mono carries ordering
              "ts_mono": time.perf_counter(), **fields}
        with self._lock:
            self._seq += 1
            ev["seq"] = self._seq
            self._ring.append(ev)
            if self._fh is not None:
                self._fh.write(json.dumps(ev, default=_jsonable) + "\n")
        return ev

    def events(self, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            evs = list(self._ring)
        return evs if kind is None else [e for e in evs
                                         if e["kind"] == kind]

    def counts(self) -> dict:
        out: dict = {}
        for e in self.events():
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out

    def dump_jsonl(self, path: str) -> int:
        evs = self.events()
        with open(path, "w") as fh:
            for e in evs:
                fh.write(json.dumps(e, default=_jsonable) + "\n")
        return len(evs)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


def _jsonable(o):
    """Best-effort JSON coercion for numpy scalars and odd field types."""
    try:
        import numpy as np
        if isinstance(o, np.generic):
            return o.item()
        if isinstance(o, np.ndarray):
            return o.tolist()
    except Exception:
        pass
    return str(o)
