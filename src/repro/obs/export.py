"""Exporters: Prometheus text format + JSON snapshot, and a parser.

``prometheus_text`` renders every series in a registry in the Prometheus
exposition format (histograms as cumulative ``_bucket``/``_sum``/
``_count`` families).  ``parse_prometheus`` inverts it strictly enough
for CI smokes to assert "the snapshot parses and series X is present"
without a prometheus client dependency.
"""

from __future__ import annotations

import json
import math
import re
from typing import Dict, Tuple

from .metrics import Gauge, Histogram, MetricsRegistry

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LINE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"          # metric name
    r"(?:\{([^}]*)\})?"                      # optional label block
    r"\s+(-?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|[Ii]nf|NaN))$")  # value
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels, extra: Dict[str, str] = None) -> str:
    items = list(labels) + sorted((extra or {}).items())
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_esc(str(v))}"' for k, v in items) + "}"


def _fmt_val(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 2 ** 53 else repr(f)


def prometheus_text(registry: MetricsRegistry) -> str:
    lines = []
    by_name: Dict[Tuple[str, str], list] = {}
    for m in registry.metrics():
        kind = ("histogram" if isinstance(m, Histogram) else
                "gauge" if isinstance(m, Gauge) else "counter")
        by_name.setdefault((m.name, kind), []).append(m)
    for (name, kind), series in sorted(by_name.items()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid prometheus metric name: {name!r}")
        lines.append(f"# TYPE {name} {kind}")
        for m in sorted(series, key=lambda s: s.labels):
            if kind == "histogram":
                cum = 0
                for b, c in zip(m.bounds, m.counts[:-1]):
                    cum += int(c)
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(m.labels, {'le': _fmt_val(b)})}"
                        f" {cum}")
                cum += int(m.counts[-1])
                lines.append(
                    f"{name}_bucket"
                    f"{_fmt_labels(m.labels, {'le': '+Inf'})} {cum}")
                lines.append(
                    f"{name}_sum{_fmt_labels(m.labels)} {_fmt_val(m.sum)}")
                lines.append(f"{name}_count{_fmt_labels(m.labels)} {cum}")
            else:
                lines.append(
                    f"{name}{_fmt_labels(m.labels)} {_fmt_val(m.value)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str],
                                                        ...], float]]:
    """Parse exposition text → {name: {sorted label tuple: value}}.

    Raises ``ValueError`` on any malformed sample line, which is the CI
    assertion that the snapshot is well-formed.
    """
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        m = _LINE_RE.match(ln)
        if m is None:
            raise ValueError(f"malformed prometheus line: {ln!r}")
        name, lblk, val = m.group(1), m.group(2) or "", m.group(3)
        labels = tuple(sorted(
            (k, v.replace('\\"', '"').replace("\\n", "\n")
             .replace("\\\\", "\\"))
            for k, v in _LABEL_RE.findall(lblk)))
        out.setdefault(name, {})[labels] = float(val)
    return out


def json_snapshot(registry: MetricsRegistry, **extra) -> str:
    snap = registry.snapshot()
    snap.update(extra)
    return json.dumps(snap, indent=1, sort_keys=True)
