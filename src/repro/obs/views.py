"""Registry-backed stats views.

The serving stack's public stats objects (``ServeStats``/``BucketStats``/
``ShardStats``) keep their dataclass-era field surface — ``stats.queries
+= n``, ``stats.seconds = 0.0`` — but every counter/gauge field is a
property over a registry series, so the Prometheus/JSON exports and the
in-process views are the same numbers by construction.

Each view instance binds its series under its own unique ``row`` label
(plus semantic labels like ``srv``/``bucket``/``gen``): a *fresh view is
a fresh series*, which preserves the old value semantics exactly (a new
``ServeStats()`` starts at zero; a per-bucket dict reset on hot-swap
starts new generation-tagged series while the retired generation's rows
stay frozen in the registry).
"""

from __future__ import annotations

from .metrics import MetricsRegistry, REGISTRY, next_instance_id


def _make_property(field: str, cast):
    def fget(self):
        return cast(self._series[field].value)

    def fset(self, v):
        self._series[field].set(v)

    return property(fget, fset, doc=f"registry-backed field {field!r}")


class StatsView:
    """Base: subclasses declare ``_COUNTERS``/``_GAUGES`` maps of
    ``field -> (metric_name, cast)`` and call ``_bind`` in __init__."""

    _COUNTERS: dict = {}
    _GAUGES: dict = {}

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        for field, (_, cast) in {**cls._COUNTERS, **cls._GAUGES}.items():
            setattr(cls, field, _make_property(field, cast))

    def _bind(self, registry: MetricsRegistry = None, labels: dict = None,
              row_prefix: str = "v") -> None:
        self.registry = REGISTRY if registry is None else registry
        lbl = {k: str(v) for k, v in (labels or {}).items()}
        lbl.setdefault("row", next_instance_id(row_prefix))
        self.labels = lbl
        self._series = {}
        for field, (name, _) in self._COUNTERS.items():
            self._series[field] = self.registry.counter(name, **lbl)
        for field, (name, _) in self._GAUGES.items():
            self._series[field] = self.registry.gauge(name, **lbl)

    def counters(self) -> dict:
        return {f: getattr(self, f)
                for f in {**self._COUNTERS, **self._GAUGES}}

    def __repr__(self) -> str:
        kv = ", ".join(f"{k}={v}" for k, v in self.counters().items())
        return f"{type(self).__name__}({kv})"
