"""Monotonic duration timing, shared by launch/serving/bench code.

``time.time()`` is wall-clock: NTP slews and DST jumps make it lie about
durations.  Everything in this repo that measures *how long something
took* goes through ``monotonic()`` / ``Stopwatch`` so the choice is made
once, here.
"""

from __future__ import annotations

import time
from typing import Optional


def monotonic() -> float:
    """The repo-wide duration clock (``time.perf_counter``)."""
    return time.perf_counter()


class Stopwatch:
    """Monotonic stopwatch: ``lap()`` returns-and-restarts, or use as a
    context manager and read ``.seconds`` after exit."""

    __slots__ = ("t0", "seconds")

    def __init__(self):
        self.t0 = monotonic()
        self.seconds: Optional[float] = None

    def lap(self) -> float:
        now = monotonic()
        dt, self.t0 = now - self.t0, now
        return dt

    def elapsed(self) -> float:
        return monotonic() - self.t0

    def __enter__(self) -> "Stopwatch":
        self.t0 = monotonic()
        return self

    def __exit__(self, *exc) -> None:
        self.seconds = monotonic() - self.t0
