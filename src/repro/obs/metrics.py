"""Process-wide metrics registry: counters, gauges, latency histograms.

Dependency-free by design (stdlib + numpy only — **no jax**): ``core.packed``
imports this module for its trace counter, so anything heavier would create
an import cycle and would drag device runtime into host-only tools.

Design notes
------------
* **Labeled series.** A metric is identified by ``(name, labels)`` where
  ``labels`` is a frozen, sorted tuple of ``(key, value)`` string pairs.
  Per-instance labels (``srv="s3"``, ``eng="e7"``) are how a process-wide
  registry serves many servers/engines without cross-talk: each
  ``ServeStats`` view owns a unique instance label, so unit tests that
  assert exact counts on a fresh server keep passing unchanged.
* **Generation-tagged series.** Per-bucket serving series carry a
  ``gen`` label.  A hot-swap starts fresh series (all zero) while the
  retired generation's series stay frozen in the registry — the registry
  never loses history, the dataclass views only show the live generation.
* **Histograms** use fixed log-spaced bucket bounds.  ``quantile(q)``
  returns the smallest bucket upper bound covering rank ``ceil(q*n)`` —
  exactly numpy's ``method="inverted_cdf"`` when samples sit on bucket
  boundaries, and within one bucket's resolution (``10**(1/per_decade)``)
  otherwise.  Counts are plain int64 numpy arrays, so shard-merge is
  element-wise addition.
* **Thread safety.** Every mutation takes the metric's own lock; the
  registry lock only guards series creation.  Recording is O(1) (or one
  ``searchsorted`` for histograms) — cheap enough for the dispatch loop,
  which records per *group*, not per query (per-query latencies go
  through the vectorized ``record_many``).
"""

from __future__ import annotations

import itertools
import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .locks import make_lock

LabelKey = Tuple[Tuple[str, str], ...]

_IDS = itertools.count(1)


def next_instance_id(prefix: str) -> str:
    """Unique per-process instance label value (``s1``, ``e2``, ...)."""
    return f"{prefix}{next(_IDS)}"


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def log_bounds(lo: float, hi: float, per_decade: int = 8) -> np.ndarray:
    """Log-spaced bucket upper bounds covering [lo, hi]."""
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    return np.asarray(lo * 10.0 ** (np.arange(n) / per_decade))


#: Default latency bounds: 1 ns .. 60 s expressed in ms, 8 buckets/decade
#: (resolution 10**(1/8) ~ 1.33x — tight enough for p99 regression gates).
DEFAULT_LATENCY_BOUNDS_MS = log_bounds(1e-6, 6e4, 8)


class Counter:
    """Monotonic-by-convention float counter (settable for view resets)."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = make_lock("obs.series")

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def merge(self, other: "Counter") -> None:
        self.inc(other.value)

    def snapshot(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self._value}


class Gauge(Counter):
    """Same storage as Counter; distinct type for export semantics."""

    __slots__ = ()

    def merge(self, other: "Counter") -> None:  # gauges take the max
        with self._lock:
            self._value = max(self._value, other.value)


class Histogram:
    """Fixed-bucket histogram with exact rank-based quantile readback."""

    __slots__ = ("name", "labels", "bounds", "counts", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str, labels: LabelKey = (),
                 bounds: Optional[np.ndarray] = None):
        self.name = name
        self.labels = labels
        self.bounds = np.asarray(
            DEFAULT_LATENCY_BOUNDS_MS if bounds is None else bounds,
            dtype=np.float64)
        if self.bounds.ndim != 1 or len(self.bounds) < 1 or \
                np.any(np.diff(self.bounds) <= 0):
            raise ValueError("bounds must be a 1-D increasing array")
        # counts[i] <= bounds[i]; counts[-1] is the +Inf overflow bucket.
        self.counts = np.zeros(len(self.bounds) + 1, dtype=np.int64)
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = make_lock("obs.series")

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def record(self, v: float) -> None:
        i = int(np.searchsorted(self.bounds, v, side="left"))
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def record_many(self, vs: Iterable[float]) -> None:
        a = np.asarray(list(vs) if not isinstance(vs, np.ndarray) else vs,
                       dtype=np.float64)
        if a.size == 0:
            return
        idx = np.searchsorted(self.bounds, a, side="left")
        add = np.bincount(idx, minlength=len(self.counts))
        with self._lock:
            self.counts += add.astype(np.int64)
            self.sum += float(a.sum())
            self.min = min(self.min, float(a.min()))
            self.max = max(self.max, float(a.max()))

    def quantile(self, q: float) -> float:
        """Smallest bucket upper bound whose CDF covers rank ceil(q*n).

        Matches ``np.quantile(data, q, method="inverted_cdf")`` exactly
        when every sample equals a bucket bound; otherwise overshoots by
        at most one bucket (documented resolution).
        """
        n = self.count
        if n == 0:
            return math.nan
        rank = max(1, int(math.ceil(q * n)))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank, side="left"))
        if i >= len(self.bounds):  # overflow bucket: best bound is max seen
            return self.max
        # Clip to observed extremes so tiny samples read back exactly.
        return float(min(max(self.bounds[i], self.min), self.max))

    def percentiles(self, qs=(0.50, 0.95, 0.99)) -> Dict[str, float]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def merge(self, other: "Histogram") -> None:
        if len(other.bounds) != len(self.bounds) or \
                not np.allclose(other.bounds, self.bounds):
            raise ValueError(f"histogram {self.name}: bounds mismatch")
        with self._lock:
            self.counts += other.counts
            self.sum += other.sum
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)

    def snapshot(self) -> dict:
        d = {"name": self.name, "labels": dict(self.labels),
             "count": self.count, "sum": self.sum,
             "min": None if math.isinf(self.min) else self.min,
             "max": None if math.isinf(self.max) else self.max,
             "bounds": [float(b) for b in self.bounds],
             "counts": [int(c) for c in self.counts]}
        d.update({k: (None if math.isnan(v) else v)
                  for k, v in self.percentiles().items()})
        return d


class MetricsRegistry:
    """Get-or-create store of labeled Counter/Gauge/Histogram series."""

    def __init__(self):
        self._lock = make_lock("obs.registry")
        self._metrics: Dict[Tuple[str, LabelKey], object] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, key[1], **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls) or (cls is Counter
                                      and isinstance(m, Gauge)):
            raise TypeError(f"metric {name} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: Optional[np.ndarray] = None,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def series(self, name: str) -> List[object]:
        """All series registered under ``name`` (any labels)."""
        with self._lock:
            return [m for (n, _), m in self._metrics.items() if n == name]

    def find(self, name: str, **labels):
        """Series under ``name`` whose labels contain ``labels``."""
        want = set(_label_key(labels))
        return [m for m in self.series(name)
                if want.issubset(set(m.labels))]

    def names(self) -> List[str]:
        with self._lock:
            return sorted({n for (n, _) in self._metrics})

    def metrics(self) -> List[object]:
        with self._lock:
            return list(self._metrics.values())

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry (e.g. a shard's) into this one."""
        for m in other.metrics():
            labels = dict(m.labels)
            if isinstance(m, Histogram):
                mine = self.histogram(m.name, bounds=m.bounds, **labels)
            elif isinstance(m, Gauge):
                mine = self.gauge(m.name, **labels)
            else:
                mine = self.counter(m.name, **labels)
            mine.merge(m)

    def snapshot(self) -> dict:
        out = {"counters": [], "gauges": [], "histograms": []}
        for m in self.metrics():
            kind = ("histograms" if isinstance(m, Histogram) else
                    "gauges" if isinstance(m, Gauge) else "counters")
            out[kind].append(m.snapshot())
        for v in out.values():
            v.sort(key=lambda d: (d["name"], sorted(d["labels"].items())))
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


#: The process-wide default registry.  Servers, engines and the jit trace
#: counter all record here unless handed an explicit registry, which is
#: what makes "benches scrape the same source serving reports" true.
REGISTRY = MetricsRegistry()
