"""Named locks with a repo-wide acquisition order (DESIGN.md §14).

Every lock in the serving/indexing/obs threading mesh is created through
:func:`make_lock` under a name from :data:`LOCK_RANKS`.  The name buys two
things:

* the static lock-order checker (``repro.analysis``, rule ``lock-order``)
  maps each ``with self._lock`` site to its rank and fails CI on any
  acquisition-graph cycle or rank inversion — AB/BA deadlocks are caught
  at lint time, before a scheduler ever interleaves them;
* with ``REPRO_LOCK_CHECK=1`` in the environment, :func:`make_lock`
  returns an :class:`OrderedLock` that asserts the same partial order at
  runtime: acquiring a lock whose rank is <= any rank the thread already
  holds raises immediately with both lock names.  The batcher/swap stress
  tests run under this sanitizer in CI.

The rank table is the authoritative partial order.  Lower rank = acquired
first (outermost).  Locks that are never held while acquiring another can
share neighborhood freely; the gaps leave room for new subsystems.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional

#: name -> rank.  An OrderedLock may only be acquired while every lock the
#: thread already holds has a *strictly smaller* rank.
LOCK_RANKS: Dict[str, int] = {
    "indexing.adapt": 10,       # IndexManager._adapt_lock (one rebuild)
    "batcher.queue": 20,        # CoalescingBatcher queue/condition
    "engine.swap": 30,          # SwappableEngine pin/swap pointer flip
    "batcher.ticket": 40,       # Ticket result scatter
    "workload.recorder": 50,    # WorkloadRecorder histogram
    "obs.profile": 55,          # CompileCapture record list
    "obs.registry": 60,         # MetricsRegistry series creation
    "obs.series": 70,           # Counter/Gauge/Histogram mutation (leaf)
    "obs.events": 80,           # EventLog ring + JSONL sink (leaf)
    "obs.spans": 85,            # TraceLog ring (leaf)
    "obs.sampler": 90,          # HeadSampler accumulator (leaf)
}


def lock_check_enabled() -> bool:
    """True when the runtime lock-order sanitizer is requested."""
    return os.environ.get("REPRO_LOCK_CHECK", "") == "1"


class LockOrderError(RuntimeError):
    """A thread acquired locks against the declared partial order."""


class _HeldStack(threading.local):
    def __init__(self) -> None:
        self.stack: List["OrderedLock"] = []


_HELD = _HeldStack()


class OrderedLock:
    """Debug lock asserting the :data:`LOCK_RANKS` partial order.

    Drop-in for ``threading.Lock`` (including as the lock behind a
    ``threading.Condition``: ``_is_owned`` is provided so the condition
    never probes ownership with a rank-checked ``acquire(0)``).  The
    thread-local held stack is shared across all OrderedLocks, so nesting
    across subsystems is checked, not just within one object.
    """

    def __init__(self, name: str):
        if name not in LOCK_RANKS:
            raise KeyError(f"lock name {name!r} has no declared rank "
                           f"(add it to repro.obs.locks.LOCK_RANKS)")
        self.name = name
        self.rank = LOCK_RANKS[name]
        self._lock = threading.Lock()
        self._owner: Optional[int] = None

    # ------------------------------------------------------------- protocol
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        held = _HELD.stack
        for h in held:
            if h.rank >= self.rank:
                raise LockOrderError(
                    f"lock-order violation: acquiring {self.name!r} "
                    f"(rank {self.rank}) while holding {h.name!r} "
                    f"(rank {h.rank}); declared order requires strictly "
                    "increasing ranks (see repro.obs.locks.LOCK_RANKS)")
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
            held.append(self)
        return got

    def release(self) -> None:
        self._owner = None
        # release in any order is legal; drop the newest matching entry
        stack = _HELD.stack
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                break
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def _is_owned(self) -> bool:
        """Condition-variable hook (avoids the ``acquire(0)`` probe)."""
        return self._owner == threading.get_ident()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: object) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"OrderedLock({self.name!r}, rank={self.rank})"


def make_lock(name: str):
    """A ``threading.Lock`` — or, under ``REPRO_LOCK_CHECK=1``, an
    :class:`OrderedLock` asserting ``name``'s declared rank.

    ``name`` must appear in :data:`LOCK_RANKS` (checked by the static
    analysis pass even when the sanitizer is off, so an unranked name
    fails CI rather than first failing in a debug run).
    """
    if lock_check_enabled():
        return OrderedLock(name)
    if name not in LOCK_RANKS:
        raise KeyError(f"lock name {name!r} has no declared rank "
                       f"(add it to repro.obs.locks.LOCK_RANKS)")
    return threading.Lock()


def held_locks() -> List[str]:
    """Names of OrderedLocks held by the calling thread (debug aid)."""
    return [h.name for h in _HELD.stack]
