"""Compile & cost attribution for the jit entry points (DESIGN.md §13).

Every device entry in ``core.packed`` is wrapped by a tiny dispatcher
(``packed._jit_entry``) that normally adds one attribute read per call.
When profiling is enabled (:func:`enable_profile`), the dispatcher routes
through a :class:`CompileCapture` which detects *traces* — the wrapped
``TraceCounter`` bumps a thread-local count at trace time, so a changed
count across the call means XLA compiled a new executable for this
(entry, shapes, statics) cache key — and attributes, per entry label:

* ``jit_compiles_total{entry=}`` / ``jit_compile_seconds_total{entry=}``
  — how many executables and how much wall time tracing+compiling cost;
* ``jit_cost_flops_total{entry=}`` / ``jit_cost_bytes_total{entry=}`` /
  ``jit_cost_output_bytes_total{entry=}`` — XLA ``cost_analysis()`` of
  the compiled executable (flops, bytes accessed, output bytes);
* ``jit_cost_capture_seconds_total{entry=}`` — what the capture itself
  cost (the AOT ``lower().compile()`` used to read ``cost_analysis()``
  does not populate jax's dispatch cache, so cost capture roughly
  doubles each *compile* — never steady-state dispatch).

All series land in an ordinary :class:`MetricsRegistry`, so the existing
Prometheus/JSON exporters pick them up with zero changes.

Caveats (see also ``benchmarks/roofline.py``): XLA's ``cost_analysis``
counts ``while``-loop bodies **once**, not per iteration, so looped
kernels under-report flops unless calibrated; on CPU the returned dict
may arrive as a one-element list.  Output bytes fall back to summing
``.nbytes`` over the result leaves when the backend omits the
``bytes accessedout{}`` key.

This module keeps **all jax imports function-local**: ``repro.obs`` must
stay importable without jax (the exporters run host-side), and
``core.packed`` imports obs for its trace counter — a module-level jax
or packed import here would cycle.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .locks import make_lock
from .metrics import MetricsRegistry, REGISTRY
from .timing import Stopwatch

# cost_analysis key names as emitted by XLA (CPU + TPU backends).
_K_FLOPS = "flops"
_K_BYTES = "bytes accessed"
_K_OUT_BYTES = "bytes accessedout{}"


@dataclass
class CompileRecord:
    """One observed trace+compile of a jit entry."""

    entry: str
    compile_s: float
    flops: float = 0.0
    bytes_accessed: float = 0.0
    output_bytes: float = 0.0
    capture_s: float = 0.0
    cost: dict = field(default_factory=dict)


def _leaf_nbytes(out) -> float:
    """Best-effort output-byte count: sum ``.nbytes`` over result leaves
    (duck-typed tree walk — no jax import needed)."""
    total = 0.0
    stack = [out]
    while stack:
        x = stack.pop()
        if x is None:
            continue
        nb = getattr(x, "nbytes", None)
        if nb is not None:
            total += float(nb)
        elif isinstance(x, dict):
            stack.extend(x.values())
        elif isinstance(x, (list, tuple)):
            stack.extend(x)
    return total


def normalize_cost(ca) -> dict:
    """Flatten a ``cost_analysis()`` result to a plain key->float dict.

    Handles the CPU backend's one-element-list wrapping and drops
    non-numeric values.
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k, v in dict(ca).items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            pass
    return out


class CompileCapture:
    """Routes profiled jit-entry calls and attributes compile cost.

    One instance is installed process-wide via :func:`enable_profile`
    (it becomes ``core.packed.TRACES.profiler``).  The capture is
    thread-safe: the trace detector reads the counter's *thread-local*
    count, so a background build thread compiling its own entries never
    credits a compile to a foreground serving call.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 costs: bool = True, max_records: int = 512):
        self.registry = REGISTRY if registry is None else registry
        self.costs = bool(costs)
        self.records: List[CompileRecord] = []
        self.max_records = int(max_records)
        self.counter = None          # TraceCounter, bound by enable_profile
        self._lock = make_lock("obs.profile")

    # -- dispatcher hook (called from packed._jit_entry wrappers) ---------

    def call(self, entry: str, jf, args, kw):
        c = self.counter
        before = -1 if c is None else c.thread_count()
        with Stopwatch() as sw:
            out = jf(*args, **kw)
        if c is not None and c.thread_count() != before:
            self._record(entry, jf, args, kw, sw.seconds, out)
        return out

    # -- attribution ------------------------------------------------------

    def _record(self, entry: str, jf, args, kw, compile_s: float,
                out) -> None:
        reg = self.registry
        reg.counter("jit_compiles_total", entry=entry).inc()
        reg.counter("jit_compile_seconds_total", entry=entry).inc(compile_s)
        rec = CompileRecord(entry=entry, compile_s=float(compile_s))
        if self.costs:
            with Stopwatch() as sw:
                try:
                    cost = normalize_cost(
                        jf.lower(*args, **kw).compile().cost_analysis())
                except Exception:            # pragma: no cover - backend gap
                    cost = {}
            rec.capture_s = sw.seconds
            rec.cost = cost
            rec.flops = cost.get(_K_FLOPS, 0.0)
            rec.bytes_accessed = cost.get(_K_BYTES, 0.0)
            rec.output_bytes = cost.get(_K_OUT_BYTES, 0.0)
            if rec.output_bytes == 0.0:
                rec.output_bytes = _leaf_nbytes(out)
            reg.counter("jit_cost_flops_total", entry=entry).inc(rec.flops)
            reg.counter("jit_cost_bytes_total",
                        entry=entry).inc(rec.bytes_accessed)
            reg.counter("jit_cost_output_bytes_total",
                        entry=entry).inc(rec.output_bytes)
            reg.counter("jit_cost_capture_seconds_total",
                        entry=entry).inc(rec.capture_s)
        with self._lock:
            if len(self.records) < self.max_records:
                self.records.append(rec)

    # -- readback ---------------------------------------------------------

    def by_entry(self) -> Dict[str, List[CompileRecord]]:
        with self._lock:
            recs = list(self.records)
        out: Dict[str, List[CompileRecord]] = {}
        for r in recs:
            out.setdefault(r.entry, []).append(r)
        return out

    def summary(self) -> dict:
        """Per-entry totals, JSON-ready (for bench artifacts)."""
        out = {}
        for entry, recs in sorted(self.by_entry().items()):
            out[entry] = {
                "compiles": len(recs),
                "compile_s": sum(r.compile_s for r in recs),
                "flops": sum(r.flops for r in recs),
                "bytes_accessed": sum(r.bytes_accessed for r in recs),
                "output_bytes": sum(r.output_bytes for r in recs),
                "capture_s": sum(r.capture_s for r in recs),
            }
        return out


def enable_profile(registry: Optional[MetricsRegistry] = None,
                   costs: bool = True,
                   capture: Optional[CompileCapture] = None
                   ) -> CompileCapture:
    """Install a :class:`CompileCapture` on ``core.packed.TRACES``.

    Returns the installed capture.  Must run before the first call of
    the shapes you want attributed: jax's jit cache is process-wide, so
    an entry traced before capture was enabled stays warm and silent.
    """
    from repro.core.packed import TRACES   # lazy: obs stays jax-free
    cap = capture if capture is not None \
        else CompileCapture(registry=registry, costs=costs)
    cap.counter = TRACES
    TRACES.profiler = cap
    return cap


def disable_profile() -> Optional[CompileCapture]:
    """Uninstall the active capture (returns it, or None)."""
    from repro.core.packed import TRACES
    cap, TRACES.profiler = TRACES.profiler, None
    return cap


class profiled:
    """Context manager: profile capture enabled inside the block."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 costs: bool = True):
        self._registry = registry
        self._costs = costs
        self.capture: Optional[CompileCapture] = None

    def __enter__(self) -> CompileCapture:
        self.capture = enable_profile(self._registry, costs=self._costs)
        return self.capture

    def __exit__(self, *exc) -> None:
        disable_profile()


def aot_cost(fn, *args, static_argnames=None, **kw) -> dict:
    """AOT-compile ``fn`` on ``args`` and return its normalized
    ``cost_analysis()`` dict (``flops`` / ``bytes accessed`` / ...).

    Standalone helper for benches — does not touch the dispatch cache
    or the installed capture.
    """
    import jax                              # lazy: obs stays jax-free
    jit_kw = {}
    if static_argnames is not None:
        jit_kw["static_argnames"] = static_argnames
    jf = fn if hasattr(fn, "lower") else jax.jit(fn, **jit_kw)  # repolint: disable=jit-registry -- aot_cost probes arbitrary callables offline
    return normalize_cost(jf.lower(*args, **kw).compile().cost_analysis())
