"""Request-lifecycle spans for the serving stack.

A ``Trace`` is one request's closed span tree: a root span (submit →
reply) plus one child span per pipeline stage.  Stage boundaries are the
timestamps the batcher already takes for its own accounting, so the stage
durations *telescope*: their sum equals the end-to-end latency exactly
(modulo float rounding), which is what makes the "attribution sums to
e2e within 5%" acceptance gate structural rather than statistical.

Async stage taxonomy (``ASYNC_STAGES``, in pipeline order):

==============  ========================================================
admission       ``submit()`` entry → admitted past the backpressure gate
queue_wait      admitted → group launch (includes any requeue laps)
stage           host routing + cross-shard gathers (``eng.stage``)
dispatch        staged → device program issued (``dispatch_staged``)
pipeline_wait   dispatched → retire loop turns to this flight
device_join     ``block_until_ready`` wait — the device-time attribution
rescue          quantized argmin residual rescue (engine-reported; 0 when
                the layout is exact or rescue is fused into dispatch)
unwind          path unwinding (async replies are distance/argmin only,
                so 0 here; the sync ``query_paths`` span fills it)
reply           scatter results to tickets + stats bookkeeping
==============  ========================================================

Sync queries (``PathServer.query``/``query_paths``) reuse the same trace
type with ``SYNC_STAGES`` (route → dispatch → rescue → unwind → reply).

The offline build pipeline reuses the same type with ``BUILD_STAGES``
(plan → compress → repack → validate → stage → swap): one trace per
``IndexManager`` adaptation attempt, stage boundaries taken from a
single shared stopwatch so the stages telescope to the end-to-end build
wall time exactly — including the thread handoff of an async swap,
which lands inside the ``compress`` lap rather than leaking out of the
span tree.

Head sampling: the submit path decides *once per request* whether to
build a trace (deterministic leaky-bucket at ``sample_rate`` — no RNG, so
tests and resumable workflows see stable picks).  Requests slower than
``slow_ms`` are traced retroactively at retire time from the group
timestamps, so tail outliers always land in the ring regardless of rate.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

from .locks import make_lock

ASYNC_STAGES: Tuple[str, ...] = (
    "admission", "queue_wait", "stage", "dispatch", "pipeline_wait",
    "device_join", "rescue", "unwind", "reply")

SYNC_STAGES: Tuple[str, ...] = (
    "route", "dispatch", "rescue", "unwind", "reply")

BUILD_STAGES: Tuple[str, ...] = (
    "plan", "compress", "repack", "validate", "stage", "swap")

STAGE_TAXONOMY: Dict[str, Tuple[str, ...]] = {
    "async": ASYNC_STAGES,
    "sync": SYNC_STAGES,
    "build": BUILD_STAGES,
}


class Span:
    """One named interval; ``t0`` is relative to the trace root (s)."""

    __slots__ = ("name", "t0", "seconds")

    def __init__(self, name: str, t0: float, seconds: float):
        self.name = name
        self.t0 = t0
        self.seconds = seconds

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "seconds": self.seconds}


class Trace:
    """A closed span tree for one request."""

    __slots__ = ("kind", "stages", "attrs", "t_start", "t_end", "closed")

    def __init__(self, kind: str = "async", **attrs):
        self.kind = kind
        self.stages: Dict[str, float] = {}
        self.attrs: dict = attrs
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.closed = False

    def stage(self, name: str, seconds: float) -> None:
        self.stages[name] = self.stages.get(name, 0.0) + float(seconds)

    def close(self, t_start: float, t_end: float,
              outcome: str = "ok") -> "Trace":
        self.t_start = t_start
        self.t_end = t_end
        self.attrs["outcome"] = outcome
        self.closed = True
        return self

    @property
    def e2e_seconds(self) -> float:
        if self.t_start is None or self.t_end is None:
            return 0.0
        return self.t_end - self.t_start

    @property
    def stage_sum(self) -> float:
        return sum(self.stages.values())

    def complete(self, required=None) -> bool:
        req = STAGE_TAXONOMY.get(self.kind, SYNC_STAGES) \
            if required is None else required
        return self.closed and all(s in self.stages for s in req)

    def tree(self) -> dict:
        """Root span with one child per stage, in taxonomy order."""
        order = STAGE_TAXONOMY.get(self.kind, SYNC_STAGES)
        names = [s for s in order if s in self.stages] + \
            [s for s in self.stages if s not in order]
        t, children = 0.0, []
        for name in names:
            dur = self.stages[name]
            children.append(Span(name, t, dur).to_dict())
            t += dur
        return {"name": f"request/{self.kind}", "t0": 0.0,
                "seconds": self.e2e_seconds, "attrs": dict(self.attrs),
                "closed": self.closed, "children": children}

    def to_dict(self) -> dict:
        return self.tree()


class HeadSampler:
    """Deterministic leaky-bucket head sampler with a slow-path override.

    ``sample()`` is called at admission; ``slow(e2e_s)`` at retire for
    requests that were not head-sampled.  Rate 0 disables head sampling
    entirely (slow-path tracing still applies unless ``slow_ms`` is 0).
    """

    def __init__(self, rate: float = 0.05, slow_ms: float = 50.0):
        self.rate = float(rate)
        self.slow_ms = float(slow_ms)
        self._acc = 0.0
        self._lock = make_lock("obs.sampler")

    def sample(self) -> bool:
        if self.rate <= 0.0:
            return False
        with self._lock:
            self._acc += self.rate
            if self._acc >= 1.0:
                self._acc -= 1.0
                return True
        return False

    def slow(self, e2e_seconds: float) -> bool:
        return self.slow_ms > 0.0 and e2e_seconds * 1e3 >= self.slow_ms


class TraceLog:
    """Bounded ring of closed traces (newest kept)."""

    def __init__(self, capacity: int = 1024):
        self._ring: deque = deque(maxlen=int(capacity))
        self._lock = make_lock("obs.spans")
        self.recorded = 0

    def add(self, trace: Trace) -> None:
        with self._lock:
            self._ring.append(trace)
            self.recorded += 1

    def traces(self, kind: Optional[str] = None) -> List[Trace]:
        with self._lock:
            ts = list(self._ring)
        return ts if kind is None else [t for t in ts if t.kind == kind]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
