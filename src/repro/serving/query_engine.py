"""Pluggable query backends behind one ``QueryEngine`` interface.

The serving layer (``PathServer``) is backend-agnostic: it routes batches,
keeps stats and scatters results; *how* a batch is answered is an engine
(DESIGN.md §6).  Three interchangeable backends:

* :class:`HostEngine`   — the scalar float64 oracle (``repro.core.query``);
  slow, exact, the reference everything else is validated against.
* :class:`JnpEngine`    — batched XLA engine over a packed layout, pure-jnp
  ops (the production path on CPU/GPU).
* :class:`PallasEngine` — same engine routed through the Pallas TPU kernels
  (interpret mode off-TPU, so the kernel bodies run everywhere).

The device engines accept either packed layout: the single-slab
``PackedIndex`` (one bucket) or the width-bucketed ``BucketedIndex``
(per-bucket jit entries, ``buckets_of`` exposes the routing key).  All three
share the distance/join core in ``repro.core.packed`` — the argmin (path
unwinding) variant is the same code path with a flag, not a fork.
"""

from __future__ import annotations

import abc
import contextlib

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.grid import EHLIndex
from repro.core.packed import (BucketedIndex, LAYOUT_F32, PackedIndex,
                               gather_masked_exact, join_masked,
                               pack_bucketed, query_batch,
                               query_batch_argmin, query_batch_at_bucket,
                               rescue_exact, splice_rescue)
from repro.core.query import query as host_query


class QueryEngine(abc.ABC):
    """Answer batches of ESPP queries; optionally bucket-routable.

    ``bucket`` arguments index the engine's dispatch buckets; engines with a
    single bucket (host oracle, single-slab) ignore them.  ``batch`` returns
    [B] float32 distances; ``batch_argmin`` additionally returns the winning
    (covis, via_s, hub, via_t) ids for host-side path unwinding.
    """

    name: str = "abstract"
    static_shapes = False   # True: batches must be padded to a fixed size
    generation = 0          # bumped by hot-swapping engines (repro.indexing)

    @contextlib.contextmanager
    def pin(self):
        """Pin a consistent engine for a multi-call request.

        ``PathServer`` routes one request through several engine calls
        (``buckets_of`` + one ``batch`` per bucket group); under a
        hot-swapping engine (``repro.indexing.SwappableEngine``) those calls
        must all hit the *same* artifact — bucket ids are meaningless across
        generations.  Static engines just yield themselves; swappable
        engines yield the pinned generation's engine and keep its device
        buffers alive until every pin drains.
        """
        yield self

    def buckets_of(self, s, t) -> np.ndarray:
        """[B] dispatch bucket per query (0 for single-bucket engines)."""
        return np.zeros(len(s), dtype=np.int32)

    @abc.abstractmethod
    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        ...

    def batch_argmin(self, s, t, bucket: int = 0):
        raise NotImplementedError(f"{self.name} has no argmin path")

    # -------------------------------------------- split-phase (async) path
    def stage(self, s, t, bucket: int = 0):
        """Begin host->device staging for one padded batch; returns an
        opaque handle for :meth:`dispatch_staged`.

        The continuous batcher (``serving.batcher``) stages batch N+1 while
        batch N computes, so transfers (and, under sharding, cross-shard
        label gathers) overlap device compute.  Default: pass-through."""
        return (s, t)

    def dispatch_staged(self, staged, bucket: int = 0,
                        want_argmin: bool = False) -> tuple:
        """Dispatch a staged batch WITHOUT synchronizing.

        Returns a tuple of result arrays (1 without argmin, 5 with) that
        may still be computing on device — the caller owns
        ``block_until_ready``, which is what lets the batcher overlap the
        next group's staging with this group's compute."""
        s, t = staged
        if want_argmin:
            return tuple(self.batch_argmin(s, t, bucket=bucket))
        return (self.batch(s, t, bucket=bucket),)

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        pass

    def device_bytes(self) -> int:
        return 0


class HostEngine(QueryEngine):
    """Scalar float64 oracle looped over the batch — exact, no device state."""

    name = "host"

    def __init__(self, index: EHLIndex):
        self.index = index

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return np.array([host_query(self.index, si, ti, want_path=False)[0]
                         for si, ti in zip(s, t)], dtype=np.float32)

    def paths(self, s, t) -> list:
        return [host_query(self.index, si, ti, want_path=True)[1]
                for si, ti in zip(s, t)]


class DeviceEngine(QueryEngine):
    """Batched XLA engine over a packed layout (jnp ops or Pallas kernels)."""

    use_kernels = False
    static_shapes = True    # jitted: pad batches so shapes never recompile

    def __init__(self, index, layout=LAYOUT_F32):
        if isinstance(index, EHLIndex):
            index = pack_bucketed(index, layout=layout)
        if not isinstance(index, (PackedIndex, BucketedIndex)):
            raise TypeError(f"unsupported index artifact: {type(index)!r}")
        self.index = index
        self.quantized = index.layout.quantized
        self.bucketed = isinstance(index, BucketedIndex)
        if self.bucketed:
            # host-side routing table mirrors (see buckets_of): admission-
            # path routing must not pay a per-call eager-jnp dispatch chain
            self._np_mapper = np.asarray(index.mapper)
            self._np_bucket = np.asarray(index.region_bucket)

    @property
    def num_buckets(self) -> int:
        return self.index.num_buckets if self.bucketed else 1

    def bucket_width(self, bucket: int) -> int:
        return (self.index.widths[bucket] if self.bucketed
                else self.index.label_width)

    def _route(self, pts) -> np.ndarray:
        """Host-numpy mirror of ``locate_regions`` -> bucket (same float32
        floor-divide, so cell ids agree with the device gathers bit-for-bit
        — the ShardRouter routes with the identical construction).  Runs on
        the submit path of the continuous batcher, where the eager per-op
        dispatch of ``dispatch_buckets`` would dominate admission cost."""
        p = np.asarray(pts, np.float32)
        cs = np.float32(self.index.cell_size)
        ix = np.clip((p[:, 0] / cs).astype(np.int32), 0, self.index.nx - 1)
        iy = np.clip((p[:, 1] / cs).astype(np.int32), 0, self.index.ny - 1)
        return self._np_bucket[self._np_mapper[iy * self.index.nx + ix]]

    def buckets_of(self, s, t) -> np.ndarray:
        if not self.bucketed:
            return np.zeros(len(s), dtype=np.int32)
        return np.maximum(self._route(s), self._route(t)).astype(np.int32)

    def _run(self, s, t, bucket: int, want_argmin: bool):
        s = jnp.asarray(s, jnp.float32)
        t = jnp.asarray(t, jnp.float32)
        if self.bucketed:
            return query_batch_at_bucket(self.index, s, t, bucket=bucket,
                                         use_kernels=self.use_kernels,
                                         want_argmin=want_argmin)
        fn = query_batch_argmin if want_argmin else query_batch
        return fn(self.index, s, t, use_kernels=self.use_kernels)

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return self._run(s, t, bucket, want_argmin=False)

    def batch_argmin(self, s, t, bucket: int = 0):
        res = self._run(s, t, bucket, want_argmin=True)
        if not self.quantized:
            return res
        # quantized: 6-tuple — rescue ambiguous-margin rows against the
        # exact residual so argmin winners match the f32 engine bitwise
        # repolint: disable=hot-path-sync -- documented rescue trigger: one flag word, the exactness contract pays this sync
        if bool(np.asarray(res[5]).any()):
            with obs.Stopwatch() as sw:
                exact = rescue_exact(self.index, s, t,
                                     self.bucket_width(bucket), res[1],
                                     use_kernels=self.use_kernels)
                out = splice_rescue(res, exact)
            # argmin-rescue attribution (DESIGN.md §12): the rescue is
            # fused into the dispatch stage from the span's point of view,
            # so its cost is surfaced through these engine-side series
            obs.REGISTRY.counter("rescue_batches_total",
                                 engine=self.name).inc()
            obs.REGISTRY.histogram("rescue_ms", engine=self.name).record(
                sw.seconds * 1e3)
            return out
        # repolint: disable=hot-path-sync -- batch_argmin is the synchronous API; host results are its contract
        return tuple(np.asarray(r) for r in res[:5])

    def stage(self, s, t, bucket: int = 0):
        """Start the host->device copies for a batch (jax transfers are
        async; on accelerators the DMA overlaps the in-flight batch)."""
        return (jnp.asarray(s, jnp.float32), jnp.asarray(t, jnp.float32))

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        """Trace every per-bucket jit entry once with the serving shape.

        ``want_argmin=True`` additionally traces the argmin (path
        extraction) entries — they are separate jit cache entries, so
        without this the first ``query_paths`` batch pays XLA compile
        inside the timed serving loop.
        """
        z = jnp.zeros((batch_size, 2), jnp.float32)
        for b in range(self.num_buckets):
            self._run(z, z, b, want_argmin=False).block_until_ready()
            if want_argmin:
                jax.block_until_ready(self._run(z, z, b, want_argmin=True))
                if self.quantized:
                    # the rescue path's entries (exact gather + plain
                    # argmin join) are their own jit cache entries
                    W = self.bucket_width(b)
                    d0 = jnp.full((batch_size, W), jnp.inf, jnp.float32)
                    ms = gather_masked_exact(self.index, z, d0, W,
                                             use_kernels=self.use_kernels)
                    jax.block_until_ready(join_masked(
                        ms, ms, z, z, jnp.zeros(batch_size, bool),
                        use_kernels=self.use_kernels, want_argmin=True))

    def device_bytes(self) -> int:
        return self.index.device_bytes()


class JnpEngine(DeviceEngine):
    name = "jnp"
    use_kernels = False


class PallasEngine(DeviceEngine):
    name = "pallas"
    use_kernels = True


def make_engine(index, backend: str = "jnp",
                layout=LAYOUT_F32) -> QueryEngine:
    """Engine factory.  ``index``: EHLIndex (host backend, or auto-packed
    bucketed for device backends), PackedIndex, or BucketedIndex.
    ``layout`` picks the slab dtypes when auto-packing (DESIGN.md §11)."""
    if backend == "host":
        if not isinstance(index, EHLIndex):
            raise TypeError("host backend needs the host-side EHLIndex")
        return HostEngine(index)
    if backend == "jnp":
        return JnpEngine(index, layout=layout)
    if backend == "pallas":
        return PallasEngine(index, layout=layout)
    raise ValueError(f"unknown backend {backend!r} "
                     "(expected host | jnp | pallas)")
