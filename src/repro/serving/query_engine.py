"""Pluggable query backends behind one ``QueryEngine`` interface.

The serving layer (``PathServer``) is backend-agnostic: it routes batches,
keeps stats and scatters results; *how* a batch is answered is an engine
(DESIGN.md §6).  Three interchangeable backends:

* :class:`HostEngine`   — the scalar float64 oracle (``repro.core.query``);
  slow, exact, the reference everything else is validated against.
* :class:`JnpEngine`    — batched XLA engine over a packed layout, pure-jnp
  ops (the production path on CPU/GPU).
* :class:`PallasEngine` — same engine routed through the Pallas TPU kernels
  (interpret mode off-TPU, so the kernel bodies run everywhere).

The device engines accept either packed layout: the single-slab
``PackedIndex`` (one bucket) or the width-bucketed ``BucketedIndex``
(per-bucket jit entries, ``buckets_of`` exposes the routing key).  All three
share the distance/join core in ``repro.core.packed`` — the argmin (path
unwinding) variant is the same code path with a flag, not a fork.
"""

from __future__ import annotations

import abc
import contextlib

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.grid import EHLIndex
from repro.core.packed import (BucketedIndex, PackedIndex, pack_bucketed,
                               query_batch, query_batch_argmin,
                               query_batch_at_bucket, dispatch_buckets)
from repro.core.query import query as host_query


class QueryEngine(abc.ABC):
    """Answer batches of ESPP queries; optionally bucket-routable.

    ``bucket`` arguments index the engine's dispatch buckets; engines with a
    single bucket (host oracle, single-slab) ignore them.  ``batch`` returns
    [B] float32 distances; ``batch_argmin`` additionally returns the winning
    (covis, via_s, hub, via_t) ids for host-side path unwinding.
    """

    name: str = "abstract"
    static_shapes = False   # True: batches must be padded to a fixed size
    generation = 0          # bumped by hot-swapping engines (repro.indexing)

    @contextlib.contextmanager
    def pin(self):
        """Pin a consistent engine for a multi-call request.

        ``PathServer`` routes one request through several engine calls
        (``buckets_of`` + one ``batch`` per bucket group); under a
        hot-swapping engine (``repro.indexing.SwappableEngine``) those calls
        must all hit the *same* artifact — bucket ids are meaningless across
        generations.  Static engines just yield themselves; swappable
        engines yield the pinned generation's engine and keep its device
        buffers alive until every pin drains.
        """
        yield self

    def buckets_of(self, s, t) -> np.ndarray:
        """[B] dispatch bucket per query (0 for single-bucket engines)."""
        return np.zeros(len(s), dtype=np.int32)

    @abc.abstractmethod
    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        ...

    def batch_argmin(self, s, t, bucket: int = 0):
        raise NotImplementedError(f"{self.name} has no argmin path")

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        pass

    def device_bytes(self) -> int:
        return 0


class HostEngine(QueryEngine):
    """Scalar float64 oracle looped over the batch — exact, no device state."""

    name = "host"

    def __init__(self, index: EHLIndex):
        self.index = index

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return np.array([host_query(self.index, si, ti, want_path=False)[0]
                         for si, ti in zip(s, t)], dtype=np.float32)

    def paths(self, s, t) -> list:
        return [host_query(self.index, si, ti, want_path=True)[1]
                for si, ti in zip(s, t)]


class DeviceEngine(QueryEngine):
    """Batched XLA engine over a packed layout (jnp ops or Pallas kernels)."""

    use_kernels = False
    static_shapes = True    # jitted: pad batches so shapes never recompile

    def __init__(self, index):
        if isinstance(index, EHLIndex):
            index = pack_bucketed(index)
        if not isinstance(index, (PackedIndex, BucketedIndex)):
            raise TypeError(f"unsupported index artifact: {type(index)!r}")
        self.index = index
        self.bucketed = isinstance(index, BucketedIndex)

    @property
    def num_buckets(self) -> int:
        return self.index.num_buckets if self.bucketed else 1

    def bucket_width(self, bucket: int) -> int:
        return (self.index.widths[bucket] if self.bucketed
                else self.index.label_width)

    def buckets_of(self, s, t) -> np.ndarray:
        if not self.bucketed:
            return np.zeros(len(s), dtype=np.int32)
        return dispatch_buckets(self.index, s, t)

    def _run(self, s, t, bucket: int, want_argmin: bool):
        s = jnp.asarray(s, jnp.float32)
        t = jnp.asarray(t, jnp.float32)
        if self.bucketed:
            return query_batch_at_bucket(self.index, s, t, bucket=bucket,
                                         use_kernels=self.use_kernels,
                                         want_argmin=want_argmin)
        fn = query_batch_argmin if want_argmin else query_batch
        return fn(self.index, s, t, use_kernels=self.use_kernels)

    def batch(self, s, t, bucket: int = 0) -> np.ndarray:
        return self._run(s, t, bucket, want_argmin=False)

    def batch_argmin(self, s, t, bucket: int = 0):
        return self._run(s, t, bucket, want_argmin=True)

    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        """Trace every per-bucket jit entry once with the serving shape.

        ``want_argmin=True`` additionally traces the argmin (path
        extraction) entries — they are separate jit cache entries, so
        without this the first ``query_paths`` batch pays XLA compile
        inside the timed serving loop.
        """
        z = jnp.zeros((batch_size, 2), jnp.float32)
        for b in range(self.num_buckets):
            self._run(z, z, b, want_argmin=False).block_until_ready()
            if want_argmin:
                jax.block_until_ready(self._run(z, z, b, want_argmin=True))

    def device_bytes(self) -> int:
        return self.index.device_bytes()


class JnpEngine(DeviceEngine):
    name = "jnp"
    use_kernels = False


class PallasEngine(DeviceEngine):
    name = "pallas"
    use_kernels = True


def make_engine(index, backend: str = "jnp") -> QueryEngine:
    """Engine factory.  ``index``: EHLIndex (host backend, or auto-packed
    bucketed for device backends), PackedIndex, or BucketedIndex."""
    if backend == "host":
        if not isinstance(index, EHLIndex):
            raise TypeError("host backend needs the host-side EHLIndex")
        return HostEngine(index)
    if backend == "jnp":
        return JnpEngine(index)
    if backend == "pallas":
        return PallasEngine(index)
    raise ValueError(f"unknown backend {backend!r} "
                     "(expected host | jnp | pallas)")
