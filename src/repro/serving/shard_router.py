"""(shard, bucket) batch routing over a region-sharded index.

The serving half of ``repro.sharding`` (DESIGN.md §9): a
:class:`~repro.sharding.planner.ShardedIndex` keeps each shard's bucket
slabs — and its *clipped* edge subset + edge grid (§10) — on its own mesh
device; the router turns an incoming query batch into per-(shard-pair,
width) sub-batches and merges the answers back in input order.

Routing path per query (all host-side numpy, O(1) per endpoint):

1. locate both endpoints' cells (same float32 floor-divide the device
   engines jit — bit-identical cell ids);
2. the routing table maps each cell to ``(shard, bucket width)``;
3. the composite key ``(shard_s, shard_t, join width)`` groups the batch.

Dispatch per group — edges are clipped per shard, so each visibility term
runs where its covering edge subset lives:

* each endpoint side gathers its label rows *and folds in via visibility*
  on its owning device (``gather_masked_labels`` — the owner's clip covers
  every query-point -> via segment of regions it owns); for a cross-shard
  query the t-side ``(hub, vd, vid)`` triple ships to the s-side device
  (``jax.device_put``, [B, W]-sized — the slabs never move);
* the direct s->t co-visibility segment can cross *any* shard's territory,
  so every shard whose owned bounding box meets the batch's bounding box
  answers against its local edges and the [B] verdicts are OR-merged on
  the s-side device (the participating clips jointly cover every edge the
  segment can cross);
* the join (``join_masked``) runs on the s-side device.

All three pieces are the same distance/join core the single-device engine
compiles, so answers are bitwise-identical to the unsharded
``BucketedIndex`` engine.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.packed import (covis_blocked, dequant_masked_labels,
                               gather_masked_exact, gather_masked_labels,
                               gather_quant_rows, join_masked)
from repro.launch.mesh import shard_devices


@dataclasses.dataclass
class StagedGroup:
    """One routed sub-batch with every pre-join transfer already dispatched.

    Produced by :meth:`ShardRouter.stage`, consumed by
    :meth:`ShardRouter.join_staged`.  Splitting the phases is what lets the
    continuous batcher overlap group N+1's host->device copies, cross-shard
    label gathers and co-visibility verdicts with group N's join — the
    serialize-every-group behavior of the old monolithic ``dispatch``."""
    key: int
    i: int                  # s-side (join/home) shard
    j: int                  # t-side shard
    parts: list             # covis participant shards
    masked_s: tuple         # visibility-folded (hub, vd, vid), home device
    masked_t: tuple         # same for the t side, shipped to home device
    covis: object           # merged co-visibility bits, home device
    s_dev: object           # [B, 2] batch on the home device
    t_dev: object


class ShardRouter:
    """Split batches by destination shard, dispatch, merge in input order."""

    def __init__(self, sharded, mesh=None, use_kernels: bool = False):
        self.sharded = sharded
        self.use_kernels = use_kernels
        self.num_shards = sharded.num_shards
        self.devices = shard_devices(mesh, self.num_shards)
        # one device_put per shard: the slabs live on their mesh device for
        # the artifact's whole generation; queries are the only per-request
        # transfers.  Leaves already committed to the right device (the
        # hot-swap path aliases the previous router's placed edge tensors)
        # pass through without a copy.
        self.shards = []
        for bx, dev in zip(sharded.shards, self.devices):
            placed = jax.device_put(bx, dev)
            # the ResidualTable is host-side state excluded from the pytree,
            # so device_put drops it — re-attach for the argmin rescue
            placed.residual = bx.residual
            self.shards.append(placed)
        self.quantized = bool(self.shards
                              and self.shards[0].layout.quantized)
        # per-shard quantization error bounds, host floats: join_staged sums
        # the two sides' bounds into the argmin ambiguity threshold
        self._qerr = [float(np.asarray(bx.qerr)) if bx.qerr is not None
                      else 0.0 for bx in sharded.shards]
        self.width_classes = np.asarray(sharded.width_classes, np.int64)
        self._nw = len(self.width_classes)
        # per-shard clip bound: foreign/padding cells can carry local ids
        # from wider shards; clipping keeps the (discarded) gather in range
        self._rmax = np.array([max(0, bx.num_regions - 1)
                               for bx in self.shards], dtype=np.int32)
        # covis participation: slack-dilated owned rects (host side)
        self._rects = np.asarray(sharded.shard_rects, np.float64)
        self._covis_slack = 1e-3 * float(
            max(self.sharded.shards[0].width, self.sharded.shards[0].height))
        # cross-shard traffic attribution (DESIGN.md §12): per-router
        # labeled series in the process-wide registry — one stage-phase
        # wall-time histogram plus wire-row counters per (src, dst) pair
        self._obs_labels = {"router": obs.next_instance_id("r")}
        self._stage_ms = obs.REGISTRY.histogram("router_stage_ms",
                                                **self._obs_labels)

    # ------------------------------------------------------------- routing
    def _cells(self, pts: np.ndarray) -> np.ndarray:
        """Float32 floor-divide cell location — mirrors ``locate_regions``
        bit-for-bit so host routing and device gathers agree."""
        p = np.asarray(pts, np.float32)  # repolint: disable=hot-path-sync -- host routing math on host inputs, no device value involved
        cs = np.float32(self.sharded.cell_size)
        ix = np.clip((p[:, 0] / cs).astype(np.int32), 0, self.sharded.nx - 1)
        iy = np.clip((p[:, 1] / cs).astype(np.int32), 0, self.sharded.ny - 1)
        return iy * self.sharded.nx + ix

    def route_keys(self, s, t) -> np.ndarray:
        """[B] composite routing keys ``(shard_s, shard_t, width-class)``."""
        cs, ct = self._cells(s), self._cells(t)
        sh_s = self.sharded.cell_shard[cs].astype(np.int64)
        sh_t = self.sharded.cell_shard[ct].astype(np.int64)
        w = np.maximum(self.sharded.cell_width[cs],
                       self.sharded.cell_width[ct])
        wc = np.searchsorted(self.width_classes, w)
        return ((sh_s * self.num_shards + sh_t) * self._nw + wc
                ).astype(np.int32)

    def decode_key(self, key: int) -> tuple:
        """key -> (shard_s, shard_t, join width)."""
        key = int(key)
        wc = key % self._nw
        pair = key // self._nw
        return (pair // self.num_shards, pair % self.num_shards,
                int(self.width_classes[wc]))

    def key_width(self, key: int) -> int:
        return int(self.width_classes[int(key) % self._nw])

    # ------------------------------------------------------------ dispatch
    def _locals(self, cells: np.ndarray, shard: int) -> jnp.ndarray:
        ids = np.minimum(self.sharded.cell_local[cells], self._rmax[shard])
        # one host->device transfer straight onto the gathering shard (a
        # detour through the default device would double the traffic)
        return jax.device_put(ids, self.devices[shard])

    def covis_shards(self, s: np.ndarray, t: np.ndarray) -> list:
        """Shards whose owned rect meets the batch's bounding box.

        Any edge the direct s->t segments can cross sits in a cell one of
        these shards owns, hence inside that shard's clipped edge subset.

        Zero-pair rows — both endpoints exactly the origin — are the tail
        padding serving batches carry; they are excluded from the bbox so
        padded batches don't drag every shard below/left of the batch into
        the covis test.  Safe even for a *real* (0,0)->(0,0) query: a
        degenerate segment can never fire a §5 rule, so its covis bit is
        correct under any participant set.
        """
        real = np.any(s != 0.0, axis=1) | np.any(t != 0.0, axis=1)
        if not real.any():
            return []
        pts = np.concatenate([s[real], t[real]], axis=0)
        lo = pts.min(axis=0) - self._covis_slack
        hi = pts.max(axis=0) + self._covis_slack
        r = self._rects
        hit = ((r[:, 0] <= hi[0]) & (r[:, 2] >= lo[0]) &
               (r[:, 1] <= hi[1]) & (r[:, 3] >= lo[1]))
        return [int(k) for k in np.nonzero(hit)[0]]

    def _covis(self, s_at, t_at, parts: list, home: int):
        """Merged co-visibility bits on the home device.

        The per-shard verdicts are all dispatched before the OR loop
        blocks on any of them, so participating devices compute in
        parallel.  ``s_at``/``t_at`` are the dispatch-level per-device
        batch caches.
        """
        dev = self.devices[home]
        verdicts = []
        for k in parts:
            bx = self.shards[k]
            verdicts.append(covis_blocked(
                s_at(k), t_at(k),
                bx.edges_a, bx.edges_b, bx.edges_c, bx.grid,
                use_kernels=self.use_kernels))
        blocked = None
        for bk in verdicts:
            bk = jax.device_put(bk, dev)
            blocked = bk if blocked is None else blocked | bk
        return blocked == 0

    def stage(self, s, t, key: int) -> StagedGroup:
        """Dispatch every pre-join transfer for one routed sub-batch.

        Ships the batch to each involved device, gathers + visibility-folds
        both endpoint sides on their owning shards, moves the t-side triple
        to the home device for cross-shard keys, and launches the covis
        verdicts — all asynchronously.  Nothing here blocks, so a staged
        group can overlap an in-flight group's join.
        """
        t_stage0 = time.perf_counter()
        i, j, W = self.decode_key(key)
        # repolint: disable=hot-path-sync -- normalizes host inputs before the H2D enqueue; nothing lives on device yet
        s = np.asarray(s, np.float32)
        t = np.asarray(t, np.float32)  # repolint: disable=hot-path-sync -- same host-input normalization as the line above
        cs, ct = self._cells(s), self._cells(t)
        dev = self.devices[i]

        # one host->device transfer of each batch side per involved device,
        # shared by the gathers, the covis participants, and the join
        s_on, t_on = {}, {}

        def s_at(k):
            if k not in s_on:
                s_on[k] = jax.device_put(s, self.devices[k])
            return s_on[k]

        def t_at(k):
            if k not in t_on:
                t_on[k] = jax.device_put(t, self.devices[k])
            return t_on[k]

        masked_s = gather_masked_labels(
            self.shards[i], self._locals(cs, i), s_at(i), W,
            use_kernels=self.use_kernels)
        if i != j and self.quantized:
            # quantized wire: ship the *encoded* t-side rows (u16 ids +
            # narrow distances + vis bits, ~7 B/slot vs 12) and decode on
            # the home device — same fold expression, bitwise-identical
            wire = gather_quant_rows(
                self.shards[j], self._locals(ct, j), t_at(j), W,
                use_kernels=self.use_kernels)
            wire = jax.device_put(wire, dev)
            masked_t = dequant_masked_labels(*wire, t_at(i),
                                             self.shards[i].vert_xy)
        else:
            masked_t = gather_masked_labels(
                self.shards[j], self._locals(ct, j), t_at(j), W,
                use_kernels=self.use_kernels)
            if i != j:
                # ship the masked [B, W] label triple, not the slabs
                masked_t = jax.device_put(masked_t, dev)
        parts = self.covis_shards(s, t) or [i]
        covis = self._covis(s_at, t_at, parts, i)
        if i != j:
            # wire-row attribution: [B, W] t-side rows shipped j -> i
            obs.REGISTRY.counter(
                "router_wire_rows_total", src=j, dst=i,
                wire="quant" if self.quantized else "f32",
                **self._obs_labels).inc(len(s) * W)
        self._stage_ms.record((time.perf_counter() - t_stage0) * 1e3)
        return StagedGroup(key=int(key), i=i, j=j, parts=parts,
                           masked_s=masked_s, masked_t=masked_t,
                           covis=covis, s_dev=s_at(i), t_dev=t_at(i))

    def join_staged(self, st: StagedGroup, want_argmin: bool = False):
        """Run the Eq. 1-3 join for a staged group on its home device.

        Returns un-synchronized device arrays — the caller owns
        ``block_until_ready``.  Quantized artifacts with ``want_argmin``
        return the 6-tuple with the ambiguity bits; the engine rescues
        flagged rows via :meth:`rescue`."""
        qerr2 = None
        if want_argmin and self.quantized:
            qerr2 = np.float32(self._qerr[st.i] + self._qerr[st.j])
        return join_masked(
            st.masked_s, st.masked_t, st.s_dev, st.t_dev, st.covis,
            use_kernels=self.use_kernels, want_argmin=want_argmin,
            qerr2=qerr2)

    def rescue(self, st: StagedGroup):
        """Exact-argmin rescue of one staged group (full batch, spliced by
        the caller): re-gather both sides with the exact residual distance
        rows, re-join on the home device without quantization error — the
        result matches the f32 sharded engine bitwise."""
        i, j, W = self.decode_key(st.key)
        # repolint: disable=hot-path-sync -- exact rescue is the sanctioned sync: correctness over overlap (DESIGN.md §11)
        s = np.asarray(st.s_dev, np.float32)
        t = np.asarray(st.t_dev, np.float32)  # repolint: disable=hot-path-sync -- part of the sanctioned rescue sync above
        ri = self.sharded.shards[i].residual
        rj = self.sharded.shards[j].residual
        ds = jax.device_put(ri.gather_d(ri.locate(s), W), self.devices[i])
        dt = jax.device_put(rj.gather_d(rj.locate(t), W), self.devices[j])
        ms = gather_masked_exact(self.shards[i], st.s_dev, ds, W,
                                 use_kernels=self.use_kernels)
        mt = gather_masked_exact(
            self.shards[j], jax.device_put(t, self.devices[j]), dt, W,
            use_kernels=self.use_kernels)
        if i != j:
            mt = jax.device_put(mt, self.devices[i])
        return join_masked(ms, mt, st.s_dev, st.t_dev, st.covis,
                           use_kernels=self.use_kernels, want_argmin=True)

    def dispatch(self, s, t, key: int, want_argmin: bool = False):
        """Answer one routed sub-batch on its destination shard's device.

        Every query in ``s``/``t`` must carry routing key ``key`` (padding
        rows are exempt — their answers are garbage the caller discards,
        exactly like per-bucket dispatch under-width padding).  Returns
        device arrays; ``(i, j, covis participants)`` ride along for the
        caller's stats.  ``stage`` + ``join_staged`` is the same path cut
        for pipelining.
        """
        st = self.stage(s, t, key)
        return self.join_staged(st, want_argmin=want_argmin), \
            (st.i, st.j, st.parts)

    # ------------------------------------------------------------- serving
    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        """Trace every (device, width) gather/join/covis entry at shape."""
        z = np.zeros((batch_size, 2), np.float32)
        zr = np.zeros((batch_size,), np.int32)
        for k, bx in enumerate(self.shards):
            dev = self.devices[k]
            zd = jax.device_put(z, dev)
            zrd = jax.device_put(zr, dev)
            cz = jax.block_until_ready(covis_blocked(
                zd, zd, bx.edges_a, bx.edges_b, bx.edges_c, bx.grid,
                use_kernels=self.use_kernels)) == 0
            for W in self.width_classes:
                W = int(W)
                if W < bx.widths[0]:
                    continue        # no local bucket fits under this width
                masked = gather_masked_labels(bx, zrd, zd, W,
                                              use_kernels=self.use_kernels)
                jax.block_until_ready(join_masked(
                    masked, masked, zd, zd, cz,
                    use_kernels=self.use_kernels, want_argmin=False))
                if self.quantized:
                    # cross-shard quantized wire: owner-side encoded gather
                    # + home-side decode (same shapes/dtypes any home uses)
                    wire = gather_quant_rows(bx, zrd, zd, W,
                                             use_kernels=self.use_kernels)
                    jax.block_until_ready(dequant_masked_labels(
                        *wire, zd, bx.vert_xy))
                if want_argmin:
                    jax.block_until_ready(join_masked(
                        masked, masked, zd, zd, cz,
                        use_kernels=self.use_kernels, want_argmin=True))
                    if self.quantized:
                        # staged-path join with the ambiguity bits, plus
                        # the rescue's exact gather + plain argmin join
                        jax.block_until_ready(join_masked(
                            masked, masked, zd, zd, cz,
                            use_kernels=self.use_kernels, want_argmin=True,
                            qerr2=np.float32(0.0)))
                        d0 = jax.device_put(
                            np.full((batch_size, W), np.inf, np.float32),
                            dev)
                        me = gather_masked_exact(
                            bx, zd, d0, W, use_kernels=self.use_kernels)
                        jax.block_until_ready(me)
