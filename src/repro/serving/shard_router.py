"""(shard, bucket) batch routing over a region-sharded index.

The serving half of ``repro.sharding`` (DESIGN.md §9): a
:class:`~repro.sharding.planner.ShardedIndex` keeps each shard's bucket
slabs on its own mesh device; the router turns an incoming query batch into
per-(shard-pair, width) sub-batches and merges the answers back in input
order.

Routing path per query (all host-side numpy, O(1) per endpoint):

1. locate both endpoints' cells (same float32 floor-divide the device
   engines jit — bit-identical cell ids);
2. the routing table maps each cell to ``(shard, bucket width)``;
3. the composite key ``(shard_s, shard_t, join width)`` groups the batch.

Dispatch per group:

* **same-shard** — both endpoints' label rows are gathered on the owning
  device and joined there; the common case a locality-aware placement
  maximizes.
* **cross-shard** — each side gathers on its own device, the t-side label
  tensors are shipped to the s-side device (``jax.device_put``, a
  [B, W]-sized transfer — the slabs themselves never move), and the join
  runs on the s-side device.

Both paths end in :func:`repro.core.packed.join_gathered` — the same
distance/join core as the single-device engine, so answers are
bitwise-identical to the unsharded ``BucketedIndex`` engine.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.packed import gather_labels_at_width, join_gathered
from repro.launch.mesh import shard_devices


class ShardRouter:
    """Split batches by destination shard, dispatch, merge in input order."""

    def __init__(self, sharded, mesh=None, use_kernels: bool = False):
        self.sharded = sharded
        self.use_kernels = use_kernels
        self.num_shards = sharded.num_shards
        self.devices = shard_devices(mesh, self.num_shards)
        # one device_put per shard: the slabs live on their mesh device for
        # the artifact's whole generation; queries are the only per-request
        # transfers.  Leaves already committed to the right device (the
        # hot-swap path aliases the previous router's placed edge tensors)
        # pass through without a copy.
        self.shards = [jax.device_put(bx, dev)
                       for bx, dev in zip(sharded.shards, self.devices)]
        self.width_classes = np.asarray(sharded.width_classes, np.int64)
        self._nw = len(self.width_classes)
        # per-shard clip bound: foreign/padding cells can carry local ids
        # from wider shards; clipping keeps the (discarded) gather in range
        self._rmax = np.array([max(0, bx.num_regions - 1)
                               for bx in self.shards], dtype=np.int32)

    # ------------------------------------------------------------- routing
    def _cells(self, pts: np.ndarray) -> np.ndarray:
        """Float32 floor-divide cell location — mirrors ``locate_regions``
        bit-for-bit so host routing and device gathers agree."""
        p = np.asarray(pts, np.float32)
        cs = np.float32(self.sharded.cell_size)
        ix = np.clip((p[:, 0] / cs).astype(np.int32), 0, self.sharded.nx - 1)
        iy = np.clip((p[:, 1] / cs).astype(np.int32), 0, self.sharded.ny - 1)
        return iy * self.sharded.nx + ix

    def route_keys(self, s, t) -> np.ndarray:
        """[B] composite routing keys ``(shard_s, shard_t, width-class)``."""
        cs, ct = self._cells(s), self._cells(t)
        sh_s = self.sharded.cell_shard[cs].astype(np.int64)
        sh_t = self.sharded.cell_shard[ct].astype(np.int64)
        w = np.maximum(self.sharded.cell_width[cs],
                       self.sharded.cell_width[ct])
        wc = np.searchsorted(self.width_classes, w)
        return ((sh_s * self.num_shards + sh_t) * self._nw + wc
                ).astype(np.int32)

    def decode_key(self, key: int) -> tuple:
        """key -> (shard_s, shard_t, join width)."""
        key = int(key)
        wc = key % self._nw
        pair = key // self._nw
        return (pair // self.num_shards, pair % self.num_shards,
                int(self.width_classes[wc]))

    def key_width(self, key: int) -> int:
        return int(self.width_classes[int(key) % self._nw])

    # ------------------------------------------------------------ dispatch
    def _locals(self, cells: np.ndarray, shard: int) -> jnp.ndarray:
        ids = np.minimum(self.sharded.cell_local[cells], self._rmax[shard])
        # one host->device transfer straight onto the gathering shard (a
        # detour through the default device would double the traffic)
        return jax.device_put(ids, self.devices[shard])

    def dispatch(self, s, t, key: int, want_argmin: bool = False):
        """Answer one routed sub-batch on its destination shard's device.

        Every query in ``s``/``t`` must carry routing key ``key`` (padding
        rows are exempt — their answers are garbage the caller discards,
        exactly like per-bucket dispatch under-width padding).  Returns
        device arrays; ``(i, j)`` — the shards that participated — ride
        along for the caller's stats.
        """
        i, j, W = self.decode_key(key)
        s = np.asarray(s, np.float32)
        t = np.asarray(t, np.float32)
        cs, ct = self._cells(s), self._cells(t)
        dev = self.devices[i]

        labels_s = gather_labels_at_width(
            self.shards[i], self._locals(cs, i), W)
        labels_t = gather_labels_at_width(
            self.shards[j], self._locals(ct, j), W)
        if i != j:
            # ship the gathered [B, W] rows, not the slabs
            labels_t = jax.device_put(labels_t, dev)
        res = join_gathered(
            labels_s, labels_t,
            jax.device_put(s, dev), jax.device_put(t, dev),
            self.shards[i].edges_a, self.shards[i].edges_b,
            use_kernels=self.use_kernels, want_argmin=want_argmin)
        return res, (i, j)

    # ------------------------------------------------------------- serving
    def warmup(self, batch_size: int, want_argmin: bool = False) -> None:
        """Trace every (device, width) gather/join entry at serving shape."""
        z = np.zeros((batch_size, 2), np.float32)
        zr = np.zeros((batch_size,), np.int32)
        for k, bx in enumerate(self.shards):
            dev = self.devices[k]
            zd = jax.device_put(z, dev)
            zrd = jax.device_put(zr, dev)
            for W in self.width_classes:
                W = int(W)
                if W < bx.widths[0]:
                    continue        # no local bucket fits under this width
                labels = gather_labels_at_width(bx, zrd, W)
                jax.block_until_ready(join_gathered(
                    labels, labels, zd, zd, bx.edges_a, bx.edges_b,
                    use_kernels=self.use_kernels, want_argmin=False))
                if want_argmin:
                    jax.block_until_ready(join_gathered(
                        labels, labels, zd, zd, bx.edges_a, bx.edges_b,
                        use_kernels=self.use_kernels, want_argmin=True))
