"""Batched request serving — the paper's online phase as a production loop.

``PathServer`` fronts a pluggable :class:`~repro.serving.query_engine.
QueryEngine`: requests are routed by dispatch bucket (max of the two
endpoint-region buckets under the width-bucketed layout, DESIGN.md §4),
each bucket group is cut into fixed-size batches (zero-padding the tail
keeps shapes static, so the jitted kernels never recompile), answered, and
scattered back into request order.  Per-bucket latency/occupancy stats make
the routing observable.  On a mesh, the query batch shards over the data
axes and the index is replicated (or region-sharded for indexes beyond
single-device HBM — the EHL* budget knob is what keeps the replicated fast
path viable, see DESIGN.md §6).

``LMServer`` does the same for LM decode against a prefilled cache — shared
batching/stats machinery, per the framework design.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.packed import empty_results
from repro.core.query import path_length, unwind_path
from repro.serving.query_engine import HostEngine, QueryEngine, make_engine


class BucketStats(obs.StatsView):
    """Per-dispatch-bucket serving counters (width = label slots paid).

    Registry-backed view (DESIGN.md §12): every counter is a labeled
    series in the metrics registry — same field surface as the old
    dataclass, but the Prometheus export and this object read the same
    storage.  Rows are generation-tagged (``gen`` label), so a hot-swap's
    per-bucket reset starts fresh series while the retired generation
    stays frozen in the registry.
    """

    _COUNTERS = {
        "batches": ("bucket_batches_total", int),
        "queries": ("bucket_queries_total", int),
        "seconds": ("bucket_seconds_total", float),
        # batch slots dispatched (incl. tail padding)
        "slots": ("bucket_slots_total", int),
        # continuous batching (serving.batcher): admission + flush mix
        "admitted": ("bucket_admitted_total", int),
        "full_flushes": ("bucket_full_flushes_total", int),
        "deadline_flushes": ("bucket_deadline_flushes_total", int),
    }

    def __init__(self, width: int = 0, registry=None, labels=None):
        self.width = int(width)
        self._bind(registry, labels, row_prefix="b")
        self.registry.gauge("bucket_width", **self.labels).set(width)

    @property
    def occupancy(self) -> float:
        """Real queries / dispatched slots (1.0 = no tail padding waste).

        Slots are counted exactly once, at dispatch — a group re-routed
        after a hot-swap superseded its routing keys never touches this
        row (see ``CoalescingBatcher._launch``), so occupancy stays <= 1.
        """
        return self.queries / max(1, self.slots)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.seconds / max(1, self.queries)


class ServeStats(obs.StatsView):
    """Server-level counters: a registry-backed view (DESIGN.md §12).

    Field names and mutation idioms (``+=``, direct assignment) are the
    dataclass-era public surface; storage is labeled series in the
    metrics registry (one unique ``srv`` row per server instance), so
    exports reproduce these numbers from the same source.
    """

    _COUNTERS = {
        "batches": ("serve_batches_total", int),
        "queries": ("serve_queries_total", int),
        "seconds": ("serve_seconds_total", float),
        # adaptive serving: generation changes observed / stale finishes
        "swaps": ("serve_swaps_total", int),
        "stale_batches": ("serve_stale_batches_total", int),
        # continuous batching (serving.batcher): admission / queue / flush
        "submitted": ("serve_submitted_total", int),
        "shed": ("serve_shed_total", int),
        "admission_waits": ("serve_admission_waits_total", int),
        "full_flushes": ("serve_full_flushes_total", int),
        "deadline_flushes": ("serve_deadline_flushes_total", int),
        "forced_flushes": ("serve_forced_flushes_total", int),
        "requeued_batches": ("serve_requeued_batches_total", int),
    }
    _GAUGES = {
        # generation the last request was served on; per_bucket is reset
        # whenever a new generation is first served — bucket ids/widths
        # are meaningless across artifact generations
        "generation": ("serve_generation", int),
        "queue_depth": ("serve_queue_depth", int),
        "queue_depth_peak": ("serve_queue_depth_peak", int),
        "pipeline_peak": ("serve_pipeline_peak", int),
    }

    def __init__(self, registry=None, labels=None):
        lbl = dict(labels or {})
        lbl.setdefault("srv", obs.next_instance_id("s"))
        self._bind(registry, lbl, row_prefix="s")
        self.per_bucket: dict = {}
        # sharded serving (repro.sharding): per-shard ShardStats rows,
        # refreshed from the engine after every request
        self.per_shard: list = []

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.seconds / max(1, self.queries)

    @property
    def qps(self) -> float:
        return self.queries / max(1e-9, self.seconds)


def expected_join_cost(engine, s, t) -> float:
    """Expected per-query join cost on a workload: mean dispatch-width^2.

    The O(W^2) label join is what a query pays at its dispatch width; a
    workload-aware index keeps hot regions in narrow buckets, so this is
    the metric the adaptive demo/bench compare against the uniform-score
    index (smaller = cheaper hot path).
    """
    buckets = engine.buckets_of(s, t)
    widths = np.array([engine.bucket_width(int(k)) for k in buckets])
    return float(np.mean(widths.astype(np.float64) ** 2))


class PathServer:
    """Fixed-batch ESPP query server over a pluggable query engine.

    ``index`` may be a packed artifact (PackedIndex / BucketedIndex — wrapped
    in a jnp or Pallas device engine per ``use_kernels``), a host EHLIndex
    (auto-packed bucketed), or a ready-made :class:`QueryEngine`.
    """

    def __init__(self, index, batch_size: int = 256,
                 use_kernels: bool = False, mesh=None, batch_sharding=None,
                 recorder=None, telemetry=None):
        if isinstance(index, QueryEngine):
            if use_kernels and not getattr(index, "use_kernels", False):
                raise ValueError("use_kernels=True conflicts with the given "
                                 f"{index.name!r} engine — construct a "
                                 "PallasEngine (or pass the packed index)")
            self.engine = index
        else:
            self.engine = make_engine(
                index, backend="pallas" if use_kernels else "jnp")
        self.index = getattr(self.engine, "index", None)
        self.batch_size = batch_size
        # telemetry: spans + events + the registry the stats views bind to
        # (DESIGN.md §12).  Default is head-sampled tracing over the
        # process-wide registry; pass obs.Telemetry.off() to disable
        # span/event recording (registry stays on — it IS the stats).
        self.telemetry = obs.Telemetry() if telemetry is None else telemetry
        self.stats = ServeStats(registry=self.telemetry.registry)
        bind = getattr(self.engine, "bind_telemetry", None)
        if bind is not None:
            bind(self.telemetry)
        self._sharding = batch_sharding
        # adaptive serving: every answered query's endpoints feed the live
        # workload histogram (repro.indexing.WorkloadRecorder)
        self._recorder = recorder
        # continuous batching: created by start_async()/first submit()
        self._batcher = None

    def warmup(self, paths: bool = False):
        """Warm every jit entry live traffic can hit: every bucket width
        present in the engine (every (shard, width) pair under sharding) is
        traced at the serving batch shape, and ``paths=True`` additionally
        traces the argmin entries behind ``query_paths`` — so the first
        live request at a cold width never pays an XLA compile inside the
        serving loop (regression-tested by a trace counter,
        ``core.packed.TRACES``)."""
        self.engine.warmup(self.batch_size, want_argmin=paths)

    # -------------------------------------------------- continuous batching
    def start_async(self, max_wait_ms: float = 2.0, max_queue: int = 8192,
                    policy: str = "block", depth: int = 2):
        """Start the continuous-batching serve loop (serving.batcher).

        Returns the :class:`~repro.serving.batcher.CoalescingBatcher`;
        ``submit``/``flush``/``drain``/``stop_async`` below delegate to it.
        """
        from repro.serving.batcher import CoalescingBatcher
        if self._batcher is not None:
            raise RuntimeError("async serve loop already running; "
                               "stop_async() first")
        if self._sharding is not None:
            raise ValueError("batch_sharding is a synchronous-dispatch "
                             "feature; the async loop stages transfers "
                             "through QueryEngine.stage instead")
        self._batcher = CoalescingBatcher(self, max_wait_ms=max_wait_ms,
                                          max_queue=max_queue,
                                          policy=policy, depth=depth)
        return self._batcher

    def submit(self, s, t, want_argmin: bool = False):
        """Enqueue N requests on the coalescing queue; returns a
        :class:`~repro.serving.batcher.Ticket` future (results in submit
        order).  Starts the serve loop with defaults if needed."""
        if self._batcher is None:
            self.start_async()
        return self._batcher.submit(s, t, want_argmin=want_argmin)

    def flush(self) -> None:
        """Force every queued group to dispatch now (deadline override)."""
        if self._batcher is not None:
            self._batcher.flush()

    def drain(self, timeout: float | None = None) -> bool:
        """Flush + wait until the queue and in-flight pipeline are empty."""
        if self._batcher is None:
            return True
        return self._batcher.drain(timeout=timeout)

    def stop_async(self) -> None:
        """Drain and stop the serve loop (submit() may start a new one)."""
        if self._batcher is not None:
            self._batcher.close(drain=True)
            self._batcher = None

    def _bucket_stats(self, bucket: int, eng) -> BucketStats:
        if bucket not in self.stats.per_bucket:
            width = getattr(eng, "bucket_width", lambda b: 0)(bucket)
            self.stats.per_bucket[bucket] = BucketStats(
                width=width, registry=self.stats.registry,
                labels={"srv": self.stats.labels["srv"], "bucket": bucket,
                        "gen": getattr(eng, "generation", 0)})
        return self.stats.per_bucket[bucket]

    def _dispatch(self, s, t, want_argmin: bool, trace=None):
        """Bucket-route N requests through fixed-shape batches; scatter back.

        Sort by dispatch bucket (stable), answer each bucket's sub-batches
        at that bucket's width, write results back through the permutation.
        Returns a list of [N]-arrays (1 for distances, 5 for argmin).

        The engine is *pinned* for the whole request: under a hot-swapping
        engine the routing key (``buckets_of``) and every batch must resolve
        against one artifact generation — a swap published mid-request takes
        effect on the next request, and the superseded artifact stays alive
        until this one drains (``QueryEngine.pin``).
        """
        n = len(s)
        bs = self.batch_size
        b0 = self.stats.batches
        with self.engine.pin() as eng:
            # the pinned engine carries the generation it belongs to
            # (stamped by SwappableEngine.swap); plain engines report 0
            gen0 = eng.generation
            if gen0 != self.stats.generation:
                # new artifact since the last request: its bucket plan is
                # unrelated to the previous generation's, so per-bucket
                # stats restart (they describe the *current* routing)
                self.stats.swaps += max(0, gen0 - self.stats.generation)
                self.stats.per_bucket = {}
            pad = getattr(eng, "static_shapes", True)
            t_route = time.perf_counter()
            buckets = eng.buckets_of(s, t) if n else np.zeros(0, np.int32)
            if trace is not None:
                trace.stage("route", time.perf_counter() - t_route)
            t_batches = time.perf_counter()
            outs = empty_results(n, want_argmin)
            for k in np.unique(buckets):
                idxs = np.nonzero(buckets == k)[0]
                bstats = self._bucket_stats(int(k), eng)
                tb0 = time.perf_counter()
                for lo in range(0, len(idxs), bs):
                    sel = idxs[lo:lo + bs]
                    # jitted engines get fixed [bs, 2] shapes (no
                    # recompiles); host-loop engines take the ragged tail
                    rows = bs if pad else len(sel)
                    sb = np.zeros((rows, 2), np.float32)
                    tb = np.zeros((rows, 2), np.float32)
                    sb[:len(sel)] = s[sel]
                    tb[:len(sel)] = t[sel]
                    sj, tj = (jnp.asarray(sb), jnp.asarray(tb)) if pad \
                        else (sb, tb)
                    if self._sharding is not None:
                        sj = jax.device_put(sj, self._sharding)
                        tj = jax.device_put(tj, self._sharding)
                    if want_argmin:
                        res = eng.batch_argmin(sj, tj, bucket=int(k))
                    else:
                        res = (eng.batch(sj, tj, bucket=int(k)),)
                    for o, r in zip(outs, res):
                        o[sel] = np.asarray(r)[:len(sel)]
                    bstats.batches += 1
                    bstats.slots += rows
                    self.stats.batches += 1
                bstats.queries += len(idxs)
                bstats.seconds += time.perf_counter() - tb0
            if trace is not None:
                trace.stage("dispatch", time.perf_counter() - t_batches)
                trace.attrs["generation"] = gen0
            shard_stats = getattr(eng, "shard_stats", None)
            if shard_stats is not None:
                self.stats.per_shard = shard_stats()
        if self.engine.generation != gen0:
            # swap published while we served on the old pin: these batches
            # completed on a superseded artifact (answers still exact)
            self.stats.stale_batches += self.stats.batches - b0
        self.stats.generation = gen0    # generation this request served on
        if self._recorder is not None and n:
            self._recorder.record(s, t)
        return outs

    def _sync_trace(self, n: int, argmin: bool):
        """Head-sample a sync-path trace (None = not sampled)."""
        if not self.telemetry.sampler.sample():
            return None
        return obs.Trace("sync", n=n, argmin=argmin,
                         srv=self.stats.labels["srv"])

    def _close_sync(self, trace, t0: float, t1: float) -> None:
        """Close a sync span tree: fill missing stages with 0, let
        ``reply`` absorb the unattributed remainder (scatter + stats
        bookkeeping) so the stage sum telescopes to e2e exactly."""
        tel = self.telemetry
        e2e = t1 - t0
        tel.registry.histogram("sync_batch_ms",
                               **self.stats.labels).record(e2e * 1e3)
        if trace is None:
            if not tel.sampler.slow(e2e):
                return
            # slow-path override without head sampling: a coarse trace
            # (no per-stage stamps were taken) still lands in the ring
            trace = obs.Trace("sync", coarse=True, n=0,
                              srv=self.stats.labels["srv"])
            trace.stage("dispatch", e2e)
        for st in obs.SYNC_STAGES:
            trace.stages.setdefault(st, 0.0)
        trace.stage("reply", max(0.0, e2e - trace.stage_sum))
        tel.spans.add(trace.close(t0, t1))

    def query(self, s: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Answer N distance requests (any N), bucket-routed."""
        t0 = time.perf_counter()
        trace = self._sync_trace(len(s), argmin=False)
        out = self._dispatch(np.asarray(s, np.float32),
                             np.asarray(t, np.float32),
                             want_argmin=False, trace=trace)[0]
        t1 = time.perf_counter()
        self.stats.seconds += t1 - t0
        self.stats.queries += len(out)
        self._close_sync(trace, t0, t1)
        return out

    def query_paths(self, s: np.ndarray, t: np.ndarray, host_index=None
                    ) -> tuple[np.ndarray, list]:
        """Distances + optimal polylines for N requests.

        The batched argmin engine identifies each query's winning
        (via_s, hub, via_t) triple; unwinding follows the hub labels'
        next-hop pointers, which live host-side — pass the host
        ``EHLIndex`` (defaults to a HostEngine's own index).
        """
        s = np.asarray(s, np.float32)
        t = np.asarray(t, np.float32)
        if isinstance(self.engine, HostEngine):
            t0 = time.perf_counter()
            paths = self.engine.paths(s, t)
            d = np.array([path_length(p) for p in paths], dtype=np.float32)
            self.stats.seconds += time.perf_counter() - t0
            self.stats.queries += len(s)
            if self._recorder is not None and len(s):
                self._recorder.record(s, t)
            return d, paths
        if host_index is None:
            raise ValueError("query_paths on a device engine needs the host "
                             "EHLIndex for label unwinding")
        t0 = time.perf_counter()
        trace = self._sync_trace(len(s), argmin=True)
        d, covis, via_s, hub, via_t = self._dispatch(s, t, want_argmin=True,
                                                     trace=trace)
        t_unwind = time.perf_counter()
        paths = []
        for i in range(len(s)):
            if covis[i]:
                paths.append([s[i].astype(np.float64), t[i].astype(np.float64)])
            elif not np.isfinite(d[i]):
                paths.append([])
            else:
                paths.append(unwind_path(host_index, s[i], t[i],
                                         int(via_s[i]), int(hub[i]),
                                         int(via_t[i])))
        t1 = time.perf_counter()
        if trace is not None:
            trace.stage("unwind", t1 - t_unwind)
        self.stats.seconds += t1 - t0
        self.stats.queries += len(s)
        self._close_sync(trace, t0, t1)
        return d, paths


class LMServer:
    """Greedy decode server over a prefilled cache (shared stats plumbing)."""

    def __init__(self, cfg, params, cache):
        from repro.models import transformer as T
        self.cfg = cfg
        self.params = params
        self.cache = cache
        self.stats = ServeStats()
        # repolint: disable=jit-registry -- LM decode demo, not an EHL query entry
        self._step = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t))

    def generate(self, prompt_tokens: np.ndarray, n_new: int) -> np.ndarray:
        B = prompt_tokens.shape[0]
        tok = jnp.asarray(prompt_tokens[:, -1:])
        out = []
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, self.cache = self._step(self.params, self.cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += B * n_new
        return np.concatenate(out, axis=1)
