"""Batched request serving — the paper's online phase as a production loop.

``PathServer`` fronts a pluggable :class:`~repro.serving.query_engine.
QueryEngine`: requests are routed by dispatch bucket (max of the two
endpoint-region buckets under the width-bucketed layout, DESIGN.md §4),
each bucket group is cut into fixed-size batches (zero-padding the tail
keeps shapes static, so the jitted kernels never recompile), answered, and
scattered back into request order.  Per-bucket latency/occupancy stats make
the routing observable.  On a mesh, the query batch shards over the data
axes and the index is replicated (or region-sharded for indexes beyond
single-device HBM — the EHL* budget knob is what keeps the replicated fast
path viable, see DESIGN.md §6).

``LMServer`` does the same for LM decode against a prefilled cache — shared
batching/stats machinery, per the framework design.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.packed import empty_results
from repro.core.query import path_length, unwind_path
from repro.serving.query_engine import HostEngine, QueryEngine, make_engine


@dataclasses.dataclass
class BucketStats:
    """Per-dispatch-bucket serving counters (width = label slots paid)."""
    width: int = 0
    batches: int = 0
    queries: int = 0
    seconds: float = 0.0
    slots: int = 0          # batch slots dispatched (incl. tail padding)
    # continuous batching (serving.batcher): per-key admission + flush mix
    admitted: int = 0           # queries admitted to this key's queue
    full_flushes: int = 0       # groups shipped because the batch filled
    deadline_flushes: int = 0   # groups shipped by the latency deadline

    @property
    def occupancy(self) -> float:
        """Real queries / dispatched slots (1.0 = no tail padding waste).

        Slots are counted exactly once, at dispatch — a group re-routed
        after a hot-swap superseded its routing keys never touches this
        row (see ``CoalescingBatcher._launch``), so occupancy stays <= 1.
        """
        return self.queries / max(1, self.slots)

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.seconds / max(1, self.queries)


@dataclasses.dataclass
class ServeStats:
    batches: int = 0
    queries: int = 0
    seconds: float = 0.0
    per_bucket: dict = dataclasses.field(default_factory=dict)
    # adaptive serving (repro.indexing): engine generation observability.
    # per_bucket is reset whenever a new generation is first served — bucket
    # ids/widths are meaningless across artifact generations.
    generation: int = 0     # generation the last request was served on
    swaps: int = 0          # generation changes observed by this server
    stale_batches: int = 0  # batches that finished on a superseded artifact
    # sharded serving (repro.sharding): per-shard ShardStats rows, refreshed
    # from the engine after every request (empty for unsharded engines)
    per_shard: list = dataclasses.field(default_factory=list)
    # continuous batching (serving.batcher): admission / queue / flush
    # observability for the async coalescing loop
    submitted: int = 0          # queries admitted through submit()
    shed: int = 0               # queries rejected by the backpressure gate
    admission_waits: int = 0    # submit() calls that blocked on the gate
    full_flushes: int = 0       # groups dispatched because they filled
    deadline_flushes: int = 0   # groups dispatched by max_wait_ms expiry
    forced_flushes: int = 0     # groups dispatched by flush()/close()
    requeued_batches: int = 0   # groups re-routed after a generation swap
    queue_depth: int = 0        # live gauge: queries waiting to dispatch
    queue_depth_peak: int = 0
    pipeline_peak: int = 0      # max groups concurrently in flight

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.seconds / max(1, self.queries)

    @property
    def qps(self) -> float:
        return self.queries / max(1e-9, self.seconds)


def expected_join_cost(engine, s, t) -> float:
    """Expected per-query join cost on a workload: mean dispatch-width^2.

    The O(W^2) label join is what a query pays at its dispatch width; a
    workload-aware index keeps hot regions in narrow buckets, so this is
    the metric the adaptive demo/bench compare against the uniform-score
    index (smaller = cheaper hot path).
    """
    buckets = engine.buckets_of(s, t)
    widths = np.array([engine.bucket_width(int(k)) for k in buckets])
    return float(np.mean(widths.astype(np.float64) ** 2))


class PathServer:
    """Fixed-batch ESPP query server over a pluggable query engine.

    ``index`` may be a packed artifact (PackedIndex / BucketedIndex — wrapped
    in a jnp or Pallas device engine per ``use_kernels``), a host EHLIndex
    (auto-packed bucketed), or a ready-made :class:`QueryEngine`.
    """

    def __init__(self, index, batch_size: int = 256,
                 use_kernels: bool = False, mesh=None, batch_sharding=None,
                 recorder=None):
        if isinstance(index, QueryEngine):
            if use_kernels and not getattr(index, "use_kernels", False):
                raise ValueError("use_kernels=True conflicts with the given "
                                 f"{index.name!r} engine — construct a "
                                 "PallasEngine (or pass the packed index)")
            self.engine = index
        else:
            self.engine = make_engine(
                index, backend="pallas" if use_kernels else "jnp")
        self.index = getattr(self.engine, "index", None)
        self.batch_size = batch_size
        self.stats = ServeStats()
        self._sharding = batch_sharding
        # adaptive serving: every answered query's endpoints feed the live
        # workload histogram (repro.indexing.WorkloadRecorder)
        self._recorder = recorder
        # continuous batching: created by start_async()/first submit()
        self._batcher = None

    def warmup(self, paths: bool = False):
        """Warm every jit entry live traffic can hit: every bucket width
        present in the engine (every (shard, width) pair under sharding) is
        traced at the serving batch shape, and ``paths=True`` additionally
        traces the argmin entries behind ``query_paths`` — so the first
        live request at a cold width never pays an XLA compile inside the
        serving loop (regression-tested by a trace counter,
        ``core.packed.TRACES``)."""
        self.engine.warmup(self.batch_size, want_argmin=paths)

    # -------------------------------------------------- continuous batching
    def start_async(self, max_wait_ms: float = 2.0, max_queue: int = 8192,
                    policy: str = "block", depth: int = 2):
        """Start the continuous-batching serve loop (serving.batcher).

        Returns the :class:`~repro.serving.batcher.CoalescingBatcher`;
        ``submit``/``flush``/``drain``/``stop_async`` below delegate to it.
        """
        from repro.serving.batcher import CoalescingBatcher
        if self._batcher is not None:
            raise RuntimeError("async serve loop already running; "
                               "stop_async() first")
        if self._sharding is not None:
            raise ValueError("batch_sharding is a synchronous-dispatch "
                             "feature; the async loop stages transfers "
                             "through QueryEngine.stage instead")
        self._batcher = CoalescingBatcher(self, max_wait_ms=max_wait_ms,
                                          max_queue=max_queue,
                                          policy=policy, depth=depth)
        return self._batcher

    def submit(self, s, t, want_argmin: bool = False):
        """Enqueue N requests on the coalescing queue; returns a
        :class:`~repro.serving.batcher.Ticket` future (results in submit
        order).  Starts the serve loop with defaults if needed."""
        if self._batcher is None:
            self.start_async()
        return self._batcher.submit(s, t, want_argmin=want_argmin)

    def flush(self) -> None:
        """Force every queued group to dispatch now (deadline override)."""
        if self._batcher is not None:
            self._batcher.flush()

    def drain(self, timeout: float | None = None) -> bool:
        """Flush + wait until the queue and in-flight pipeline are empty."""
        if self._batcher is None:
            return True
        return self._batcher.drain(timeout=timeout)

    def stop_async(self) -> None:
        """Drain and stop the serve loop (submit() may start a new one)."""
        if self._batcher is not None:
            self._batcher.close(drain=True)
            self._batcher = None

    def _bucket_stats(self, bucket: int, eng) -> BucketStats:
        if bucket not in self.stats.per_bucket:
            width = getattr(eng, "bucket_width", lambda b: 0)(bucket)
            self.stats.per_bucket[bucket] = BucketStats(width=width)
        return self.stats.per_bucket[bucket]

    def _dispatch(self, s, t, want_argmin: bool):
        """Bucket-route N requests through fixed-shape batches; scatter back.

        Sort by dispatch bucket (stable), answer each bucket's sub-batches
        at that bucket's width, write results back through the permutation.
        Returns a list of [N]-arrays (1 for distances, 5 for argmin).

        The engine is *pinned* for the whole request: under a hot-swapping
        engine the routing key (``buckets_of``) and every batch must resolve
        against one artifact generation — a swap published mid-request takes
        effect on the next request, and the superseded artifact stays alive
        until this one drains (``QueryEngine.pin``).
        """
        n = len(s)
        bs = self.batch_size
        b0 = self.stats.batches
        with self.engine.pin() as eng:
            # the pinned engine carries the generation it belongs to
            # (stamped by SwappableEngine.swap); plain engines report 0
            gen0 = eng.generation
            if gen0 != self.stats.generation:
                # new artifact since the last request: its bucket plan is
                # unrelated to the previous generation's, so per-bucket
                # stats restart (they describe the *current* routing)
                self.stats.swaps += max(0, gen0 - self.stats.generation)
                self.stats.per_bucket = {}
            pad = getattr(eng, "static_shapes", True)
            buckets = eng.buckets_of(s, t) if n else np.zeros(0, np.int32)
            outs = empty_results(n, want_argmin)
            for k in np.unique(buckets):
                idxs = np.nonzero(buckets == k)[0]
                bstats = self._bucket_stats(int(k), eng)
                tb0 = time.perf_counter()
                for lo in range(0, len(idxs), bs):
                    sel = idxs[lo:lo + bs]
                    # jitted engines get fixed [bs, 2] shapes (no
                    # recompiles); host-loop engines take the ragged tail
                    rows = bs if pad else len(sel)
                    sb = np.zeros((rows, 2), np.float32)
                    tb = np.zeros((rows, 2), np.float32)
                    sb[:len(sel)] = s[sel]
                    tb[:len(sel)] = t[sel]
                    sj, tj = (jnp.asarray(sb), jnp.asarray(tb)) if pad \
                        else (sb, tb)
                    if self._sharding is not None:
                        sj = jax.device_put(sj, self._sharding)
                        tj = jax.device_put(tj, self._sharding)
                    if want_argmin:
                        res = eng.batch_argmin(sj, tj, bucket=int(k))
                    else:
                        res = (eng.batch(sj, tj, bucket=int(k)),)
                    for o, r in zip(outs, res):
                        o[sel] = np.asarray(r)[:len(sel)]
                    bstats.batches += 1
                    bstats.slots += rows
                    self.stats.batches += 1
                bstats.queries += len(idxs)
                bstats.seconds += time.perf_counter() - tb0
            shard_stats = getattr(eng, "shard_stats", None)
            if shard_stats is not None:
                self.stats.per_shard = shard_stats()
        if self.engine.generation != gen0:
            # swap published while we served on the old pin: these batches
            # completed on a superseded artifact (answers still exact)
            self.stats.stale_batches += self.stats.batches - b0
        self.stats.generation = gen0    # generation this request served on
        if self._recorder is not None and n:
            self._recorder.record(s, t)
        return outs

    def query(self, s: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Answer N distance requests (any N), bucket-routed."""
        t0 = time.perf_counter()
        out = self._dispatch(np.asarray(s, np.float32),
                             np.asarray(t, np.float32),
                             want_argmin=False)[0]
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += len(out)
        return out

    def query_paths(self, s: np.ndarray, t: np.ndarray, host_index=None
                    ) -> tuple[np.ndarray, list]:
        """Distances + optimal polylines for N requests.

        The batched argmin engine identifies each query's winning
        (via_s, hub, via_t) triple; unwinding follows the hub labels'
        next-hop pointers, which live host-side — pass the host
        ``EHLIndex`` (defaults to a HostEngine's own index).
        """
        s = np.asarray(s, np.float32)
        t = np.asarray(t, np.float32)
        if isinstance(self.engine, HostEngine):
            t0 = time.perf_counter()
            paths = self.engine.paths(s, t)
            d = np.array([path_length(p) for p in paths], dtype=np.float32)
            self.stats.seconds += time.perf_counter() - t0
            self.stats.queries += len(s)
            if self._recorder is not None and len(s):
                self._recorder.record(s, t)
            return d, paths
        if host_index is None:
            raise ValueError("query_paths on a device engine needs the host "
                             "EHLIndex for label unwinding")
        t0 = time.perf_counter()
        d, covis, via_s, hub, via_t = self._dispatch(s, t, want_argmin=True)
        paths = []
        for i in range(len(s)):
            if covis[i]:
                paths.append([s[i].astype(np.float64), t[i].astype(np.float64)])
            elif not np.isfinite(d[i]):
                paths.append([])
            else:
                paths.append(unwind_path(host_index, s[i], t[i],
                                         int(via_s[i]), int(hub[i]),
                                         int(via_t[i])))
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += len(s)
        return d, paths


class LMServer:
    """Greedy decode server over a prefilled cache (shared stats plumbing)."""

    def __init__(self, cfg, params, cache):
        from repro.models import transformer as T
        self.cfg = cfg
        self.params = params
        self.cache = cache
        self.stats = ServeStats()
        self._step = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t))

    def generate(self, prompt_tokens: np.ndarray, n_new: int) -> np.ndarray:
        B = prompt_tokens.shape[0]
        tok = jnp.asarray(prompt_tokens[:, -1:])
        out = []
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, self.cache = self._step(self.params, self.cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += B * n_new
        return np.concatenate(out, axis=1)
