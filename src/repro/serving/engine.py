"""Batched request serving — the paper's online phase as a production loop.

``PathServer`` fronts the EHL* packed index: requests accumulate into
fixed-size batches (padding with the last request keeps shapes static, so
the jitted kernel never recompiles), are answered with the batched Eq. 1-3
engine, and throughput/latency stats are collected per batch.  On a mesh,
the query batch shards over the data axes and the index is replicated (or
region-sharded for indexes beyond single-device HBM — the EHL* budget knob
is what keeps the replicated fast path viable, see DESIGN.md).

``LMServer`` does the same for LM decode against a prefilled cache — shared
batching/stats machinery, per the framework design.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.packed import PackedIndex, query_batch


@dataclasses.dataclass
class ServeStats:
    batches: int = 0
    queries: int = 0
    seconds: float = 0.0

    @property
    def us_per_query(self) -> float:
        return 1e6 * self.seconds / max(1, self.queries)

    @property
    def qps(self) -> float:
        return self.queries / max(1e-9, self.seconds)


class PathServer:
    """Fixed-batch ESPP query server over a packed EHL* index."""

    def __init__(self, index: PackedIndex, batch_size: int = 256,
                 use_kernels: bool = False, mesh=None, batch_sharding=None):
        self.index = index
        self.batch_size = batch_size
        self.use_kernels = use_kernels
        self.stats = ServeStats()
        self._sharding = batch_sharding
        self._fn = jax.jit(
            lambda idx, s, t: query_batch(idx, s, t,
                                          use_kernels=use_kernels))

    def warmup(self):
        z = jnp.zeros((self.batch_size, 2), jnp.float32)
        self._fn(self.index, z, z).block_until_ready()

    def query(self, s: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Answer N requests (any N); pads the tail batch to a fixed shape."""
        n = len(s)
        out = np.empty(n, np.float32)
        bs = self.batch_size
        t0 = time.perf_counter()
        for lo in range(0, n, bs):
            hi = min(lo + bs, n)
            sb = np.zeros((bs, 2), np.float32)
            tb = np.zeros((bs, 2), np.float32)
            sb[:hi - lo] = s[lo:hi]
            tb[:hi - lo] = t[lo:hi]
            sj, tj = jnp.asarray(sb), jnp.asarray(tb)
            if self._sharding is not None:
                sj = jax.device_put(sj, self._sharding)
                tj = jax.device_put(tj, self._sharding)
            d = self._fn(self.index, sj, tj)
            out[lo:hi] = np.asarray(d)[:hi - lo]
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += n
        self.stats.batches += -(-n // bs)
        return out


class LMServer:
    """Greedy decode server over a prefilled cache (shared stats plumbing)."""

    def __init__(self, cfg, params, cache):
        from repro.models import transformer as T
        self.cfg = cfg
        self.params = params
        self.cache = cache
        self.stats = ServeStats()
        self._step = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t))

    def generate(self, prompt_tokens: np.ndarray, n_new: int) -> np.ndarray:
        B = prompt_tokens.shape[0]
        tok = jnp.asarray(prompt_tokens[:, -1:])
        out = []
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, self.cache = self._step(self.params, self.cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += B * n_new
        return np.concatenate(out, axis=1)
