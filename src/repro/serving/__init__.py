from .engine import BucketStats, LMServer, PathServer, ServeStats  # noqa: F401
from .query_engine import (DeviceEngine, HostEngine, JnpEngine,  # noqa: F401
                           PallasEngine, QueryEngine, make_engine)
