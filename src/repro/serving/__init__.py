from .engine import LMServer, PathServer, ServeStats  # noqa: F401
