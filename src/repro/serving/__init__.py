from .batcher import CoalescingBatcher, QueueFull, Ticket  # noqa: F401
from .engine import (BucketStats, LMServer, PathServer,  # noqa: F401
                     ServeStats, expected_join_cost)
from .query_engine import (DeviceEngine, HostEngine, JnpEngine,  # noqa: F401
                           PallasEngine, QueryEngine, make_engine)
from .shard_router import ShardRouter  # noqa: F401
