"""Continuous-batching front-end: coalescing queue + double-buffered dispatch.

``PathServer.query`` answers one caller-assembled batch at a time, which
makes real-traffic throughput a batching problem: requests arrive one by
one, spread over dispatch keys (bucket width, or ``(shard_s, shard_t,
width)`` under the sharded engine), and a synchronous server pays a full
padded kernel launch for every half-empty tail group.  The
:class:`CoalescingBatcher` turns the server into a continuous-batching
loop (DESIGN.md §6):

* **coalesce** — submitted queries enter per-dispatch-key groups.  A group
  ships when it fills ``batch_size`` (*full flush*) **or** when its oldest
  request has waited ``max_wait_ms`` (*deadline flush*), so occupancy stays
  high without unbounded tail latency.  ``flush()`` force-ships everything
  (*forced flush*).
* **double-buffer** — the serve loop keeps up to ``depth`` (default 2)
  groups in flight: while group N's kernels run on device, group N+1 is
  already staged host→device (``QueryEngine.stage``) and dispatched
  (``QueryEngine.dispatch_staged`` — un-synchronized device results; the
  batcher owns ``block_until_ready``).  Under the sharded engine the stage
  phase includes the cross-shard label gathers and co-visibility dispatch,
  so the next group's transfers overlap the current group's join instead
  of serializing behind it.
* **backpressure** — ``max_queue`` bounds the number of queued queries;
  past it, ``submit`` blocks (``policy="block"``) or raises
  :class:`QueueFull` (``policy="shed"``).  Admission, queue-depth and
  flush-reason counters land in the server's ``ServeStats``.
* **swap safety** — every group records the engine generation its routing
  keys were computed under.  Dispatch pins the engine
  (``QueryEngine.pin``); a group whose generation was superseded by a
  hot-swap before dispatch is *re-routed* under the live generation
  (``requeued_batches``) rather than served against stale bucket ids, and
  a group already in flight finishes on its pinned generation
  (``stale_batches``) — in-flight work never mixes artifacts.

Results come back through :class:`Ticket` futures, scattered into the
submit order of each ticket regardless of which flush group answered them.
"""

from __future__ import annotations

import collections
import threading
import time

import numpy as np

import jax

from repro import obs
from repro.core.packed import empty_results
from repro.obs.locks import make_lock

from typing import TYPE_CHECKING

if TYPE_CHECKING:             # import cycle: engine lazily imports us
    from repro.serving.engine import PathServer


class QueueFull(RuntimeError):
    """Backpressure gate rejection (``policy="shed"`` and the queue is at
    ``max_queue``)."""


class Ticket:
    """Future for one ``submit()`` call (N queries, answered in order)."""

    def __init__(self, n: int, want_argmin: bool):
        self.n = n
        self.want_argmin = want_argmin
        self._outs = empty_results(n, want_argmin)
        self._remaining = n
        self._lock = make_lock("batcher.ticket")
        self._event = threading.Event()
        self.t_submit = time.perf_counter()      # span root (obs.Trace)
        self.completed_at: float | None = None   # perf_counter stamp
        if n == 0:
            self.completed_at = time.perf_counter()
            self._event.set()

    def _write(self, slots: np.ndarray, cols: list) -> None:
        for o, c in zip(self._outs, cols):
            o[slots] = c
        with self._lock:
            self._remaining -= len(slots)
            done = self._remaining == 0
        if done:
            self.completed_at = time.perf_counter()
            self._event.set()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: float | None = None):
        """Block until answered; [N] distances (or the 5-tuple of argmin
        outputs) in submit order."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket incomplete ({self._remaining} of "
                               f"{self.n} queries pending)")
        return tuple(self._outs) if self.want_argmin else self._outs[0]


class _Entry:
    """One queued query: destination ticket slot + endpoints + arrival.

    ``sampled`` is the head-sampling verdict taken once at admission
    (trace objects are only materialized at retire, off the hot path);
    ``requeues`` counts swap-superseded re-routes of this entry."""

    __slots__ = ("ticket", "slot", "s", "t", "arrived", "sampled",
                 "requeues")

    def __init__(self, ticket, slot, s, t, arrived, sampled=False):
        self.ticket = ticket
        self.slot = slot
        self.s = s
        self.t = t
        self.arrived = arrived
        self.sampled = sampled
        self.requeues = 0


class _Flight:
    """A dispatched group awaiting synchronization (the in-flight handle).

    Carries its own ``BucketStats`` row: a generation reset between launch
    and retire replaces ``stats.per_bucket`` wholesale, and retiring into a
    same-keyed row of the *new* generation would count queries against
    slots it never dispatched (occupancy > 1)."""

    __slots__ = ("pin_cm", "eng", "gen", "key", "want_argmin", "entries",
                 "rows", "res", "t_launch", "bstats", "reason", "t_staged",
                 "t_dispatched")

    def __init__(self, pin_cm, eng, gen, key, want_argmin, entries, rows,
                 res, t_launch, bstats, reason, t_staged, t_dispatched):
        self.pin_cm = pin_cm
        self.eng = eng
        self.gen = gen
        self.key = key
        self.want_argmin = want_argmin
        self.entries = entries
        self.rows = rows
        self.res = res
        self.t_launch = t_launch
        self.bstats = bstats
        self.reason = reason            # flush reason (span attribute)
        self.t_staged = t_staged        # stage -> dispatch boundary
        self.t_dispatched = t_dispatched


class CoalescingBatcher:
    """Async coalescing queue + double-buffered dispatch over a PathServer.

    ``server``: the :class:`~repro.serving.engine.PathServer` whose engine,
    ``batch_size`` and ``stats`` this loop serves through.  One batcher per
    server; constructed via ``PathServer.start_async()``.
    """

    def __init__(self, server: "PathServer", max_wait_ms: float = 2.0,
                 max_queue: int = 8192, policy: str = "block",
                 depth: int = 2, autostart: bool = True):
        if policy not in ("block", "shed"):
            raise ValueError(f"policy must be block|shed, got {policy!r}")
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.server = server
        self.max_wait_s = max_wait_ms / 1e3
        self.max_queue = int(max_queue)
        self.policy = policy
        self.depth = int(depth)
        # (generation, routing key, want_argmin) -> FIFO entry list
        self._groups: dict[tuple, list] = {}
        self._queued = 0            # entries waiting in groups
        self._in_flight = 0         # entries staged/dispatched, not retired
        self._force = False         # flush() latch: ship everything queued
        self._closing = False
        self._lock = make_lock("batcher.queue")
        self._cond = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        if autostart:
            self.start()

    # -------------------------------------------------------------- control
    def start(self) -> None:
        """Start the serve loop (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="pathserver-batcher",
                                        daemon=True)
        self._thread.start()

    def flush(self) -> None:
        """Force every queued group to dispatch without waiting for the
        batch to fill or the deadline to expire."""
        with self._cond:
            self._force = True
            self._cond.notify_all()

    def drain(self, timeout: float | None = None) -> bool:
        """Flush, then block until the queue and the pipeline are empty."""
        self.flush()
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while self._queued or self._in_flight:
                left = None if deadline is None \
                    else max(0.0, deadline - time.perf_counter())
                if left == 0.0:
                    return False
                self._cond.wait(timeout=0.02 if left is None
                                else min(0.02, left))
        return True

    def close(self, drain: bool = True) -> None:
        """Stop the serve loop; ``drain=True`` answers everything queued
        first, ``drain=False`` abandons queued work (tickets stay pending)."""
        if drain and self._thread is not None and self._thread.is_alive():
            self.drain()
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return self._queued

    # --------------------------------------------------------------- submit
    def submit(self, s, t, want_argmin: bool = False) -> Ticket:
        """Enqueue N queries; returns a :class:`Ticket` future.

        Routing keys are computed against the engine generation current at
        admission; the dispatch path revalidates them (see module doc).
        Blocks (or sheds) when the backpressure gate is closed.
        """
        s = np.ascontiguousarray(np.asarray(s, np.float32)).reshape(-1, 2)
        t = np.ascontiguousarray(np.asarray(t, np.float32)).reshape(-1, 2)
        n = len(s)
        ticket = Ticket(n, want_argmin)
        if n == 0:
            return ticket
        stats = self.server.stats
        tel = self.server.telemetry
        # head-sampling verdict, once per submit; traces materialize at
        # retire from group timestamps (nothing allocated here)
        sampled = tel.sampler.sample()
        with self.server.engine.pin() as eng:
            gen = eng.generation
            keys = eng.buckets_of(s, t)
        now = time.perf_counter()
        with self._cond:
            if self._closing:
                raise RuntimeError("batcher is closed")
            if self._queued + n > self.max_queue:
                if self.policy == "shed":
                    stats.shed += n
                    tel.events.emit("shed", n=n, queued=self._queued,
                                    max_queue=self.max_queue)
                    if sampled:
                        tr = obs.Trace("async", n=n, argmin=want_argmin,
                                       srv=stats.labels["srv"])
                        tr.stage("admission", now - ticket.t_submit)
                        for st in obs.ASYNC_STAGES:
                            tr.stages.setdefault(st, 0.0)
                        tel.spans.add(tr.close(ticket.t_submit, now,
                                               outcome="shed"))
                    raise QueueFull(
                        f"queue at {self._queued}/{self.max_queue}; "
                        f"rejected {n} queries")
                stats.admission_waits += 1
                # a submit larger than max_queue can never fit beside other
                # work; it admits alone once the queue is empty (transient
                # overshoot) instead of deadlocking on impossible room
                while self._queued + n > self.max_queue and self._queued \
                        and not self._closing:
                    self._cond.wait(timeout=0.02)
                if self._closing:
                    raise RuntimeError("batcher closed while blocked on "
                                       "the admission gate")
            for i in range(n):
                k = int(keys[i])
                gk = (gen, k, want_argmin)
                self._groups.setdefault(gk, []).append(
                    _Entry(ticket, i, s[i], t[i], now, sampled=sampled))
                bs = self.server._bucket_stats(k, eng)
                bs.admitted += 1
            self._queued += n
            stats.submitted += n
            stats.queue_depth = self._queued
            stats.queue_depth_peak = max(stats.queue_depth_peak,
                                         self._queued)
            self._cond.notify_all()
        return ticket

    # ----------------------------------------------------------- serve loop
    def _serve_loop(self) -> None:
        inflight: collections.deque[_Flight] = collections.deque()
        stats = self.server.stats
        while True:
            launched = False
            while len(inflight) < self.depth:
                chunk = self._pop_ready(block=not (inflight or launched))
                if chunk is None:
                    break
                flight = self._launch(*chunk)
                if flight is not None:
                    inflight.append(flight)
                    launched = True
                    stats.pipeline_peak = max(stats.pipeline_peak,
                                              len(inflight))
            if inflight:
                self._retire(inflight.popleft())
            elif self._done():
                return

    def _done(self) -> bool:
        with self._lock:
            return self._closing and not self._queued

    def _pop_ready(self, block: bool):
        """Next dispatchable (gen, key, want_argmin, entries, reason)
        chunk, or None.  ``block=True`` waits (deadline-aware) until one
        exists or the batcher is closing with an empty queue."""
        bs = self.server.batch_size
        stats = self.server.stats
        with self._cond:
            while True:
                best, reason = None, ""
                now = time.perf_counter()
                for gk, entries in self._groups.items():
                    if not entries:
                        continue
                    if len(entries) >= bs:
                        r = "full"
                    elif self._force or self._closing:
                        r = "forced"
                    elif now - entries[0].arrived >= self.max_wait_s:
                        r = "deadline"
                    else:
                        continue
                    if best is None or entries[0].arrived \
                            < self._groups[best][0].arrived:
                        best, reason = gk, r
                if best is not None:
                    entries = self._groups[best]
                    chunk, rest = entries[:bs], entries[bs:]
                    if rest:
                        self._groups[best] = rest
                    else:
                        del self._groups[best]
                        if not any(self._groups.values()):
                            self._force = False
                    self._queued -= len(chunk)
                    stats.queue_depth = self._queued
                    if reason == "full":
                        stats.full_flushes += 1
                    elif reason == "deadline":
                        stats.deadline_flushes += 1
                    else:
                        stats.forced_flushes += 1
                    self._in_flight += len(chunk)
                    self._cond.notify_all()     # admission gate may reopen
                    gen, key, want_argmin = best
                    return gen, key, want_argmin, chunk, reason
                if not block or (self._closing and not self._queued):
                    return None
                self._cond.wait(timeout=self._wait_timeout(now))

    def _wait_timeout(self, now: float) -> float:
        """Sleep until the nearest group deadline (bounded poll)."""
        nearest = None
        for entries in self._groups.values():
            if entries:
                d = entries[0].arrived + self.max_wait_s - now
                nearest = d if nearest is None else min(nearest, d)
        if nearest is None:
            return 0.05
        return float(min(0.05, max(1e-4, nearest)))

    # ------------------------------------------------------------- dispatch
    def _launch(self, gen: int, key: int, want_argmin: bool,
                entries: list, reason: str) -> _Flight | None:
        """Stage + dispatch one chunk under a pinned engine.

        Returns the in-flight handle, or None when the chunk's generation
        was superseded before dispatch — its entries are re-routed under
        the live generation (a *requeue*, not a dispatch: no per-bucket
        batch/slot accounting happens, so padding is never double-counted).
        """
        srv = self.server
        stats = srv.stats
        cm = srv.engine.pin()
        eng = cm.__enter__()
        if eng.generation != gen:
            cm.__exit__(None, None, None)
            self._requeue(entries, want_argmin, old_gen=gen)
            return None
        if eng.generation != stats.generation:
            # first dispatch of a new generation: per-bucket rows describe
            # the previous artifact's routing, so they restart
            stats.swaps += max(0, eng.generation - stats.generation)
            stats.per_bucket = {}
            stats.generation = eng.generation
        n = len(entries)
        rows = srv.batch_size if getattr(eng, "static_shapes", True) else n
        sb = np.zeros((rows, 2), np.float32)
        tb = np.zeros((rows, 2), np.float32)
        for i, e in enumerate(entries):
            sb[i] = e.s
            tb[i] = e.t
        t0 = time.perf_counter()
        staged = eng.stage(sb, tb, bucket=key)
        t_staged = time.perf_counter()
        res = eng.dispatch_staged(staged, bucket=key,
                                  want_argmin=want_argmin)
        t_dispatched = time.perf_counter()
        bstats = srv._bucket_stats(key, eng)
        bstats.batches += 1
        bstats.slots += rows
        if reason == "full":
            bstats.full_flushes += 1
        elif reason == "deadline":
            bstats.deadline_flushes += 1
        stats.batches += 1
        return _Flight(cm, eng, gen, key, want_argmin, entries, rows, res,
                       t0, bstats, reason, t_staged, t_dispatched)

    def _requeue(self, entries: list, want_argmin: bool,
                 old_gen: int = -1) -> None:
        """Re-route a superseded chunk: recompute keys under the live
        generation and put the entries back with their original arrival
        times (deadlines keep counting from first admission)."""
        srv = self.server
        s = np.stack([e.s for e in entries])
        t = np.stack([e.t for e in entries])
        with srv.engine.pin() as eng:
            gen = eng.generation
            keys = eng.buckets_of(s, t)
        with self._cond:
            for e, k in zip(entries, keys):
                e.requeues += 1
                self._groups.setdefault((gen, int(k), want_argmin),
                                        []).append(e)
            self._queued += len(entries)
            self._in_flight -= len(entries)
            srv.stats.requeued_batches += 1
            srv.stats.queue_depth = self._queued
            self._cond.notify_all()
        srv.telemetry.events.emit("requeue", n=len(entries),
                                  from_gen=old_gen, to_gen=gen)

    def _retire(self, f: _Flight) -> None:
        """Synchronize one in-flight group, scatter results into tickets,
        close out stats, release the generation pin."""
        srv = self.server
        stats = srv.stats
        try:
            t_retire = time.perf_counter()
            jax.block_until_ready(f.res)
            t_joined = time.perf_counter()
            dt = t_joined - f.t_launch
            n = len(f.entries)
            outs = [np.asarray(r)[:n] for r in f.res]
            per_ticket: dict = collections.defaultdict(lambda: ([], []))
            for bi, e in enumerate(f.entries):
                rows, slots = per_ticket[e.ticket]
                rows.append(bi)
                slots.append(e.slot)
            for ticket, (rows, slots) in per_ticket.items():
                ridx = np.asarray(rows)
                ticket._write(np.asarray(slots),
                              [o[ridx] for o in outs])
            t_reply = time.perf_counter()
            self._observe(f, per_ticket, t_retire, t_joined, t_reply)
            f.bstats.queries += n
            f.bstats.seconds += dt
            stats.queries += n
            stats.seconds += dt
            if srv.engine.generation != f.gen:
                # a swap published while this group was in flight: it
                # finished on its pinned (now superseded) artifact
                stats.stale_batches += 1
            note = getattr(f.eng, "note_batch_seconds", None)
            if note is not None:
                note(f.key, dt)
            shard_stats = getattr(f.eng, "shard_stats", None)
            if shard_stats is not None:
                stats.per_shard = shard_stats()
            if srv._recorder is not None:
                s = np.stack([e.s for e in f.entries])
                t = np.stack([e.t for e in f.entries])
                srv._recorder.record(s, t)
        finally:
            f.pin_cm.__exit__(None, None, None)
            with self._cond:
                self._in_flight -= len(f.entries)
                self._cond.notify_all()

    # -------------------------------------------------------------- observe
    def _observe(self, f: _Flight, per_ticket: dict, t_retire: float,
                 t_joined: float, t_reply: float) -> None:
        """Record per-stage histograms and materialize span trees.

        Every stage boundary is a timestamp the loop already took for its
        own accounting, so the per-request stage durations *telescope* —
        their sum equals ``t_reply - ticket.t_submit`` exactly — which is
        what makes the span-attribution acceptance gate structural.
        Traces are built only for head-sampled tickets (or retroactively
        for requests over the slow threshold: all stamps survive in the
        flight, so no information was lost by not sampling them)."""
        tel = srv_tel = self.server.telemetry
        reg = tel.registry
        lbl = self.server.stats.labels
        stages = (("queue_wait", f.t_launch - f.entries[0].arrived),
                  ("stage", f.t_staged - f.t_launch),
                  ("dispatch", f.t_dispatched - f.t_staged),
                  ("pipeline_wait", t_retire - f.t_dispatched),
                  ("device_join", t_joined - t_retire),
                  ("reply", t_reply - t_joined))
        for name, dur in stages:
            reg.histogram("stage_ms", stage=name,
                          **lbl).record(max(0.0, dur) * 1e3)
        lat = reg.histogram("request_latency_ms", **lbl)
        lat.record_many([(t_reply - t.t_submit) * 1e3 for t in per_ticket])
        if not (srv_tel.sampler.rate > 0.0 or srv_tel.sampler.slow_ms > 0.0):
            return
        for ticket, (rows, _) in per_ticket.items():
            e2e = t_reply - ticket.t_submit
            ents = [f.entries[i] for i in rows]
            if not (ents[0].sampled or srv_tel.sampler.slow(e2e)):
                continue
            tr = obs.Trace("async", key=f.key, generation=f.gen,
                           flush=f.reason, n=len(ents),
                           argmin=f.want_argmin, srv=lbl["srv"],
                           requeues=max(e.requeues for e in ents))
            # admission: submit entry -> admitted; per-submit stamp pairs
            tr.stage("admission", ents[0].arrived - ticket.t_submit)
            tr.stage("queue_wait", f.t_launch - ents[0].arrived)
            for name, dur in stages[1:]:
                tr.stage(name, dur)
            # rescue is fused into dispatch/device_join by the quantized
            # engines (engine-side counters cover it); unwind only happens
            # on the sync query_paths span — present as explicit zeros so
            # the tree is complete
            tr.stage("rescue", 0.0)
            tr.stage("unwind", 0.0)
            srv_tel.spans.add(tr.close(ticket.t_submit, t_reply))
