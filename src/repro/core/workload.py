"""Query workloads — paper §Experiments.

* ``Unknown``: uniform random free-space pairs (stands in for the MovingAI
  scenario files).
* ``Cluster-x``: x rectangular clusters, side = 10% of map extent, random
  centers in traversable space, each cluster reachable from at least one
  other; queries pick s and t from (possibly different) clusters.
* ``historical_workload``: per-cell counts w_c from a history sample — the
  score initialisation ``s(c) = 1 + w_c`` of workload-aware EHL*.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .geometry import Scene, points_strictly_inside, random_free_points
from .grid import EHLIndex
from .visgraph import VisGraph, astar


@dataclasses.dataclass
class QuerySet:
    name: str
    s: np.ndarray     # [N,2]
    t: np.ndarray     # [N,2]


def _free_points_in_rect(scene: Scene, rect, n, rng,
                         strict: bool = True) -> np.ndarray:
    """Rejection-sample ``n`` free-space points inside ``rect``.

    ``strict=True`` (default) raises if the rect cannot yield ``n`` free
    points after 200 sampling rounds — a short array silently propagating
    into a QuerySet used to surface much later as shape errors downstream.
    ``strict=False`` is the probing mode (``make_clusters`` testing whether
    a candidate rect has enough free space at all).
    """
    x0, y0, x1, y1 = rect
    out = np.zeros((n, 2))
    got = 0
    tries = 0
    while got < n and tries < 200:
        tries += 1
        cand = rng.uniform([x0, y0], [x1, y1], size=(max(32, 2 * (n - got)), 2))
        keep = cand[~points_strictly_inside(scene, cand)]
        take = min(len(keep), n - got)
        out[got:got + take] = keep[:take]
        got += take
    if got < n and strict:
        raise RuntimeError(
            f"only {got}/{n} free points found in rect "
            f"({x0:.2f},{y0:.2f})-({x1:.2f},{y1:.2f}) after 200 sampling "
            "rounds — the rect is (almost) fully covered by obstacles; "
            "pick a different cluster rect or pass strict=False to probe")
    return out[:got]


def make_clusters(scene: Scene, k: int, rng: np.random.Generator,
                  side_frac: float = 0.10) -> list:
    """k cluster rectangles with centers in traversable space."""
    w, h = scene.width, scene.height
    sw, sh = side_frac * w, side_frac * h
    rects = []
    while len(rects) < k:
        c = random_free_points(scene, 1, rng)[0]
        x0 = min(max(c[0] - sw / 2, 0.0), w - sw)
        y0 = min(max(c[1] - sh / 2, 0.0), h - sh)
        rect = (x0, y0, x0 + sw, y0 + sh)
        if len(_free_points_in_rect(scene, rect, 4, rng, strict=False)) >= 4:
            rects.append(rect)
    return rects


def cluster_queries(scene: Scene, graph: VisGraph, k: int, n: int,
                    seed: int = 0, require_path: bool = True) -> QuerySet:
    """Cluster-k query set (paper's synthetic known-distribution workload)."""
    rng = np.random.default_rng(seed)
    rects = make_clusters(scene, k, rng)
    S, T = [], []
    guard = 0
    while len(S) < n and guard < 50 * n:
        guard += 1
        ra, rb = rng.integers(0, k, size=2)
        # rects are pre-validated by make_clusters to contain free points,
        # so strict sampling raising here is a real error, not bad luck
        ps = _free_points_in_rect(scene, rects[ra], 1, rng)
        pt = _free_points_in_rect(scene, rects[rb], 1, rng)
        if require_path:
            d, _ = astar(graph, ps[0], pt[0])
            if not np.isfinite(d):
                continue
        S.append(ps[0])
        T.append(pt[0])
    return QuerySet(name=f"Cluster-{k}", s=np.array(S), t=np.array(T))


def uniform_queries(scene: Scene, graph: VisGraph, n: int, seed: int = 0,
                    require_path: bool = True) -> QuerySet:
    rng = np.random.default_rng(seed)
    S, T = [], []
    guard = 0
    while len(S) < n and guard < 50 * n:
        guard += 1
        p = random_free_points(scene, 2, rng)
        if require_path:
            d, _ = astar(graph, p[0], p[1])
            if not np.isfinite(d):
                continue
        S.append(p[0])
        T.append(p[1])
    return QuerySet(name="Unknown", s=np.array(S), t=np.array(T))


def mixed_queries(cluster_qs: QuerySet, uniform_qs: QuerySet,
                  adherence: float, seed: int = 0) -> QuerySet:
    """Deviation workload (Table 6): y% cluster queries, rest uniform."""
    rng = np.random.default_rng(seed)
    n = min(len(cluster_qs.s), len(uniform_qs.s))
    pick = rng.random(n) < adherence
    s = np.where(pick[:, None], cluster_qs.s[:n], uniform_qs.s[:n])
    t = np.where(pick[:, None], cluster_qs.t[:n], uniform_qs.t[:n])
    return QuerySet(name=f"Mixed-{int(adherence * 100)}", s=s, t=t)


def historical_workload(index: EHLIndex, qs: QuerySet) -> np.ndarray:
    """Per-cell workload w_c = # historical queries with s or t in c."""
    w = np.zeros(index.nx * index.ny, dtype=np.float64)
    for p in np.concatenate([qs.s, qs.t]):
        w[index.cell_of_point(p)] += 1.0
    return w


def workload_scores(index: EHLIndex, qs: QuerySet) -> np.ndarray:
    """Paper's workload-aware initialisation: s(c) = 1 + w_c."""
    return 1.0 + historical_workload(index, qs)
