"""EHL* core — the paper's contribution.

Offline: Scene -> visibility graph -> hub labels -> EHL grid index ->
EHL* budgeted compression (Algorithm 1).  Online: Eq. 1-3 query processing
(scalar reference here; batched JAX/Pallas engine in ``repro.core.packed`` +
``repro.kernels``).
"""

from .geometry import (Scene, edist, visible, visible_batch,  # noqa: F401
                       blocked_strict_batch, segments_block_strict)
from .edgegrid import (EdgeGrid, build_edge_grid,           # noqa: F401
                       gather_edge_tiles, segvis_grid)
from .visgraph import VisGraph, build_visgraph, astar       # noqa: F401
from .hublabel import HubLabels, build_hub_labels           # noqa: F401
from .grid import EHLIndex, Region, build_ehl, LABEL_BYTES  # noqa: F401
from .compression import (compress, compress_to_fraction,   # noqa: F401
                          compress_incremental,
                          compress_to_device_budget,
                          rescore_regions,
                          CompressionStats, jaccard)
from .query import query, query_distance, path_length       # noqa: F401
from .query import unwind_path                              # noqa: F401
from .packed import (PackedIndex, BucketedIndex,            # noqa: F401
                     SlabLayout, LAYOUT_F32, slab_layout,
                     dtype_bytes, ResidualTable,
                     pack_index, pack_bucketed, plan_buckets,
                     pack_bucketed_split, padded_edge_count,
                     slab_device_bytes, slab_label_slots,
                     bucketed_device_bytes,
                     query_batch, query_batch_argmin,
                     query_batch_bucketed, dispatch_buckets,
                     locate_regions,
                     gather_labels_at_width, join_gathered,
                     gather_masked_labels, join_masked, covis_blocked,
                     rescue_exact, splice_rescue, wire_dtypes)
from .workload import (QuerySet, make_clusters,             # noqa: F401
                       cluster_queries, uniform_queries, mixed_queries,
                       historical_workload, workload_scores)
from .maps import make_map                                  # noqa: F401
from . import maps, workload                                # noqa: F401
