"""EHL* core — the paper's contribution.

Offline: Scene -> visibility graph -> hub labels -> EHL grid index ->
EHL* budgeted compression (Algorithm 1).  Online: Eq. 1-3 query processing
(scalar reference here; batched JAX/Pallas engine in ``repro.core.packed`` +
``repro.kernels``).
"""

from .geometry import Scene, edist, visible, visible_batch  # noqa: F401
from .visgraph import VisGraph, build_visgraph, astar       # noqa: F401
from .hublabel import HubLabels, build_hub_labels           # noqa: F401
from .grid import EHLIndex, Region, build_ehl, LABEL_BYTES  # noqa: F401
from .compression import (compress, compress_to_fraction,   # noqa: F401
                          CompressionStats, jaccard)
from .query import query, query_distance, path_length       # noqa: F401
from . import maps, workload                                # noqa: F401
