"""Dense padded tensor form of an EHL/EHL* index — the TPU-resident artifact.

The host-side index (``repro.core.grid``) stores ragged per-region label
lists.  The online engine needs contiguous, gatherable tensors:

* ``hub_ids / via_ids / via_xy / via_d``: ``[R, L]`` region-major label slabs,
  sorted by hub id inside each region and padded to ``L = Lmax`` (rounded up
  to a multiple of ``lane``) with a sentinel hub — EHL*'s memory budget
  directly caps ``Lmax`` and hence the padding waste, which is exactly why
  the compression phase matters on TPU.
* ``edges_*``: flat obstacle-edge tensors for the query-time visibility
  predicate (strict proper-crossing semantics; see DESIGN.md on the
  measure-zero deviation from the exact host predicate).
* ``mapper``: cell -> region row, so point location stays O(1).

Everything is float32/int32; the host oracle is float64 — tests compare with
~1e-5 tolerances.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .grid import EHLIndex

HUB_PAD = np.int32(2 ** 30)     # sorts after every real hub id


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedIndex:
    """Pytree of device arrays (static geometry in ``aux``)."""

    hub_ids: jnp.ndarray    # [R, L] int32, HUB_PAD padded, sorted per row
    via_xy: jnp.ndarray     # [R, L, 2] float32
    via_d: jnp.ndarray      # [R, L] float32 (+inf on pads)
    via_ids: jnp.ndarray    # [R, L] int32 (-1 pads) — for path unwinding
    mapper: jnp.ndarray     # [C] int32 cell -> region row
    edges_a: jnp.ndarray    # [E, 2] float32 (repeat-padded)
    edges_b: jnp.ndarray    # [E, 2] float32
    # static metadata
    nx: int
    ny: int
    cell_size: float
    width: float
    height: float

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.edges_a, self.edges_b)
        aux = (self.nx, self.ny, self.cell_size, self.width, self.height)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- properties ----------------------------------------------------------
    @property
    def num_regions(self) -> int:
        return self.hub_ids.shape[0]

    @property
    def label_width(self) -> int:
        return self.hub_ids.shape[1]

    @property
    def num_edges(self) -> int:
        return self.edges_a.shape[0]

    def device_bytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize for a in
                   (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.edges_a, self.edges_b))


def pack_index(index: EHLIndex, lane: int = 128,
               region_pad_multiple: int = 1) -> PackedIndex:
    """Freeze a (possibly compressed) host index into dense device tensors."""
    live = sorted(index.regions.keys())
    row_of = {rid: i for i, rid in enumerate(live)}
    R = _round_up(len(live), region_pad_multiple)

    packs = [index.pack_region(index.regions[rid]) for rid in live]
    Lmax = max((len(p["hubs"]) for p in packs), default=1)
    L = _round_up(max(Lmax, 1), lane)

    hub_ids = np.full((R, L), HUB_PAD, dtype=np.int32)
    via_xy = np.zeros((R, L, 2), dtype=np.float32)
    via_d = np.full((R, L), np.inf, dtype=np.float32)
    via_ids = np.full((R, L), -1, dtype=np.int32)
    for i, p in enumerate(packs):
        k = len(p["hubs"])
        hub_ids[i, :k] = p["hubs"]
        via_xy[i, :k] = p["via_xy"]
        via_d[i, :k] = p["d"]
        via_ids[i, :k] = p["vias"]

    mapper = np.zeros(index.mapper.size, dtype=np.int32)
    for ci, rid in enumerate(index.mapper):
        mapper[ci] = row_of[int(rid)]

    E = index.scene.edges.shape[0]
    Ep = _round_up(max(E, 1), lane)
    ea = np.zeros((Ep, 2), dtype=np.float32)
    eb = np.zeros((Ep, 2), dtype=np.float32)
    if E:
        ea[:E] = index.scene.edges[:, 0]
        eb[:E] = index.scene.edges[:, 1]
        ea[E:] = index.scene.edges[0, 0]   # repeat-pad: degenerate repeats
        eb[E:] = index.scene.edges[0, 1]   # never change the OR-reduction
    return PackedIndex(
        hub_ids=jnp.asarray(hub_ids), via_xy=jnp.asarray(via_xy),
        via_d=jnp.asarray(via_d), via_ids=jnp.asarray(via_ids),
        mapper=jnp.asarray(mapper), edges_a=jnp.asarray(ea),
        edges_b=jnp.asarray(eb), nx=index.nx, ny=index.ny,
        cell_size=float(index.cell_size), width=float(index.scene.width),
        height=float(index.scene.height))


def narrow_view(pk: PackedIndex, width: int) -> tuple[PackedIndex, jnp.ndarray]:
    """Width-bucketed view: the first ``width`` label slots of every region.

    Beyond-paper optimization (EXPERIMENTS.md §Perf iteration D): global
    padding is governed by the single largest merged region, so most queries
    pay O(Lmax^2) join + O(Lmax*E) visibility for labels that are padding.
    Queries whose BOTH endpoint regions hold <= width labels are answered
    exactly by this truncated view; the returned [R] mask says which regions
    qualify.  Routing happens in the serving engine / query_batch_bucketed.
    """
    ok = jnp.asarray((np.asarray(pk.hub_ids) != HUB_PAD).sum(1) <= width)
    nv = PackedIndex(
        hub_ids=pk.hub_ids[:, :width], via_xy=pk.via_xy[:, :width],
        via_d=pk.via_d[:, :width], via_ids=pk.via_ids[:, :width],
        mapper=pk.mapper, edges_a=pk.edges_a, edges_b=pk.edges_b,
        nx=pk.nx, ny=pk.ny, cell_size=pk.cell_size, width=pk.width,
        height=pk.height)
    return nv, ok


def query_batch_bucketed(pk: PackedIndex, nv: PackedIndex, ok: jnp.ndarray,
                         s: jnp.ndarray, t: jnp.ndarray,
                         use_kernels: bool = False) -> jnp.ndarray:
    """Two-tier routing: narrow view where both regions fit, full otherwise.

    Shapes stay static (both paths run over the full batch with masking), so
    on TPU this trades a cheap narrow pass + a masked wide pass; the wide
    pass only pays for the (rare) oversized-region queries when batches are
    region-sorted upstream (PathServer does this).
    """
    rs = locate_regions(pk, s)
    rt = locate_regions(pk, t)
    fast = ok[rs] & ok[rt]
    d_narrow = query_batch(nv, s, t, use_kernels=use_kernels)
    d_full = query_batch(pk, s, t, use_kernels=use_kernels)
    return jnp.where(fast, d_narrow, d_full)


# ---------------------------------------------------------------------------
# batched query engine (pure jnp; kernels plug in via repro.kernels.ops)
# ---------------------------------------------------------------------------

def locate_regions(idx: PackedIndex, pts: jnp.ndarray) -> jnp.ndarray:
    """[B] region rows for query points (floor-div + mapper, O(1))."""
    ix = jnp.clip((pts[:, 0] / idx.cell_size).astype(jnp.int32), 0, idx.nx - 1)
    iy = jnp.clip((pts[:, 1] / idx.cell_size).astype(jnp.int32), 0, idx.ny - 1)
    return idx.mapper[iy * idx.nx + ix]


@partial(jax.jit, static_argnames=("use_kernels",))
def query_batch(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray,
                use_kernels: bool = False) -> jnp.ndarray:
    """Batched Eq. 1-3: shortest distances for query pairs [B,2]x[B,2].

    use_kernels=True routes visibility + join through the Pallas kernels
    (``repro.kernels.ops``); False uses their jnp references — identical
    semantics, asserted by tests.
    """
    from repro.kernels import ops

    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    rs = locate_regions(idx, s)
    rt = locate_regions(idx, t)

    hub_s = idx.hub_ids[rs]          # [B, L]
    hub_t = idx.hub_ids[rt]
    xy_s = idx.via_xy[rs]            # [B, L, 2]
    xy_t = idx.via_xy[rt]
    d_s = idx.via_d[rs]              # [B, L]
    d_t = idx.via_d[rt]

    segvis = ops.segvis_kernel if use_kernels else ops.segvis_ref
    join = ops.label_join_kernel if use_kernels else ops.label_join_ref

    B, L = hub_s.shape
    # visibility of each via vertex from its query point  [B, L]
    vis_s = segvis(jnp.repeat(s, L, axis=0), xy_s.reshape(-1, 2),
                   idx.edges_a, idx.edges_b).reshape(B, L)
    vis_t = segvis(jnp.repeat(t, L, axis=0), xy_t.reshape(-1, 2),
                   idx.edges_a, idx.edges_b).reshape(B, L)

    inf = jnp.float32(jnp.inf)
    vd_s = jnp.where(vis_s, jnp.linalg.norm(s[:, None] - xy_s, axis=-1) + d_s, inf)
    vd_t = jnp.where(vis_t, jnp.linalg.norm(t[:, None] - xy_t, axis=-1) + d_t, inf)

    d_label = join(hub_s, vd_s, hub_t, vd_t)            # [B]

    covis = segvis(s, t, idx.edges_a, idx.edges_b)       # [B]
    d_direct = jnp.linalg.norm(s - t, axis=-1)
    return jnp.where(covis, d_direct, d_label)


@partial(jax.jit, static_argnames=())
def query_batch_argmin(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray):
    """Distances + winning (via_s, hub, via_t) label ids (path unwinding)."""
    from repro.kernels import ops

    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    rs = locate_regions(idx, s)
    rt = locate_regions(idx, t)
    hub_s, hub_t = idx.hub_ids[rs], idx.hub_ids[rt]
    xy_s, xy_t = idx.via_xy[rs], idx.via_xy[rt]
    d_s, d_t = idx.via_d[rs], idx.via_d[rt]
    B, L = hub_s.shape
    vis_s = ops.segvis_ref(jnp.repeat(s, L, axis=0), xy_s.reshape(-1, 2),
                           idx.edges_a, idx.edges_b).reshape(B, L)
    vis_t = ops.segvis_ref(jnp.repeat(t, L, axis=0), xy_t.reshape(-1, 2),
                           idx.edges_a, idx.edges_b).reshape(B, L)
    inf = jnp.float32(jnp.inf)
    vd_s = jnp.where(vis_s, jnp.linalg.norm(s[:, None] - xy_s, axis=-1) + d_s, inf)
    vd_t = jnp.where(vis_t, jnp.linalg.norm(t[:, None] - xy_t, axis=-1) + d_t, inf)

    eq = hub_s[:, :, None] == hub_t[:, None, :]
    tot = jnp.where(eq, vd_s[:, :, None] + vd_t[:, None, :], inf)   # [B,L,L]
    flat = tot.reshape(B, -1)
    k = jnp.argmin(flat, axis=1)
    i, j = k // L, k % L
    d_label = jnp.take_along_axis(flat, k[:, None], axis=1)[:, 0]

    covis = ops.segvis_ref(s, t, idx.edges_a, idx.edges_b)
    d = jnp.where(covis, jnp.linalg.norm(s - t, axis=-1), d_label)
    via_s = jnp.take_along_axis(idx.via_ids[rs], i[:, None], 1)[:, 0]
    via_t = jnp.take_along_axis(idx.via_ids[rt], j[:, None], 1)[:, 0]
    hub = jnp.take_along_axis(hub_s, i[:, None], 1)[:, 0]
    return d, covis, via_s, hub, via_t
