"""Dense tensor forms of an EHL/EHL* index — the TPU-resident artifact.

The host-side index (``repro.core.grid``) stores ragged per-region label
lists.  The online engine needs contiguous, gatherable tensors.  Two layouts
are provided (DESIGN.md §4):

* :class:`PackedIndex` — the single ``[R, Lmax]`` slab: every region padded
  to the global maximum label count.  Simple, one jit cache entry, but one
  oversized merged region inflates both ``device_bytes()`` and the O(L^2)
  label join for *every* query — the padding waste EHL*'s budget is supposed
  to eliminate.
* :class:`BucketedIndex` — regions grouped into power-of-two width buckets
  (multiples of ``lane``), each bucket its own dense slab, plus a
  ``region -> (bucket, row)`` indirection behind the cell mapper.
  ``device_bytes()`` then tracks the true EHL* budget, and queries dispatch
  per bucket so they only pay for the label width their regions actually
  need (``query_batch_at_bucket`` / the PathServer router).

Shared across layouts:

* ``edges_*``: flat obstacle-edge tensors for the query-time visibility
  predicate (strict proper-crossing semantics; see DESIGN.md §5 on the
  measure-zero deviation from the exact host predicate).
* ``mapper``: cell -> region row (single slab) or cell -> region id
  (bucketed), so point location stays O(1).
* one distance/join core (:func:`_labels_to_distances`) used by every entry
  point — plain distances and argmin (path unwinding) are the same code
  path with a flag, for both the jnp reference and the Pallas kernels.

Everything is float32/int32; the host oracle is float64 — tests compare with
~1e-5 tolerances.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .grid import EHLIndex

HUB_PAD = np.int32(2 ** 30)     # sorts after every real hub id


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def bucket_width(n_labels: int, lane: int = 128) -> int:
    """Smallest power-of-two multiple of ``lane`` holding ``n_labels``."""
    w = lane
    while w < n_labels:
        w *= 2
    return w


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedIndex:
    """Single-slab layout: pytree of device arrays (static geometry in aux)."""

    hub_ids: jnp.ndarray    # [R, L] int32, HUB_PAD padded, sorted per row
    via_xy: jnp.ndarray     # [R, L, 2] float32
    via_d: jnp.ndarray      # [R, L] float32 (+inf on pads)
    via_ids: jnp.ndarray    # [R, L] int32 (-1 pads) — for path unwinding
    mapper: jnp.ndarray     # [C] int32 cell -> region row
    edges_a: jnp.ndarray    # [E, 2] float32 (repeat-padded)
    edges_b: jnp.ndarray    # [E, 2] float32
    # static metadata
    nx: int
    ny: int
    cell_size: float
    width: float
    height: float

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.edges_a, self.edges_b)
        aux = (self.nx, self.ny, self.cell_size, self.width, self.height)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- properties ----------------------------------------------------------
    @property
    def num_regions(self) -> int:
        return self.hub_ids.shape[0]

    @property
    def label_width(self) -> int:
        return self.hub_ids.shape[1]

    @property
    def num_edges(self) -> int:
        return self.edges_a.shape[0]

    def device_bytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize for a in
                   (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.edges_a, self.edges_b))

    def label_slots(self) -> tuple[int, int]:
        """(used, total) label slots — padding waste is total - used."""
        used = int((np.asarray(self.hub_ids) != HUB_PAD).sum())
        return used, int(np.prod(self.hub_ids.shape))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BucketedIndex:
    """Width-bucketed layout: one dense slab per power-of-two label width.

    Region ``r`` lives at ``(region_bucket[r], region_row[r])``; slab ``k``
    has shape ``[R_k, widths[k]]``.  The mapper resolves cells to region ids
    (not rows), so point location composes with the indirection in O(1).
    """

    hub_ids: tuple          # per bucket: [R_k, W_k] int32, HUB_PAD padded
    via_xy: tuple           # per bucket: [R_k, W_k, 2] float32
    via_d: tuple            # per bucket: [R_k, W_k] float32 (+inf pads)
    via_ids: tuple          # per bucket: [R_k, W_k] int32 (-1 pads)
    mapper: jnp.ndarray     # [C] int32 cell -> region id
    region_bucket: jnp.ndarray  # [R] int32 region id -> bucket
    region_row: jnp.ndarray     # [R] int32 region id -> row in its slab
    edges_a: jnp.ndarray    # [E, 2] float32 (repeat-padded)
    edges_b: jnp.ndarray    # [E, 2] float32
    # static metadata
    nx: int
    ny: int
    cell_size: float
    width: float
    height: float
    widths: tuple           # per-bucket label width, strictly increasing

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.region_bucket, self.region_row,
                    self.edges_a, self.edges_b)
        aux = (self.nx, self.ny, self.cell_size, self.width, self.height,
               self.widths)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- properties ----------------------------------------------------------
    @property
    def num_buckets(self) -> int:
        return len(self.widths)

    @property
    def num_regions(self) -> int:
        return self.region_bucket.shape[0]

    @property
    def label_width(self) -> int:
        """Widest bucket — what a single slab would pad everything to."""
        return self.widths[-1] if self.widths else 0

    @property
    def num_edges(self) -> int:
        return self.edges_a.shape[0]

    def device_bytes(self) -> int:
        slabs = sum(np.prod(a.shape) * a.dtype.itemsize
                    for group in (self.hub_ids, self.via_xy, self.via_d,
                                  self.via_ids)
                    for a in group)
        return int(slabs) + sum(np.prod(a.shape) * a.dtype.itemsize for a in
                                (self.mapper, self.region_bucket,
                                 self.region_row, self.edges_a, self.edges_b))

    def bucket_stats(self) -> list[dict]:
        """Per-bucket occupancy: regions, used/total label slots, waste."""
        out = []
        for k, w in enumerate(self.widths):
            hub = np.asarray(self.hub_ids[k])
            used = int((hub != HUB_PAD).sum())
            total = int(np.prod(hub.shape))
            out.append(dict(bucket=k, width=w, regions=hub.shape[0],
                            used_slots=used, total_slots=total,
                            waste=1.0 - used / max(1, total)))
        return out

    def label_slots(self) -> tuple[int, int]:
        """(used, total) label slots across all buckets."""
        st = self.bucket_stats()
        return (sum(s["used_slots"] for s in st),
                sum(s["total_slots"] for s in st))


# ---------------------------------------------------------------------------
# packing (host -> device layouts)
# ---------------------------------------------------------------------------

def _host_packs(index: EHLIndex):
    """Live regions in rid order with their packed (ragged) label arrays."""
    live = sorted(index.regions.keys())
    packs = [index.pack_region(index.regions[rid]) for rid in live]
    return live, packs


def _fill_row(arrs, i, p):
    hub_ids, via_xy, via_d, via_ids = arrs
    k = len(p["hubs"])
    hub_ids[i, :k] = p["hubs"]
    via_xy[i, :k] = p["via_xy"]
    via_d[i, :k] = p["d"]
    via_ids[i, :k] = p["vias"]


def _alloc_slab(rows: int, width: int):
    return (np.full((rows, width), HUB_PAD, dtype=np.int32),
            np.zeros((rows, width, 2), dtype=np.float32),
            np.full((rows, width), np.inf, dtype=np.float32),
            np.full((rows, width), -1, dtype=np.int32))


def _cell_mapper(index: EHLIndex, live: list) -> np.ndarray:
    """[C] int32 cell -> dense index into the live-region ordering."""
    row_of = {rid: i for i, rid in enumerate(live)}
    mapper = np.zeros(index.mapper.size, dtype=np.int32)
    for ci, rid in enumerate(index.mapper):
        mapper[ci] = row_of[int(rid)]
    return mapper


def _pack_edges(index: EHLIndex, lane: int):
    E = index.scene.edges.shape[0]
    Ep = _round_up(max(E, 1), lane)
    ea = np.zeros((Ep, 2), dtype=np.float32)
    eb = np.zeros((Ep, 2), dtype=np.float32)
    if E:
        ea[:E] = index.scene.edges[:, 0]
        eb[:E] = index.scene.edges[:, 1]
        ea[E:] = index.scene.edges[0, 0]   # repeat-pad: degenerate repeats
        eb[E:] = index.scene.edges[0, 1]   # never change the OR-reduction
    return ea, eb


def slab_label_slots(index: EHLIndex, lane: int = 128,
                     region_pad_multiple: int = 1) -> tuple[int, int]:
    """(used, total) label slots of the would-be single slab, analytically."""
    counts = index.packed_label_counts()
    R = _round_up(max(1, len(counts)), region_pad_multiple)
    L = _round_up(max(1, int(counts.max(initial=1))), lane)
    return int(counts.sum()), R * L


def slab_device_bytes(index: EHLIndex, lane: int = 128,
                      region_pad_multiple: int = 1) -> int:
    """What ``pack_index(...).device_bytes()`` would be, without packing.

    Lets callers report the single-slab footprint for comparison against the
    bucketed layout without materializing the global-Lmax slab on device.
    """
    _, slots = slab_label_slots(index, lane, region_pad_multiple)
    per_slot = 4 + 8 + 4 + 4          # hub_ids + via_xy + via_d + via_ids
    Ep = _round_up(max(1, index.scene.edges.shape[0]), lane)
    return slots * per_slot + index.mapper.size * 4 + 2 * Ep * 2 * 4


def pack_index(index: EHLIndex, lane: int = 128,
               region_pad_multiple: int = 1) -> PackedIndex:
    """Freeze a (possibly compressed) host index into one global-Lmax slab."""
    live, packs = _host_packs(index)
    R = _round_up(len(live), region_pad_multiple)

    Lmax = max((len(p["hubs"]) for p in packs), default=1)
    L = _round_up(max(Lmax, 1), lane)

    arrs = _alloc_slab(R, L)
    for i, p in enumerate(packs):
        _fill_row(arrs, i, p)

    mapper = _cell_mapper(index, live)
    ea, eb = _pack_edges(index, lane)
    return PackedIndex(
        hub_ids=jnp.asarray(arrs[0]), via_xy=jnp.asarray(arrs[1]),
        via_d=jnp.asarray(arrs[2]), via_ids=jnp.asarray(arrs[3]),
        mapper=jnp.asarray(mapper), edges_a=jnp.asarray(ea),
        edges_b=jnp.asarray(eb), nx=index.nx, ny=index.ny,
        cell_size=float(index.cell_size), width=float(index.scene.width),
        height=float(index.scene.height))


def plan_buckets(index: EHLIndex, lane: int = 128
                 ) -> tuple[list, list, np.ndarray]:
    """Bucket assignment from the grid's pack metadata — no device arrays.

    Returns (per-region label counts, bucket widths, region -> bucket).
    Single definition shared by ``pack_bucketed`` and the analytic
    accounting helpers below.
    """
    counts = [max(1, int(c)) for c in index.packed_label_counts()]
    widths = sorted({bucket_width(c, lane) for c in counts}) or [lane]
    bucket_of_width = {w: k for k, w in enumerate(widths)}
    region_bucket = np.array([bucket_of_width[bucket_width(c, lane)]
                              for c in counts], dtype=np.int32)
    return counts, widths, region_bucket


def bucketed_device_bytes(index: EHLIndex, lane: int = 128) -> int:
    """What ``pack_bucketed(...).device_bytes()`` would be, without packing."""
    counts, widths, region_bucket = plan_buckets(index, lane)
    per_slot = 4 + 8 + 4 + 4          # hub_ids + via_xy + via_d + via_ids
    slabs = sum(max(1, int((region_bucket == k).sum())) * w * per_slot
                for k, w in enumerate(widths))
    Ep = _round_up(max(1, index.scene.edges.shape[0]), lane)
    return (slabs + index.mapper.size * 4 + 2 * len(counts) * 4
            + 2 * Ep * 2 * 4)


def pack_bucketed(index: EHLIndex, lane: int = 128,
                  reuse_edges_from: "BucketedIndex | PackedIndex | None" = None
                  ) -> BucketedIndex:
    """Freeze a host index into width-bucketed slabs (DESIGN.md §4).

    Each region goes into the smallest power-of-two-multiple-of-``lane``
    bucket that holds its label count, so padding waste is < 50% per region
    instead of being governed by the single largest merged region.

    ``reuse_edges_from``: repack-from-index fast path for the adaptive
    hot-swap loop — the scene (and thus the padded edge tensors) never
    changes across recompressions, so the previous artifact's device-resident
    ``edges_a``/``edges_b`` are aliased instead of re-uploaded.  Region packs
    untouched since the last pack are already reused via the per-region
    ``packed`` cache (:meth:`EHLIndex.pack_region`).
    """
    live, packs = _host_packs(index)
    counts, widths, region_bucket = plan_buckets(index, lane)
    region_row = np.zeros(len(live), dtype=np.int32)
    members: list[list[int]] = [[] for _ in widths]
    for i, b in enumerate(region_bucket):
        region_row[i] = len(members[b])
        members[b].append(i)

    slabs = []
    for k, w in enumerate(widths):
        arrs = _alloc_slab(max(1, len(members[k])), w)
        for row, i in enumerate(members[k]):
            _fill_row(arrs, row, packs[i])
        slabs.append(arrs)

    mapper = _cell_mapper(index, live)
    if reuse_edges_from is not None:
        ea, eb = reuse_edges_from.edges_a, reuse_edges_from.edges_b
    else:
        ea, eb = _pack_edges(index, lane)
    return BucketedIndex(
        hub_ids=tuple(jnp.asarray(a[0]) for a in slabs),
        via_xy=tuple(jnp.asarray(a[1]) for a in slabs),
        via_d=tuple(jnp.asarray(a[2]) for a in slabs),
        via_ids=tuple(jnp.asarray(a[3]) for a in slabs),
        mapper=jnp.asarray(mapper),
        region_bucket=jnp.asarray(region_bucket),
        region_row=jnp.asarray(region_row),
        edges_a=jnp.asarray(ea), edges_b=jnp.asarray(eb),
        nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
        width=float(index.scene.width), height=float(index.scene.height),
        widths=tuple(widths))


# ---------------------------------------------------------------------------
# batched query engine (pure jnp; kernels plug in via repro.kernels.ops)
# ---------------------------------------------------------------------------

def locate_regions(idx, pts: jnp.ndarray) -> jnp.ndarray:
    """[B] region rows/ids for query points (floor-div + mapper, O(1)).

    Works for both layouts: PackedIndex's mapper yields slab rows,
    BucketedIndex's yields region ids (resolve via region_bucket/row).
    """
    ix = jnp.clip((pts[:, 0] / idx.cell_size).astype(jnp.int32), 0, idx.nx - 1)
    iy = jnp.clip((pts[:, 1] / idx.cell_size).astype(jnp.int32), 0, idx.ny - 1)
    return idx.mapper[iy * idx.nx + ix]


def _labels_to_distances(labels_s, labels_t, s, t, edges_a, edges_b,
                         use_kernels: bool, want_argmin: bool):
    """Shared Eq. 1-3 core: per-endpoint labels -> distances (+ argmin ids).

    ``labels_*`` are (hub_ids [B,L], via_xy [B,L,2], via_d [B,L],
    via_ids [B,L]) gathered for each query endpoint.  One code path serves
    ``query_batch``, ``query_batch_argmin`` and the bucketed dispatch, for
    both the jnp reference ops and the Pallas kernels: the join emits the
    row-min form ``rowmin[b,i] = vd_s[b,i] + min_{hub match j} vd_t[b,j]``
    and the argmin pair is recovered with two cheap O(L) reductions.
    """
    from repro.kernels import ops

    hub_s, xy_s, d_s, vid_s = labels_s
    hub_t, xy_t, d_t, vid_t = labels_t
    segvis = ops.segvis_kernel if use_kernels else ops.segvis_ref
    rowmin_join = (ops.label_join_rowmin_kernel if use_kernels
                   else ops.label_join_rowmin_ref)

    B, L = hub_s.shape
    # visibility of each via vertex from its query point  [B, L]
    vis_s = segvis(jnp.repeat(s, L, axis=0), xy_s.reshape(-1, 2),
                   edges_a, edges_b).reshape(B, L)
    vis_t = segvis(jnp.repeat(t, L, axis=0), xy_t.reshape(-1, 2),
                   edges_a, edges_b).reshape(B, L)

    inf = jnp.float32(jnp.inf)
    vd_s = jnp.where(vis_s, jnp.linalg.norm(s[:, None] - xy_s, axis=-1) + d_s,
                     inf)
    vd_t = jnp.where(vis_t, jnp.linalg.norm(t[:, None] - xy_t, axis=-1) + d_t,
                     inf)

    rowmin = rowmin_join(hub_s, vd_s, hub_t, vd_t)      # [B, L]
    d_label = rowmin.min(axis=-1)

    covis = segvis(s, t, edges_a, edges_b)              # [B]
    d_direct = jnp.linalg.norm(s - t, axis=-1)
    d = jnp.where(covis, d_direct, d_label)
    if not want_argmin:
        return d

    # winning (i, j): i minimizes the row join; with i's hub fixed, j is the
    # min-vd_t label sharing that hub (ties resolve to the first index, same
    # as the historical flat [L,L] argmin).
    i = jnp.argmin(rowmin, axis=-1)                     # [B]
    hub_i = jnp.take_along_axis(hub_s, i[:, None], 1)   # [B, 1]
    vd_t_match = jnp.where(hub_t == hub_i, vd_t, inf)
    j = jnp.argmin(vd_t_match, axis=-1)                 # [B]
    via_s = jnp.take_along_axis(vid_s, i[:, None], 1)[:, 0]
    via_t = jnp.take_along_axis(vid_t, j[:, None], 1)[:, 0]
    hub = hub_i[:, 0]
    return d, covis, via_s, hub, via_t


def _gather_packed(idx: PackedIndex, rows: jnp.ndarray):
    return (idx.hub_ids[rows], idx.via_xy[rows], idx.via_d[rows],
            idx.via_ids[rows])


@partial(jax.jit, static_argnames=("use_kernels",))
def query_batch(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray,
                use_kernels: bool = False) -> jnp.ndarray:
    """Batched Eq. 1-3: shortest distances for query pairs [B,2]x[B,2].

    use_kernels=True routes visibility + join through the Pallas kernels
    (``repro.kernels.ops``); False uses their jnp references — identical
    semantics, asserted by tests.
    """
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    rs = locate_regions(idx, s)
    rt = locate_regions(idx, t)
    return _labels_to_distances(
        _gather_packed(idx, rs), _gather_packed(idx, rt), s, t,
        idx.edges_a, idx.edges_b, use_kernels, want_argmin=False)


@partial(jax.jit, static_argnames=("use_kernels",))
def query_batch_argmin(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray,
                       use_kernels: bool = False):
    """Distances + winning (via_s, hub, via_t) label ids (path unwinding)."""
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    rs = locate_regions(idx, s)
    rt = locate_regions(idx, t)
    return _labels_to_distances(
        _gather_packed(idx, rs), _gather_packed(idx, rt), s, t,
        idx.edges_a, idx.edges_b, use_kernels, want_argmin=True)


# ---------------------------------------------------------------------------
# bucketed dispatch
# ---------------------------------------------------------------------------

def _gather_bucketed(bx: BucketedIndex, regions: jnp.ndarray, bucket: int,
                     width: int | None = None):
    """Gather per-query labels from buckets <= ``bucket``, padded to its width.

    One masked gather per source bucket (a handful of O(B*W) memory ops) in
    exchange for running the O(W^2) join and O(W*E) visibility at the
    dispatch width instead of the global Lmax.  Regions living in a *wider*
    bucket than ``bucket`` come back as pure padding (inf distances) — the
    caller must dispatch each query at the max of its endpoint buckets.

    ``width`` (>= ``widths[bucket]``) pads the gather beyond the bucket's
    own width.  The extra slots are HUB_PAD/inf — inert in the join — so a
    sharded query whose two endpoints live on shards with different bucket
    ladders can be joined at the pair's common width (``repro.sharding``).
    """
    W = bx.widths[bucket] if width is None else width
    B = regions.shape[0]
    hub = jnp.full((B, W), HUB_PAD, jnp.int32)
    xy = jnp.zeros((B, W, 2), jnp.float32)
    vd = jnp.full((B, W), jnp.inf, jnp.float32)
    vid = jnp.full((B, W), -1, jnp.int32)

    src_bucket = bx.region_bucket[regions]
    src_row = bx.region_row[regions]
    for k in range(bucket + 1):
        rows = jnp.clip(src_row, 0, bx.hub_ids[k].shape[0] - 1)
        sel = src_bucket == k
        pad = ((0, 0), (0, W - bx.widths[k]))
        hub = jnp.where(sel[:, None],
                        jnp.pad(bx.hub_ids[k][rows], pad,
                                constant_values=HUB_PAD), hub)
        xy = jnp.where(sel[:, None, None],
                       jnp.pad(bx.via_xy[k][rows], pad + ((0, 0),)), xy)
        vd = jnp.where(sel[:, None],
                       jnp.pad(bx.via_d[k][rows], pad,
                               constant_values=np.inf), vd)
        vid = jnp.where(sel[:, None],
                        jnp.pad(bx.via_ids[k][rows], pad,
                                constant_values=-1), vid)
    return hub, xy, vd, vid


@partial(jax.jit, static_argnames=("bucket", "use_kernels", "want_argmin"))
def query_batch_at_bucket(bx: BucketedIndex, s: jnp.ndarray, t: jnp.ndarray,
                          bucket: int, use_kernels: bool = False,
                          want_argmin: bool = False):
    """Eq. 1-3 over one dispatch bucket — the per-bucket jit cache entry.

    Every query's endpoint regions must live in buckets <= ``bucket``
    (i.e. ``bucket == max(endpoint buckets)`` after routing); the result is
    then bitwise-identical to the full-width ``query_batch`` because the
    extra slots it would have carried are all inf/HUB_PAD padding.
    """
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    rs = locate_regions(bx, s)
    rt = locate_regions(bx, t)
    return _labels_to_distances(
        _gather_bucketed(bx, rs, bucket), _gather_bucketed(bx, rt, bucket),
        s, t, bx.edges_a, bx.edges_b, use_kernels, want_argmin)


# ---------------------------------------------------------------------------
# sharded dispatch primitives (repro.sharding)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("width",))
def gather_labels_at_width(bx: BucketedIndex, regions: jnp.ndarray,
                           width: int):
    """Gather [B] regions' labels as dense [B, width] tensors.

    The device half of sharded routing: each shard gathers its *own*
    endpoints' label rows at the pair's join width; for a cross-shard query
    the t-side tensors are then shipped to the s-side device and joined
    there (:func:`join_gathered`).  ``width`` must be >= the widest bucket
    any of ``regions`` lives in — the host router guarantees that by
    dispatching at ``max(endpoint widths)``.
    """
    bucket = max((k for k, w in enumerate(bx.widths) if w <= width),
                 default=0)
    return _gather_bucketed(bx, regions, bucket, width)


@partial(jax.jit, static_argnames=("use_kernels", "want_argmin"))
def join_gathered(labels_s, labels_t, s: jnp.ndarray, t: jnp.ndarray,
                  edges_a: jnp.ndarray, edges_b: jnp.ndarray,
                  use_kernels: bool = False, want_argmin: bool = False):
    """Eq. 1-3 over pre-gathered label tensors (both sides [B, W]).

    Same distance/join core as every other entry point, minus the on-device
    region lookup — the labels arrive already gathered (possibly from
    another shard's device).  With identical label/edge values this is
    bitwise-identical to ``query_batch_at_bucket`` at width W: the compute
    graph below the gather is the same code.
    """
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    return _labels_to_distances(labels_s, labels_t, s, t, edges_a, edges_b,
                                use_kernels, want_argmin)


def pack_bucketed_split(index: EHLIndex, region_shard: np.ndarray,
                        num_shards: int | None = None, lane: int = 128,
                        reuse_edges_from=None):
    """Freeze a host index into per-shard width-bucketed slabs.

    The shard-aware sibling of :func:`pack_bucketed`: ``region_shard`` maps
    each live region (in live-rid order, as ``packed_label_counts``) to a
    shard; each shard gets its own :class:`BucketedIndex` holding only its
    regions' slabs, with the bucket ladder recomputed from its own label
    counts (a region's bucket *width* is invariant — smallest power-of-two
    multiple of ``lane`` — so sharded join widths match the unsharded
    dispatch widths exactly).

    Every shard's mapper covers the full grid; cells owned by other shards
    resolve to local row 0 — harmless, because the host-side routing table
    returned alongside is what decides which shard a query is sent to.

    ``reuse_edges_from``: a previous artifact (single ``BucketedIndex`` /
    ``PackedIndex``) or a per-shard sequence of them — the scene never
    changes across recompressions, so the padded edge tensors are aliased
    instead of re-uploaded (the multi-shard hot-swap fast path, mirroring
    ``pack_bucketed``).

    Returns ``(shards, route)``: the per-shard ``BucketedIndex`` list plus
    the host-side routing table, numpy arrays over grid cells —
    ``cell_shard``/``cell_local`` (destination shard + local region id),
    ``cell_bucket``/``cell_row`` (slab coordinates inside that shard) and
    ``cell_width`` (the cell's bucket width, the join-width input).
    """
    live, packs = _host_packs(index)
    R = len(live)
    region_shard = np.asarray(region_shard, dtype=np.int32)
    if region_shard.shape != (R,):
        raise ValueError(f"region_shard has shape {region_shard.shape}, "
                         f"index has {R} live regions")
    S = int(num_shards) if num_shards is not None \
        else int(region_shard.max(initial=-1)) + 1
    counts = index.packed_label_counts()
    if reuse_edges_from is None or hasattr(reuse_edges_from, "edges_a"):
        reuse_edges_from = [reuse_edges_from] * S
    ea0, eb0 = None, None       # packed once, aliased across shards

    # global region -> (local id, local bucket, local row) within its shard
    region_local = np.zeros(R, dtype=np.int32)
    region_lbucket = np.zeros(R, dtype=np.int32)
    region_lrow = np.zeros(R, dtype=np.int32)
    region_width = np.array([bucket_width(max(1, int(c)), lane)
                             for c in counts], dtype=np.int32)
    cell_region = _cell_mapper(index, live)

    shards = []
    for k in range(S):
        members = np.nonzero(region_shard == k)[0]
        if members.size == 0:
            raise ValueError(f"shard {k} owns no regions — plan fewer "
                             "shards or rebalance")
        region_local[members] = np.arange(members.size, dtype=np.int32)
        widths_k = sorted({int(region_width[i]) for i in members})
        bucket_of_width = {w: b for b, w in enumerate(widths_k)}
        lbucket = np.array([bucket_of_width[int(region_width[i])]
                            for i in members], dtype=np.int32)
        lrow = np.zeros(members.size, dtype=np.int32)
        slab_members: list[list[int]] = [[] for _ in widths_k]
        for li, gi in enumerate(members):
            b = lbucket[li]
            lrow[li] = len(slab_members[b])
            slab_members[b].append(int(gi))
        region_lbucket[members] = lbucket
        region_lrow[members] = lrow

        slabs = []
        for b, w in enumerate(widths_k):
            arrs = _alloc_slab(max(1, len(slab_members[b])), w)
            for row, gi in enumerate(slab_members[b]):
                _fill_row(arrs, row, packs[gi])
            slabs.append(arrs)

        reuse = reuse_edges_from[k]
        if reuse is not None:
            ea, eb = reuse.edges_a, reuse.edges_b
        else:
            if ea0 is None:
                ea0, eb0 = _pack_edges(index, lane)
                ea0, eb0 = jnp.asarray(ea0), jnp.asarray(eb0)
            ea, eb = ea0, eb0

        # full-grid mapper: owned cells -> local id, foreign cells -> 0
        mapper_k = np.where(region_shard[cell_region] == k,
                            region_local[cell_region], 0).astype(np.int32)
        shards.append(BucketedIndex(
            hub_ids=tuple(jnp.asarray(a[0]) for a in slabs),
            via_xy=tuple(jnp.asarray(a[1]) for a in slabs),
            via_d=tuple(jnp.asarray(a[2]) for a in slabs),
            via_ids=tuple(jnp.asarray(a[3]) for a in slabs),
            mapper=jnp.asarray(mapper_k),
            region_bucket=jnp.asarray(lbucket),
            region_row=jnp.asarray(lrow),
            edges_a=ea, edges_b=eb,
            nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
            width=float(index.scene.width), height=float(index.scene.height),
            widths=tuple(widths_k)))

    route = dict(
        region_shard=region_shard,
        region_local=region_local,
        cell_region=cell_region,
        cell_shard=region_shard[cell_region],
        cell_local=region_local[cell_region],
        cell_bucket=region_lbucket[cell_region],
        cell_row=region_lrow[cell_region],
        cell_width=region_width[cell_region])
    return shards, route


def dispatch_buckets(bx: BucketedIndex, s, t) -> np.ndarray:
    """[B] dispatch bucket per query: max of the two endpoint buckets."""
    s = jnp.asarray(s, jnp.float32)
    t = jnp.asarray(t, jnp.float32)
    bs = bx.region_bucket[locate_regions(bx, s)]
    bt = bx.region_bucket[locate_regions(bx, t)]
    return np.asarray(jnp.maximum(bs, bt))


def query_batch_bucketed(bx: BucketedIndex, s, t,
                         use_kernels: bool = False,
                         want_argmin: bool = False):
    """Route a batch through per-bucket dispatch and scatter results back.

    Host-side convenience wrapper (PathServer does the same routing with
    fixed batch shapes and per-bucket stats): group queries by dispatch
    bucket, answer each group at its own width, reassemble in input order.
    """
    s = np.asarray(s, np.float32)
    t = np.asarray(t, np.float32)
    n = len(s)
    buckets = dispatch_buckets(bx, s, t) if n else np.zeros(0, np.int32)
    outs = empty_results(n, want_argmin)
    for k in np.unique(buckets):
        m = buckets == k
        res = query_batch_at_bucket(bx, jnp.asarray(s[m]), jnp.asarray(t[m]),
                                    bucket=int(k), use_kernels=use_kernels,
                                    want_argmin=want_argmin)
        for o, r in zip(outs, res if want_argmin else (res,)):
            o[m] = np.asarray(r)
    return tuple(outs) if want_argmin else outs[0]


def empty_results(n: int, want_argmin: bool) -> list:
    """Output buffers matching the engine dtypes: d [+ covis, label ids]."""
    if not want_argmin:
        return [np.empty(n, np.float32)]
    return [np.empty(n, np.float32), np.empty(n, bool),
            np.empty(n, np.int32), np.empty(n, np.int32),
            np.empty(n, np.int32)]
