"""Dense tensor forms of an EHL/EHL* index — the TPU-resident artifact.

The host-side index (``repro.core.grid``) stores ragged per-region label
lists.  The online engine needs contiguous, gatherable tensors.  Two layouts
are provided (DESIGN.md §4):

* :class:`PackedIndex` — the single ``[R, Lmax]`` slab: every region padded
  to the global maximum label count.  Simple, one jit cache entry, but one
  oversized merged region inflates both ``device_bytes()`` and the O(L^2)
  label join for *every* query — the padding waste EHL*'s budget is supposed
  to eliminate.
* :class:`BucketedIndex` — regions grouped into power-of-two width buckets
  (multiples of ``lane``), each bucket its own dense slab, plus a
  ``region -> (bucket, row)`` indirection behind the cell mapper.
  ``device_bytes()`` then tracks the true EHL* budget, and queries dispatch
  per bucket so they only pay for the label width their regions actually
  need (``query_batch_at_bucket`` / the PathServer router).

Shared across layouts:

* ``edges_a/b/c``: flat obstacle-edge tensors for the query-time visibility
  predicate (``a``/``b`` endpoints plus the CCW next vertex ``c`` for the
  through-vertex rule; DESIGN.md §5 convention — touching != blocked,
  interior penetration = blocked).  Padding slots are provably degenerate
  (a == b == c), and at least one exists — the grid sentinel points at it.
* ``grid``: optional :class:`~repro.core.edgegrid.EdgeGrid` that prunes the
  visibility predicate from O(L·E) to O(L·E_local) (DESIGN.md §10);
  attached by the packers when it pays (or forced via ``edge_grid=True``),
  bitwise-identical to the dense predicate either way.
* ``mapper``: cell -> region row (single slab) or cell -> region id
  (bucketed), so point location stays O(1).
* one distance/join core — :func:`_mask_labels` (per-endpoint visibility +
  distance fold) feeding :func:`_join_masked` (hub join + co-visibility
  override) — used by every entry point; plain distances and argmin (path
  unwinding) are the same code path with a flag, for both the jnp
  reference and the Pallas kernels.  The sharded router calls the two
  halves on different devices (``gather_masked_labels`` /
  ``covis_blocked`` / ``join_masked``) with byte-identical results.

Everything is float32/int32 in the reference layout; the host oracle is
float64 — tests compare with ~1e-5 tolerances.

**Quantized slabs (DESIGN.md §11).**  Both layouts optionally store their
label slabs in a compressed on-device format (:class:`SlabLayout`):

* distances as bf16/f16 (per-bucket fallback to f32 when a finite distance
  would overflow the narrow dtype — f16 tops out at 65504);
* hub and via ids delta-encoded per region row into u16 against per-row
  i32 bases (pad sentinel ``0xFFFF``; per-bucket fallback to raw i32 when
  a row's id range exceeds what u16 can carry);
* the 8-byte-per-slot ``via_xy`` slab replaced by one shared ``[V, 2]``
  float32 vertex table gathered through the via id — exact, because the
  packers always filled ``via_xy`` with ``graph.nodes[via]``.

20 bytes/slot become 6.  The gathers decode in-register — ids back to
exact int32, distances widened to f32 — so every downstream op (visibility
fold, join, kernels) runs unchanged, and a *f32-layout* artifact compiles
the exact pre-quantization program (the layout is static aux).  Distances
come back within ``2*qerr`` of the f32 engine (``qerr`` is the measured
max quantization error, a device scalar riding the artifact); argmin
winners stay **bitwise-identical** via the residual rescue: the argmin
entries also emit an ambiguity mask (join margin within the quantization
error bound) and ambiguous rows are recomputed through
:func:`gather_masked_exact` with exact f32 distance rows from the
host-side :class:`ResidualTable` — the same arithmetic the f32 engine
runs, so the spliced winners (and path answers) match it bit for bit.
"""

from __future__ import annotations

import dataclasses
import threading
from functools import wraps

import numpy as np

import jax
import jax.numpy as jnp

try:                            # jax's own low-precision dtype package
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:             # pragma: no cover - ml_dtypes ships with jax
    ml_dtypes = None
    _BF16 = None

from .edgegrid import (EdgeGrid, build_edge_grid, ell_bytes, plan_grid,
                       segvis_grid)
from .grid import EHLIndex

HUB_PAD = np.int32(2 ** 30)     # sorts after every real hub id
U16_PAD = np.uint16(0xFFFF)     # delta-encoded pad sentinel (u16 id slabs)


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """On-device slab dtypes — static (lives in pytree aux, keys jit caches).

    ``dist``: f32 | bf16 | f16 — label-distance storage dtype.
    ``ids``:  i32 | u16       — hub/via id storage (u16 = per-row delta).

    The f32/i32 default reproduces the historical layout bit for bit; any
    quantized layout also drops the per-slot ``via_xy`` pair in favor of
    the shared vertex table.
    """

    dist: str = "f32"
    ids: str = "i32"

    def __post_init__(self):
        if self.dist not in ("f32", "bf16", "f16"):
            raise ValueError(f"unknown distance dtype {self.dist!r}")
        if self.ids not in ("i32", "u16"):
            raise ValueError(f"unknown id dtype {self.ids!r}")

    @property
    def quantized(self) -> bool:
        return self.dist != "f32" or self.ids != "i32"

    @property
    def dist_dtype(self):
        if self.dist == "bf16":
            return _BF16
        return np.dtype(np.float16) if self.dist == "f16" \
            else np.dtype(np.float32)


LAYOUT_F32 = SlabLayout()


def slab_layout(name: str) -> SlabLayout:
    """CLI spelling -> layout: 'f32'/'off' | 'bf16' | 'f16'."""
    if name in ("f32", "off", "none", ""):
        return LAYOUT_F32
    if name in ("bf16", "f16"):
        return SlabLayout(dist=name, ids="u16")
    raise ValueError(f"unknown slab layout {name!r} (f32 | bf16 | f16)")


@dataclasses.dataclass(frozen=True)
class LayoutBytes:
    """Analytic byte costs of a :class:`SlabLayout` (see :func:`dtype_bytes`)."""
    per_slot: int               # bytes per label slot (slab area term)
    per_row: int                # bytes per slab row (delta-encoding bases)
    per_vertex: int             # bytes per graph vertex (shared xy table)


def dtype_bytes(layout: SlabLayout = LAYOUT_F32) -> LayoutBytes:
    """Single source of per-slot/per-row/per-vertex byte math.

    Every analytic estimator (:func:`slab_device_bytes`,
    :func:`bucketed_device_bytes`, the shard planner's balance weights and
    ``sharded_overhead_bytes``) routes through this helper, so planner
    decisions, per-shard budget gates and bench padding-waste rows all
    agree with the real slab dtypes.  Estimates assume no per-bucket
    fallback (the realized ``device_bytes()`` is authoritative when a
    bucket overflowed its narrow dtype).
    """
    if not layout.quantized:
        return LayoutBytes(per_slot=4 + 8 + 4 + 4,  # hub + xy + d + vid
                           per_row=0, per_vertex=0)
    id_b = 2 if layout.ids == "u16" else 4
    dist_b = layout.dist_dtype.itemsize
    return LayoutBytes(per_slot=2 * id_b + dist_b,  # hub_enc + d + via_enc
                       per_row=(8 if layout.ids == "u16" else 0),
                       per_vertex=8)                # shared [V, 2] f32 table


class ResidualTable:
    """Host-side exact f32 distance rows — the residual the rescue reads.

    Per bucket, the pre-quantization float32 ``via_d`` slab plus int32
    routing mirrors (mapper / region -> bucket / row), ~4 bytes per label
    slot of host memory.  Only *distances* are kept: the device slabs
    already decode hub/via ids to their exact int32 values, so the rescue
    only has to replace the quantized distance term
    (:func:`gather_masked_exact`).  Host-resident, never uploaded whole —
    ambiguous batches gather [B, W] rows and ship just those.
    """

    def __init__(self, d_slabs, region_bucket, region_row, mapper,
                 widths, nx: int, ny: int, cell_size: float):
        self.d = [np.ascontiguousarray(np.asarray(a, np.float32))
                  for a in d_slabs]
        self.region_bucket = np.asarray(region_bucket, np.int32)
        self.region_row = np.asarray(region_row, np.int32)
        self.mapper = np.asarray(mapper, np.int32)
        self.widths = tuple(int(w) for w in widths)
        self.nx, self.ny = int(nx), int(ny)
        self.cell_size = float(cell_size)

    def locate(self, pts: np.ndarray) -> np.ndarray:
        """[B] region ids — the same float32 floor-divide as
        :func:`locate_regions`, so host rows match device gathers exactly."""
        p = np.asarray(pts, np.float32)
        cs = np.float32(self.cell_size)
        ix = np.clip((p[:, 0] / cs).astype(np.int32), 0, self.nx - 1)
        iy = np.clip((p[:, 1] / cs).astype(np.int32), 0, self.ny - 1)
        return self.mapper[iy * self.nx + ix]

    def gather_d(self, regions: np.ndarray, width: int) -> np.ndarray:
        """[B, width] exact f32 distance rows, inf-padded — the host mirror
        of the distance plane of :func:`_gather_bucketed`."""
        regions = np.asarray(regions)
        out = np.full((len(regions), width), np.inf, np.float32)
        b = self.region_bucket[regions]
        r = self.region_row[regions]
        for k, w in enumerate(self.widths):
            if w > width:
                continue        # wider buckets stay padding, as on device
            m = b == k
            if m.any():
                rows = np.minimum(r[m], self.d[k].shape[0] - 1)
                out[np.nonzero(m)[0][:, None],
                    np.arange(w)[None, :]] = self.d[k][rows]
        return out


class TraceCounter:
    """Counts jit *traces* of the serving entry points below.

    A trace is 1:1 with a fresh XLA compilation for that (static args,
    shapes, dtypes) cache entry, so serving code can assert "warmup left
    nothing cold": snapshot ``TRACES.count``, serve, and require the count
    unchanged.  Bumps happen inside the traced bodies — they run at trace
    time only, never per call.

    ``count`` stays the in-process fast path; each bump also lands on an
    entry-labeled ``jit_traces_total{entry=}`` counter in the process-wide
    metrics registry so cold-compile events show up in the Prometheus/JSON
    exports next to the serving series they perturb (DESIGN.md §12/§13).

    Two profiling hooks ride along (DESIGN.md §13): a *thread-local*
    count (``thread_count()``) lets :class:`repro.obs.CompileCapture`
    detect "this call traced" without crediting a background build
    thread's compile to a foreground serving call, and ``profiler`` is
    the installed capture (None when profiling is off — the only cost
    then is one attribute read per entry call).
    """

    def __init__(self):
        self.count = 0
        self.profiler = None            # CompileCapture | None
        self._tl = threading.local()
        self._metrics = {}

    def thread_count(self) -> int:
        return getattr(self._tl, "count", 0)

    def bump(self, entry: str = "") -> None:
        self.count += 1
        self._tl.count = self.thread_count() + 1
        m = self._metrics.get(entry)
        if m is None:
            # deferred: repro.obs is import-light (numpy + stdlib), but
            # binding lazily keeps module import order unconstrained
            from repro.obs import REGISTRY
            m = (REGISTRY.counter("jit_traces_total", entry=entry)
                 if entry else REGISTRY.counter("jit_traces_total"))
            self._metrics[entry] = m
        m.inc()


TRACES = TraceCounter()

#: The jit entry taxonomy: every ``@_jit_entry("name")`` in the tree, in
#: rough serving-path order.  Static so tests, docs, and the
#: ``jit-registry`` checker can enumerate the surface without tracing;
#: the checker fails CI if this tuple and the decorators ever drift.
TRACE_ENTRIES = (
    "fold_endpoint",
    "join_endpoints",
    "gather_labels_at_width",
    "join_gathered",
    "gather_masked_labels",
    "covis_blocked",
    "join_masked",
    "gather_masked_exact",
    "gather_quant_rows",
    "dequant_masked_labels",
)


def _jit_entry(entry: str, **jit_kw):
    """``jax.jit`` for a named serving entry, routed via the profiler.

    With no profiler installed the wrapper is one attribute read + one
    ``is None`` per call on top of the jit dispatch.  With one installed
    (:func:`repro.obs.enable_profile`) the call goes through
    ``CompileCapture.call``, which times the call and — when the entry's
    ``TRACES.bump(entry)`` fired on this thread, i.e. the call traced —
    attributes compile wall-time and XLA ``cost_analysis()`` to the
    entry label.  The traced body must call ``TRACES.bump(entry)`` with
    the same name.
    """
    def deco(fn):
        jf = jax.jit(fn, **jit_kw)

        @wraps(fn)
        def wrapper(*args, **kw):
            prof = TRACES.profiler
            if prof is None:
                return jf(*args, **kw)
            return prof.call(entry, jf, args, kw)

        wrapper.jit = jf                # the underlying jit callable
        wrapper.entry = entry
        return wrapper
    return deco


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def padded_edge_count(num_edges: int, lane: int = 128) -> int:
    """Packed edge-tensor length: lane-aligned with >= 1 degenerate slot."""
    return _round_up(num_edges + 1, lane)


def bucket_width(n_labels: int, lane: int = 128) -> int:
    """Smallest power-of-two multiple of ``lane`` holding ``n_labels``."""
    w = lane
    while w < n_labels:
        w *= 2
    return w


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedIndex:
    """Single-slab layout: pytree of device arrays (static geometry in aux)."""

    hub_ids: jnp.ndarray    # [R, L] int32 (or u16 delta vs hub_base), sorted
    via_xy: jnp.ndarray     # [R, L, 2] float32, or None (quantized: vert_xy)
    via_d: jnp.ndarray      # [R, L] float32/bf16/f16 (+inf on pads)
    via_ids: jnp.ndarray    # [R, L] int32 (-1 pads) or u16 delta vs vid_base
    mapper: jnp.ndarray     # [C] int32 cell -> region row
    edges_a: jnp.ndarray    # [E, 2] float32 (degenerate-padded)
    edges_b: jnp.ndarray    # [E, 2] float32
    edges_c: jnp.ndarray    # [E, 2] float32 CCW next vertex (§5 vertex rule)
    grid: EdgeGrid | None   # edge-grid pruning (DESIGN.md §10), or None
    # static metadata
    nx: int
    ny: int
    cell_size: float
    width: float
    height: float
    # quantized-layout extras (§11) — all None under the f32 layout
    vert_xy: jnp.ndarray | None = None      # [V, 2] f32 shared vertex table
    hub_base: jnp.ndarray | None = None     # [R] i32 per-row hub id base
    vid_base: jnp.ndarray | None = None     # [R] i32 per-row via id base
    qerr: jnp.ndarray | None = None         # f32 scalar max |f32(dq) - d|
    layout: SlabLayout = LAYOUT_F32
    residual: ResidualTable | None = dataclasses.field(
        default=None, repr=False, compare=False)   # host-side, not a leaf

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.edges_a, self.edges_b, self.edges_c,
                    self.grid, self.vert_xy, self.hub_base, self.vid_base,
                    self.qerr)
        aux = (self.nx, self.ny, self.cell_size, self.width, self.height,
               self.layout)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:9], *aux[:5],
                   vert_xy=children[9], hub_base=children[10],
                   vid_base=children[11], qerr=children[12], layout=aux[5])

    # -- properties ----------------------------------------------------------
    @property
    def num_regions(self) -> int:
        return self.hub_ids.shape[0]

    @property
    def label_width(self) -> int:
        return self.hub_ids.shape[1]

    @property
    def num_edges(self) -> int:
        return self.edges_a.shape[0]

    def device_bytes(self) -> int:
        arrs = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                self.mapper, self.edges_a, self.edges_b, self.edges_c,
                self.vert_xy, self.hub_base, self.vid_base)
        base = sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in arrs if a is not None)
        return int(base) + (self.grid.device_bytes() if self.grid else 0)

    def label_slots(self) -> tuple[int, int]:
        """(used, total) label slots — padding waste is total - used."""
        used = int(_used_mask(self.hub_ids).sum())
        return used, int(np.prod(self.hub_ids.shape))

    def quant_stats(self) -> dict:
        """Realized quantization record (fallbacks are loud, not silent)."""
        return _quant_stats(self.layout, (self.hub_ids,), (self.via_d,),
                            (self.via_ids,), self.qerr)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BucketedIndex:
    """Width-bucketed layout: one dense slab per power-of-two label width.

    Region ``r`` lives at ``(region_bucket[r], region_row[r])``; slab ``k``
    has shape ``[R_k, widths[k]]``.  The mapper resolves cells to region ids
    (not rows), so point location composes with the indirection in O(1).
    """

    hub_ids: tuple          # per bucket: [R_k, W_k] int32 or u16 delta
    via_xy: tuple           # per bucket: [R_k, W_k, 2] float32 (or () §11)
    via_d: tuple            # per bucket: [R_k, W_k] f32/bf16/f16 (+inf pads)
    via_ids: tuple          # per bucket: [R_k, W_k] int32 (-1 pads) or u16
    mapper: jnp.ndarray     # [C] int32 cell -> region id
    region_bucket: jnp.ndarray  # [R] int32 region id -> bucket
    region_row: jnp.ndarray     # [R] int32 region id -> row in its slab
    edges_a: jnp.ndarray    # [E, 2] float32 (degenerate-padded)
    edges_b: jnp.ndarray    # [E, 2] float32
    edges_c: jnp.ndarray    # [E, 2] float32 CCW next vertex (§5 vertex rule)
    grid: EdgeGrid | None   # edge-grid pruning (DESIGN.md §10), or None
    # static metadata
    nx: int
    ny: int
    cell_size: float
    width: float
    height: float
    widths: tuple           # per-bucket label width, strictly increasing
    # quantized-layout extras (§11) — all None/() under the f32 layout
    vert_xy: jnp.ndarray | None = None      # [V, 2] f32 shared vertex table
    hub_base: tuple = ()                    # per bucket: [R_k] i32 row base
    vid_base: tuple = ()                    # per bucket: [R_k] i32 row base
    qerr: jnp.ndarray | None = None         # f32 scalar max |f32(dq) - d|
    layout: SlabLayout = LAYOUT_F32
    residual: ResidualTable | None = dataclasses.field(
        default=None, repr=False, compare=False)   # host-side, not a leaf

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.hub_ids, self.via_xy, self.via_d, self.via_ids,
                    self.mapper, self.region_bucket, self.region_row,
                    self.edges_a, self.edges_b, self.edges_c, self.grid,
                    self.vert_xy, self.hub_base, self.vid_base, self.qerr)
        aux = (self.nx, self.ny, self.cell_size, self.width, self.height,
               self.widths, self.layout)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:11], *aux[:6],
                   vert_xy=children[11], hub_base=children[12],
                   vid_base=children[13], qerr=children[14], layout=aux[6])

    # -- properties ----------------------------------------------------------
    @property
    def num_buckets(self) -> int:
        return len(self.widths)

    @property
    def num_regions(self) -> int:
        return self.region_bucket.shape[0]

    @property
    def label_width(self) -> int:
        """Widest bucket — what a single slab would pad everything to."""
        return self.widths[-1] if self.widths else 0

    @property
    def num_edges(self) -> int:
        return self.edges_a.shape[0]

    def device_bytes(self) -> int:
        slabs = sum(np.prod(a.shape) * a.dtype.itemsize
                    for group in (self.hub_ids, self.via_xy, self.via_d,
                                  self.via_ids, self.hub_base, self.vid_base)
                    for a in group)
        fixed = sum(np.prod(a.shape) * a.dtype.itemsize for a in
                    (self.mapper, self.region_bucket, self.region_row,
                     self.edges_a, self.edges_b, self.edges_c))
        if self.vert_xy is not None:
            fixed += np.prod(self.vert_xy.shape) * self.vert_xy.dtype.itemsize
        return (int(slabs) + int(fixed)
                + (self.grid.device_bytes() if self.grid else 0))

    def bucket_stats(self) -> list[dict]:
        """Per-bucket occupancy: regions, used/total label slots, waste."""
        out = []
        for k, w in enumerate(self.widths):
            hub = np.asarray(self.hub_ids[k])
            used = int(_used_mask(hub).sum())
            total = int(np.prod(hub.shape))
            out.append(dict(bucket=k, width=w, regions=hub.shape[0],
                            used_slots=used, total_slots=total,
                            waste=1.0 - used / max(1, total)))
        return out

    def quant_stats(self) -> dict:
        """Realized quantization record (fallbacks are loud, not silent)."""
        return _quant_stats(self.layout, self.hub_ids, self.via_d,
                            self.via_ids, self.qerr)

    def label_slots(self) -> tuple[int, int]:
        """(used, total) label slots across all buckets."""
        st = self.bucket_stats()
        return (sum(s["used_slots"] for s in st),
                sum(s["total_slots"] for s in st))


# ---------------------------------------------------------------------------
# packing (host -> device layouts)
# ---------------------------------------------------------------------------

def _host_packs(index: EHLIndex):
    """Live regions in rid order with their packed (ragged) label arrays."""
    live = sorted(index.regions.keys())
    packs = [index.pack_region(index.regions[rid]) for rid in live]
    return live, packs


def _fill_row(arrs, i, p):
    hub_ids, via_xy, via_d, via_ids = arrs
    k = len(p["hubs"])
    hub_ids[i, :k] = p["hubs"]
    via_xy[i, :k] = p["via_xy"]
    via_d[i, :k] = p["d"]
    via_ids[i, :k] = p["vias"]


def _alloc_slab(rows: int, width: int):
    return (np.full((rows, width), HUB_PAD, dtype=np.int32),
            np.zeros((rows, width, 2), dtype=np.float32),
            np.full((rows, width), np.inf, dtype=np.float32),
            np.full((rows, width), -1, dtype=np.int32))


# ---------------------------------------------------------------------------
# quantized slab encoding (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _used_mask(hub_arr) -> np.ndarray:
    """Real-label mask for either id encoding (u16 sentinel vs HUB_PAD)."""
    a = np.asarray(hub_arr)
    return (a != np.uint16(U16_PAD)) if a.dtype == np.uint16 \
        else (a != HUB_PAD)


def encode_delta_u16(ids: np.ndarray, valid: np.ndarray):
    """Per-row delta encoding of an id slab into u16 + [R] i32 bases.

    Returns ``(enc, base)`` with pad slots at the ``0xFFFF`` sentinel, or
    ``(None, None)`` when any row's id range exceeds 65534 — the caller
    must then keep the raw i32 slab (the loud per-bucket fallback).
    """
    ids = np.asarray(ids, np.int64)
    any_valid = valid.any(axis=1)
    lo = np.where(valid, ids, np.iinfo(np.int64).max).min(axis=1)
    lo = np.where(any_valid, lo, 0)
    hi = np.where(valid, ids, np.iinfo(np.int64).min).max(axis=1)
    hi = np.where(any_valid, hi, 0)
    if int((hi - lo).max(initial=0)) > 0xFFFE:      # 0xFFFF is the pad
        return None, None
    enc = np.where(valid, ids - lo[:, None], 0xFFFF)
    return enc.astype(np.uint16), lo.astype(np.int32)


def encode_dist(d: np.ndarray, dtype) -> tuple:
    """Quantize a f32 distance slab; returns ``(dq, qerr)``.

    ``(None, 0.0)`` when any *finite* distance overflows to inf in the
    narrow dtype (f16 tops out at 65504) — per-bucket fallback to f32.
    +inf pads are representable in every dtype and round-trip exactly.
    """
    d = np.asarray(d, np.float32)
    with np.errstate(over="ignore"):
        dq = d.astype(dtype)
        back = dq.astype(np.float32)
    finite = np.isfinite(d)
    if np.any(finite & ~np.isfinite(back)):
        return None, 0.0
    err = np.abs(back[finite] - d[finite])
    return dq, float(err.max(initial=0.0))


def _quantize_slab(arrs, layout: SlabLayout):
    """Encode one (hub, xy, d, vid) f32 slab into the quantized layout.

    Returns ``(hub, d, vid, hub_base, vid_base, qerr)`` — ids u16-delta
    (or raw i32 on range overflow, per bucket), distances in
    ``layout.dist_dtype`` (or f32 on finite-overflow, per bucket).  The
    ``via_xy`` plane is dropped entirely: it is always
    ``vert_xy[via_id]`` (see ``EHLIndex.pack_region``), so the shared
    vertex table replaces it exactly.
    """
    hub, _, d, vid = arrs
    R = hub.shape[0]
    zeros = np.zeros(R, np.int32)
    hub_q, hub_base = hub, zeros
    vid_q, vid_base = vid, zeros
    if layout.ids == "u16":
        enc, base = encode_delta_u16(hub, hub != HUB_PAD)
        if enc is not None:
            hub_q, hub_base = enc, base
        enc, base = encode_delta_u16(vid, vid >= 0)
        if enc is not None:
            vid_q, vid_base = enc, base
    d_q, qerr = d, 0.0
    if layout.dist != "f32":
        dq, err = encode_dist(d, layout.dist_dtype)
        if dq is not None:
            d_q, qerr = dq, err
    return hub_q, d_q, vid_q, hub_base, vid_base, qerr


def _quant_stats(layout: SlabLayout, hub_ids, via_d, via_ids, qerr) -> dict:
    """Per-bucket realized encoding + fallback flags (never silent)."""
    return dict(
        layout=layout,
        qerr=(float(np.asarray(qerr)) if qerr is not None else 0.0),
        id_fallback=tuple(np.asarray(h).dtype != np.uint16
                          for h in hub_ids) if layout.ids == "u16" else (),
        vid_fallback=tuple(np.asarray(v).dtype != np.uint16
                           for v in via_ids) if layout.ids == "u16" else (),
        dist_fallback=tuple(
            np.asarray(d).dtype != layout.dist_dtype for d in via_d)
        if layout.dist != "f32" else ())


def _vert_table(index: EHLIndex) -> jnp.ndarray:
    """[V, 2] f32 shared vertex table — exactly the values the f32 packers
    wrote per slot (``via_xy = graph.nodes[via]`` cast to float32)."""
    return jnp.asarray(np.asarray(index.graph.nodes, np.float32))


def _cell_mapper(index: EHLIndex, live: list) -> np.ndarray:
    """[C] int32 cell -> dense index into the live-region ordering."""
    row_of = {rid: i for i, rid in enumerate(live)}
    mapper = np.zeros(index.mapper.size, dtype=np.int32)
    for ci, rid in enumerate(index.mapper):
        mapper[ci] = row_of[int(rid)]
    return mapper


def _pack_edges(scene_or_index, lane: int, mask: np.ndarray | None = None):
    """Pack (a, b, c) edge tensors, degenerate-padded with >= 1 sentinel.

    ``mask`` selects an edge subset (the per-shard clip path); order is
    preserved so duplicate registrations stay deterministic.  Every padding
    slot is the degenerate triple (a == b == c) — provably non-blocking
    under the §5 predicate for *every* query segment — and the last slot is
    always padding, so it doubles as the edge-grid sentinel.
    """
    scene = getattr(scene_or_index, "scene", scene_or_index)
    edges = scene.edges
    enext = scene.edge_next
    if mask is not None:
        edges = edges[mask]
        enext = enext[mask]
    E = edges.shape[0]
    Ep = padded_edge_count(E, lane)
    ea = np.zeros((Ep, 2), dtype=np.float32)
    eb = np.zeros((Ep, 2), dtype=np.float32)
    ec = np.zeros((Ep, 2), dtype=np.float32)
    if E:
        ea[:E] = edges[:, 0]
        eb[:E] = edges[:, 1]
        ec[:E] = enext
        ea[E:] = eb[E:] = ec[E:] = edges[0, 0]   # degenerate pads
    assert np.array_equal(ea[E:], eb[E:]) and np.array_equal(eb[E:], ec[E:]) \
        and Ep > E, "edge padding must be degenerate (a == b == c)"
    return ea, eb, ec


def _maybe_grid(ea: np.ndarray, eb: np.ndarray, num_real: int,
                scene, edge_grid: bool | None) -> EdgeGrid | None:
    """Build the edge grid when forced or when pruning pays.

    ``edge_grid=None`` (auto) attaches the grid only when the per-segment
    gathered tile is smaller than the dense edge list — on small suite maps
    the dense O(L·E) sweep is already cheaper than the walk's padding, on
    edge-heavy maps the grid wins by orders of magnitude.  ``True``/
    ``False`` force.  Deterministic, mirrored by the analytic byte helpers.
    """
    if edge_grid is False:
        return None
    if edge_grid is None:
        # decide host-side (plan_grid: no device arrays) before building —
        # on dense-favored maps the grid would be discarded right away
        gnx, gny, _, M = plan_grid(ea, eb, num_real, scene.width,
                                   scene.height)
        if 3 * max(gnx, gny) * M >= ea.shape[0]:
            return None
    return build_edge_grid(ea, eb, num_real, scene.width, scene.height,
                           sentinel=ea.shape[0] - 1)


_GRID_PLAN_CACHE: dict = {}


def _grid_bytes(index: EHLIndex, lane: int, edge_grid: bool | None) -> int:
    """Analytic twin of :func:`_maybe_grid` for the byte estimators.

    Pure host arithmetic (:func:`plan_grid`), memoized per scene — the
    budget searches in ``core.compression`` and the adaptive planner call
    the byte estimators every round, the scene never changes for an
    index's lifetime, and this must never build device arrays."""
    if edge_grid is False:
        return 0
    scene = index.scene
    E = scene.edges.shape[0]
    key = (hash(scene.edges.tobytes()), E, lane,
           float(scene.width), float(scene.height))
    plan = _GRID_PLAN_CACHE.get(key)
    if plan is None:
        ea, eb, _ = _pack_edges(index, lane)
        gnx, gny, _, M = plan_grid(ea, eb, E, scene.width, scene.height)
        if len(_GRID_PLAN_CACHE) >= 64:
            _GRID_PLAN_CACHE.clear()
        plan = _GRID_PLAN_CACHE[key] = (gnx, gny, M, ea.shape[0])
    gnx, gny, M, Ep = plan
    if edge_grid is None and 3 * max(gnx, gny) * M >= Ep:
        return 0                      # the auto policy stays dense
    return ell_bytes(gnx, gny, M)


def slab_label_slots(index: EHLIndex, lane: int = 128,
                     region_pad_multiple: int = 1) -> tuple[int, int]:
    """(used, total) label slots of the would-be single slab, analytically."""
    counts = index.packed_label_counts()
    R = _round_up(max(1, len(counts)), region_pad_multiple)
    L = _round_up(max(1, int(counts.max(initial=1))), lane)
    return int(counts.sum()), R * L


def slab_device_bytes(index: EHLIndex, lane: int = 128,
                      region_pad_multiple: int = 1,
                      edge_grid: bool | None = None,
                      layout: SlabLayout = LAYOUT_F32) -> int:
    """What ``pack_index(...).device_bytes()`` would be, without packing.

    Lets callers report the single-slab footprint for comparison against the
    bucketed layout without materializing the global-Lmax slab on device.
    """
    _, slots = slab_label_slots(index, lane, region_pad_multiple)
    lb = dtype_bytes(layout)
    counts = index.packed_label_counts()
    R = _round_up(max(1, len(counts)), region_pad_multiple)
    Ep = padded_edge_count(index.scene.edges.shape[0], lane)
    return (slots * lb.per_slot + R * lb.per_row
            + index.graph.num_nodes * lb.per_vertex
            + index.mapper.size * 4 + 3 * Ep * 2 * 4
            + _grid_bytes(index, lane, edge_grid))


def pack_index(index: EHLIndex, lane: int = 128,
               region_pad_multiple: int = 1,
               edge_grid: bool | None = None,
               layout: SlabLayout = LAYOUT_F32) -> PackedIndex:
    """Freeze a (possibly compressed) host index into one global-Lmax slab.

    ``edge_grid``: ``None`` attaches the §10 edge grid when pruning pays,
    ``True``/``False`` force it on/off.

    ``layout``: quantized layouts store distances narrow, ids u16-delta,
    drop ``via_xy`` for the shared vertex table, and attach the host-side
    :class:`ResidualTable` the exact-argmin rescue reads (DESIGN.md §11).
    """
    live, packs = _host_packs(index)
    R = _round_up(len(live), region_pad_multiple)

    Lmax = max((len(p["hubs"]) for p in packs), default=1)
    L = _round_up(max(Lmax, 1), lane)

    arrs = _alloc_slab(R, L)
    for i, p in enumerate(packs):
        _fill_row(arrs, i, p)

    mapper = _cell_mapper(index, live)
    ea, eb, ec = _pack_edges(index, lane)
    grid = _maybe_grid(ea, eb, index.scene.edges.shape[0], index.scene,
                       edge_grid)
    if layout.quantized:
        hub_q, d_q, vid_q, hb, vb, qerr = _quantize_slab(arrs, layout)
        residual = ResidualTable(
            (arrs[2],), np.zeros(R, np.int32), np.arange(R, dtype=np.int32),
            mapper, (L,), index.nx, index.ny, float(index.cell_size))
        return PackedIndex(
            hub_ids=jnp.asarray(hub_q), via_xy=None,
            via_d=jnp.asarray(d_q), via_ids=jnp.asarray(vid_q),
            mapper=jnp.asarray(mapper), edges_a=jnp.asarray(ea),
            edges_b=jnp.asarray(eb), edges_c=jnp.asarray(ec), grid=grid,
            nx=index.nx, ny=index.ny,
            cell_size=float(index.cell_size), width=float(index.scene.width),
            height=float(index.scene.height),
            vert_xy=_vert_table(index), hub_base=jnp.asarray(hb),
            vid_base=jnp.asarray(vb), qerr=jnp.float32(qerr),
            layout=layout, residual=residual)
    return PackedIndex(
        hub_ids=jnp.asarray(arrs[0]), via_xy=jnp.asarray(arrs[1]),
        via_d=jnp.asarray(arrs[2]), via_ids=jnp.asarray(arrs[3]),
        mapper=jnp.asarray(mapper), edges_a=jnp.asarray(ea),
        edges_b=jnp.asarray(eb), edges_c=jnp.asarray(ec), grid=grid,
        nx=index.nx, ny=index.ny,
        cell_size=float(index.cell_size), width=float(index.scene.width),
        height=float(index.scene.height))


def plan_buckets(index: EHLIndex, lane: int = 128
                 ) -> tuple[list, list, np.ndarray]:
    """Bucket assignment from the grid's pack metadata — no device arrays.

    Returns (per-region label counts, bucket widths, region -> bucket).
    Single definition shared by ``pack_bucketed`` and the analytic
    accounting helpers below.
    """
    counts = [max(1, int(c)) for c in index.packed_label_counts()]
    widths = sorted({bucket_width(c, lane) for c in counts}) or [lane]
    bucket_of_width = {w: k for k, w in enumerate(widths)}
    region_bucket = np.array([bucket_of_width[bucket_width(c, lane)]
                              for c in counts], dtype=np.int32)
    return counts, widths, region_bucket


def bucketed_device_bytes(index: EHLIndex, lane: int = 128,
                          edge_grid: bool | None = None,
                          layout: SlabLayout = LAYOUT_F32) -> int:
    """What ``pack_bucketed(...).device_bytes()`` would be, without packing."""
    counts, widths, region_bucket = plan_buckets(index, lane)
    lb = dtype_bytes(layout)
    slabs = sum(max(1, int((region_bucket == k).sum()))
                * (w * lb.per_slot + lb.per_row)
                for k, w in enumerate(widths))
    Ep = padded_edge_count(index.scene.edges.shape[0], lane)
    return (slabs + index.graph.num_nodes * lb.per_vertex
            + index.mapper.size * 4 + 2 * len(counts) * 4
            + 3 * Ep * 2 * 4 + _grid_bytes(index, lane, edge_grid))


def pack_bucketed(index: EHLIndex, lane: int = 128,
                  reuse_edges_from: "BucketedIndex | PackedIndex | None" = None,
                  edge_grid: bool | None = None,
                  layout: SlabLayout = LAYOUT_F32) -> BucketedIndex:
    """Freeze a host index into width-bucketed slabs (DESIGN.md §4).

    Each region goes into the smallest power-of-two-multiple-of-``lane``
    bucket that holds its label count, so padding waste is < 50% per region
    instead of being governed by the single largest merged region.

    ``reuse_edges_from``: repack-from-index fast path for the adaptive
    hot-swap loop — the scene (and thus the padded edge tensors and the
    edge grid built from them) never changes across recompressions, so the
    previous artifact's device-resident ``edges_a/b/c`` and ``grid`` are
    aliased instead of re-uploaded.  Region packs untouched since the last
    pack are already reused via the per-region ``packed`` cache
    (:meth:`EHLIndex.pack_region`).

    ``edge_grid``: ``None`` attaches the §10 edge grid when pruning pays,
    ``True``/``False`` force it on/off (ignored when reusing — the previous
    artifact's decision carries over with its arrays).
    """
    live, packs = _host_packs(index)
    counts, widths, region_bucket = plan_buckets(index, lane)
    region_row = np.zeros(len(live), dtype=np.int32)
    members: list[list[int]] = [[] for _ in widths]
    for i, b in enumerate(region_bucket):
        region_row[i] = len(members[b])
        members[b].append(i)

    slabs = []
    for k, w in enumerate(widths):
        arrs = _alloc_slab(max(1, len(members[k])), w)
        for row, i in enumerate(members[k]):
            _fill_row(arrs, row, packs[i])
        slabs.append(arrs)

    mapper = _cell_mapper(index, live)
    if reuse_edges_from is not None:
        ea, eb, ec = (reuse_edges_from.edges_a, reuse_edges_from.edges_b,
                      reuse_edges_from.edges_c)
        grid = reuse_edges_from.grid
    else:
        ea, eb, ec = _pack_edges(index, lane)
        grid = _maybe_grid(ea, eb, index.scene.edges.shape[0], index.scene,
                           edge_grid)
        ea, eb, ec = jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(ec)
    if layout.quantized:
        quant = [_quantize_slab(a, layout) for a in slabs]
        residual = ResidualTable(
            [a[2] for a in slabs], region_bucket, region_row, mapper,
            widths, index.nx, index.ny, float(index.cell_size))
        return BucketedIndex(
            hub_ids=tuple(jnp.asarray(q[0]) for q in quant),
            via_xy=(),
            via_d=tuple(jnp.asarray(q[1]) for q in quant),
            via_ids=tuple(jnp.asarray(q[2]) for q in quant),
            mapper=jnp.asarray(mapper),
            region_bucket=jnp.asarray(region_bucket),
            region_row=jnp.asarray(region_row),
            edges_a=ea, edges_b=eb, edges_c=ec, grid=grid,
            nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
            width=float(index.scene.width), height=float(index.scene.height),
            widths=tuple(widths),
            vert_xy=_vert_table(index),
            hub_base=tuple(jnp.asarray(q[3]) for q in quant),
            vid_base=tuple(jnp.asarray(q[4]) for q in quant),
            qerr=jnp.float32(max((q[5] for q in quant), default=0.0)),
            layout=layout, residual=residual)
    return BucketedIndex(
        hub_ids=tuple(jnp.asarray(a[0]) for a in slabs),
        via_xy=tuple(jnp.asarray(a[1]) for a in slabs),
        via_d=tuple(jnp.asarray(a[2]) for a in slabs),
        via_ids=tuple(jnp.asarray(a[3]) for a in slabs),
        mapper=jnp.asarray(mapper),
        region_bucket=jnp.asarray(region_bucket),
        region_row=jnp.asarray(region_row),
        edges_a=ea, edges_b=eb, edges_c=ec, grid=grid,
        nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
        width=float(index.scene.width), height=float(index.scene.height),
        widths=tuple(widths))


# ---------------------------------------------------------------------------
# batched query engine (pure jnp; kernels plug in via repro.kernels.ops)
# ---------------------------------------------------------------------------

def locate_regions(idx, pts: jnp.ndarray) -> jnp.ndarray:
    """[B] region rows/ids for query points (floor-div + mapper, O(1)).

    Works for both layouts: PackedIndex's mapper yields slab rows,
    BucketedIndex's yields region ids (resolve via region_bucket/row).
    """
    ix = jnp.clip((pts[:, 0] / idx.cell_size).astype(jnp.int32), 0, idx.nx - 1)
    iy = jnp.clip((pts[:, 1] / idx.cell_size).astype(jnp.int32), 0, idx.ny - 1)
    return idx.mapper[iy * idx.nx + ix]


def _segvis(p, q, edges, use_kernels: bool):
    """Visibility dispatch: grid-pruned when the artifact carries a grid.

    ``edges`` is the (edges_a, edges_b, edges_c, grid) tuple; the grid path
    is bitwise-identical to the dense path (DESIGN.md §10 superset
    argument), so this choice is invisible to every caller.
    """
    from repro.kernels import ops

    ea, eb, ec, grid = edges
    if grid is not None:
        return segvis_grid(p, q, ea, eb, ec, grid, use_kernels=use_kernels)
    fn = ops.segvis_kernel if use_kernels else ops.segvis_ref
    return fn(p, q, ea, eb, ec)


def _mask_labels(labels, pts, edges, use_kernels: bool):
    """Per-endpoint half of Eq. 1-3: fold via visibility into distances.

    (hub [B,L], xy [B,L,2], d [B,L], vid [B,L]) -> (hub, vd, vid) where
    ``vd`` is inf wherever the via vertex is invisible from the query
    point.  Runs on whichever device holds the endpoint's labels — the
    sharded router calls it per shard with that shard's clipped edge set,
    which covers every segment of queries in its owned regions, so results
    match the single-device full-edge fold exactly.
    """
    hub, xy, d, vid = labels
    B, L = hub.shape
    vis = _segvis(jnp.repeat(pts, L, axis=0), xy.reshape(-1, 2),
                  edges, use_kernels).reshape(B, L)
    vd = jnp.where(vis, jnp.linalg.norm(pts[:, None] - xy, axis=-1) + d,
                   jnp.float32(jnp.inf))
    return hub, vd, vid


def _join_masked(masked_s, masked_t, s, t, covis, use_kernels: bool,
                 want_argmin: bool, qerr2=None):
    """Join half of Eq. 1-3 over visibility-masked labels.

    The join emits the row-min form ``rowmin[b,i] = vd_s[b,i] + min_{hub
    match j} vd_t[b,j]`` and the argmin pair is recovered with two cheap
    O(L) reductions.  ``covis`` overrides with the direct Euclidean
    distance (the label set does not witness co-visible pairs).

    ``qerr2`` (quantized layouts only, with ``want_argmin``): the summed
    per-side quantization error bounds.  A sixth ``amb`` [B] bool output
    flags rows whose argmin margin is within the error bound — their
    winner could differ from the f32 engine's, so the host rescues them
    against the exact residual rows (DESIGN.md §11).  Rows with a unique
    candidate (inf second-best) or no candidate at all (all-inf row) are
    provably unambiguous and excluded.
    """
    from repro.kernels import ops

    hub_s, vd_s, vid_s = masked_s
    hub_t, vd_t, vid_t = masked_t
    rowmin_join = (ops.label_join_rowmin_kernel if use_kernels
                   else ops.label_join_rowmin_ref)

    rowmin = rowmin_join(hub_s, vd_s, hub_t, vd_t)      # [B, L]
    d_label = rowmin.min(axis=-1)
    d_direct = jnp.linalg.norm(s - t, axis=-1)
    d = jnp.where(covis, d_direct, d_label)
    if not want_argmin:
        return d

    # winning (i, j): i minimizes the row join; with i's hub fixed, j is the
    # min-vd_t label sharing that hub (ties resolve to the first index, same
    # as the historical flat [L,L] argmin).
    inf = jnp.float32(jnp.inf)
    i = jnp.argmin(rowmin, axis=-1)                     # [B]
    hub_i = jnp.take_along_axis(hub_s, i[:, None], 1)   # [B, 1]
    vd_t_match = jnp.where(hub_t == hub_i, vd_t, inf)
    j = jnp.argmin(vd_t_match, axis=-1)                 # [B]
    via_s = jnp.take_along_axis(vid_s, i[:, None], 1)[:, 0]
    via_t = jnp.take_along_axis(vid_t, j[:, None], 1)[:, 0]
    hub = hub_i[:, 0]
    if qerr2 is None:
        return d, covis, via_s, hub, via_t

    # exact-argmin ambiguity: two candidates can swap order in exact f32
    # space only if their quantized margin is within twice the worst-case
    # per-candidate perturbation (qerr2 plus a few ulps of f32 rounding)
    L = rowmin.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
    second_i = jnp.min(jnp.where(iota == i[:, None], inf, rowmin), -1)
    best_j = jnp.take_along_axis(vd_t_match, j[:, None], 1)[:, 0]
    second_j = jnp.min(jnp.where(iota == j[:, None], inf, vd_t_match), -1)
    thr = (jnp.float32(2.0) * qerr2
           + jnp.float32(64.0) * jnp.finfo(jnp.float32).eps
           * jnp.abs(d_label))
    amb = ((jnp.isfinite(second_i) & (second_i - d_label <= thr))
           | (jnp.isfinite(second_j) & (second_j - best_j <= thr)))
    return d, covis, via_s, hub, via_t, amb


def _labels_to_distances(labels_s, labels_t, s, t, edges,
                         use_kernels: bool, want_argmin: bool, qerr2=None):
    """Shared Eq. 1-3 core: per-endpoint labels -> distances (+ argmin ids).

    ``labels_*`` are (hub_ids [B,L], via_xy [B,L,2], via_d [B,L],
    via_ids [B,L]) gathered for each query endpoint; ``edges`` is the
    (edges_a, edges_b, edges_c, grid) tuple.  One code path serves
    ``query_batch``, ``query_batch_argmin``, the bucketed dispatch and
    (split across devices) the sharded router, for both the jnp reference
    ops and the Pallas kernels.
    """
    masked_s = _mask_labels(labels_s, s, edges, use_kernels)
    masked_t = _mask_labels(labels_t, t, edges, use_kernels)
    covis = _segvis(s, t, edges, use_kernels)           # [B]
    # materialize the masked triples: left to itself XLA fuses the O(W*E)
    # visibility fold into the O(W^2) join and re-evaluates per pair —
    # measurably slower for every layout, ruinously so for quantized
    # slabs whose fold also drags the decode gathers along (identity op,
    # so bitwise answers are untouched)
    masked_s, masked_t = jax.lax.optimization_barrier((masked_s, masked_t))
    return _join_masked(masked_s, masked_t, s, t, covis, use_kernels,
                        want_argmin, qerr2=qerr2)


def _decode_ids(enc: jnp.ndarray, base: jnp.ndarray, pad_val) -> jnp.ndarray:
    """u16 delta rows + per-row bases -> exact int32 ids (i32 passes through).

    The dtype check is a trace-time constant, so per-bucket i32 fallbacks
    compile to a plain passthrough — fallback handling costs nothing where
    it didn't happen.
    """
    if enc.dtype != jnp.uint16:
        return enc
    raw = base[:, None].astype(jnp.int32) + enc.astype(jnp.int32)
    return jnp.where(enc == jnp.uint16(U16_PAD), jnp.int32(pad_val), raw)


def _via_xy_of(vid: jnp.ndarray, vert_xy: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the per-slot via coordinates from the shared vertex table.

    Bitwise-equal to the f32 layout's ``via_xy`` plane: the packer writes
    ``graph.nodes[via]`` (cast f32) per slot and zeros for pads, which is
    exactly ``vert_xy[vid]`` masked at ``vid < 0``.
    """
    xy = vert_xy[jnp.clip(vid, 0, vert_xy.shape[0] - 1)]
    return jnp.where((vid >= 0)[..., None], xy, jnp.float32(0.0))


def _gather_packed(idx: PackedIndex, rows: jnp.ndarray):
    if not idx.layout.quantized:
        return (idx.hub_ids[rows], idx.via_xy[rows], idx.via_d[rows],
                idx.via_ids[rows])
    hub = _decode_ids(idx.hub_ids[rows], idx.hub_base[rows], HUB_PAD)
    vid = _decode_ids(idx.via_ids[rows], idx.vid_base[rows], -1)
    # materialize the decoded planes (see _gather_bucketed: XLA would
    # otherwise re-evaluate the decode gathers inside the visibility loop)
    return jax.lax.optimization_barrier(
        (hub, _via_xy_of(vid, idx.vert_xy),
         idx.via_d[rows].astype(jnp.float32), vid))


def _edges_of(idx) -> tuple:
    return (idx.edges_a, idx.edges_b, idx.edges_c, idx.grid)


@_jit_entry("fold_endpoint", static_argnames=("bucket", "use_kernels"))
def _fold_endpoint(idx, pts: jnp.ndarray, bucket=None,
                   use_kernels: bool = False):
    """locate + gather + visibility-fold one endpoint side (own jit entry).

    ``bucket=None`` gathers the single PackedIndex slab; an int gathers the
    bucketed layout at that dispatch bucket.  Splitting the fold from the
    O(W^2) join at a real jit boundary materializes the gathered planes:
    fused into one program, XLA folds the gather/decode chain into the
    visibility loop and re-evaluates it per edge — same flop count, ~2x
    wall on wide buckets for quantized layouts (``optimization_barrier``
    does not survive this backend's fusion pass).  The boundary changes no
    arithmetic: the sharded engine has always split here
    (``gather_masked_labels`` + ``join_masked``) and is bitwise-identical
    to the fused engine.
    """
    TRACES.bump("fold_endpoint")
    pts = pts.astype(jnp.float32)
    r = locate_regions(idx, pts)
    labels = (_gather_packed(idx, r) if bucket is None
              else _gather_bucketed(idx, r, bucket))
    return _mask_labels(labels, pts, _edges_of(idx), use_kernels)


@_jit_entry("join_endpoints", static_argnames=("use_kernels", "want_argmin"))
def _join_endpoints(idx, masked_s, masked_t, s: jnp.ndarray, t: jnp.ndarray,
                    use_kernels: bool = False, want_argmin: bool = False,
                    qerr2=None):
    """Co-visibility + Eq. 1-3 join over folded endpoint sides (jit entry)."""
    TRACES.bump("join_endpoints")
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    covis = _segvis(s, t, _edges_of(idx), use_kernels)
    return _join_masked(masked_s, masked_t, s, t, covis, use_kernels,
                        want_argmin, qerr2=qerr2)


def query_batch(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray,
                use_kernels: bool = False) -> jnp.ndarray:
    """Batched Eq. 1-3: shortest distances for query pairs [B,2]x[B,2].

    use_kernels=True routes visibility + join through the Pallas kernels
    (``repro.kernels.ops``); False uses their jnp references — identical
    semantics, asserted by tests.  Two async jit dispatches per call
    (endpoint folds + join; see :func:`_fold_endpoint`).
    """
    s = jnp.asarray(s).astype(jnp.float32)
    t = jnp.asarray(t).astype(jnp.float32)
    ms = _fold_endpoint(idx, s, use_kernels=use_kernels)
    mt = _fold_endpoint(idx, t, use_kernels=use_kernels)
    return _join_endpoints(idx, ms, mt, s, t, use_kernels=use_kernels)


def query_batch_argmin(idx: PackedIndex, s: jnp.ndarray, t: jnp.ndarray,
                       use_kernels: bool = False):
    """Distances + winning (via_s, hub, via_t) label ids (path unwinding).

    Quantized layouts return a sixth ``amb`` array — rows the caller must
    rescue against the residual (:func:`rescue_exact`) for exact argmin.
    """
    s = jnp.asarray(s).astype(jnp.float32)
    t = jnp.asarray(t).astype(jnp.float32)
    ms = _fold_endpoint(idx, s, use_kernels=use_kernels)
    mt = _fold_endpoint(idx, t, use_kernels=use_kernels)
    qerr2 = idx.qerr + idx.qerr if idx.layout.quantized else None
    return _join_endpoints(idx, ms, mt, s, t, use_kernels=use_kernels,
                           want_argmin=True, qerr2=qerr2)


# ---------------------------------------------------------------------------
# bucketed dispatch
# ---------------------------------------------------------------------------

def _gather_bucketed(bx: BucketedIndex, regions: jnp.ndarray, bucket: int,
                     width: int | None = None):
    """Gather per-query labels from buckets <= ``bucket``, padded to its width.

    One masked gather per source bucket (a handful of O(B*W) memory ops) in
    exchange for running the O(W^2) join and O(W*E) visibility at the
    dispatch width instead of the global Lmax.  Regions living in a *wider*
    bucket than ``bucket`` come back as pure padding (inf distances) — the
    caller must dispatch each query at the max of its endpoint buckets.

    ``width`` (>= ``widths[bucket]``) pads the gather beyond the bucket's
    own width.  The extra slots are HUB_PAD/inf — inert in the join — so a
    sharded query whose two endpoints live on shards with different bucket
    ladders can be joined at the pair's common width (``repro.sharding``).
    """
    W = bx.widths[bucket] if width is None else width
    B = regions.shape[0]
    hub = jnp.full((B, W), HUB_PAD, jnp.int32)
    xy = jnp.zeros((B, W, 2), jnp.float32)
    vd = jnp.full((B, W), jnp.inf, jnp.float32)
    vid = jnp.full((B, W), -1, jnp.int32)

    src_bucket = bx.region_bucket[regions]
    src_row = bx.region_row[regions]
    quantized = bx.layout.quantized
    for k in range(bucket + 1):
        rows = jnp.clip(src_row, 0, bx.hub_ids[k].shape[0] - 1)
        sel = src_bucket == k
        pad = ((0, 0), (0, W - bx.widths[k]))
        if quantized:
            # dequantize in the gather: decode ids against the per-row
            # bases, rebuild xy from the shared vertex table and widen the
            # distances — downstream masking/join code is dtype-blind and
            # identical to the f32 path.  The barrier materializes the
            # decoded planes once: without it XLA fuses the decode chain
            # into the O(W*E) visibility loop and re-evaluates the gathers
            # per edge (~2x wall on wide buckets, same flop count).
            hub_k = _decode_ids(bx.hub_ids[k][rows], bx.hub_base[k][rows],
                                HUB_PAD)
            vid_k = _decode_ids(bx.via_ids[k][rows], bx.vid_base[k][rows],
                                -1)
            xy_k = _via_xy_of(vid_k, bx.vert_xy)
            vd_k = bx.via_d[k][rows].astype(jnp.float32)
        else:
            hub_k, xy_k, vd_k, vid_k = (bx.hub_ids[k][rows],
                                        bx.via_xy[k][rows],
                                        bx.via_d[k][rows],
                                        bx.via_ids[k][rows])
        hub = jnp.where(sel[:, None],
                        jnp.pad(hub_k, pad, constant_values=HUB_PAD), hub)
        xy = jnp.where(sel[:, None, None],
                       jnp.pad(xy_k, pad + ((0, 0),)), xy)
        vd = jnp.where(sel[:, None],
                       jnp.pad(vd_k, pad, constant_values=np.inf), vd)
        vid = jnp.where(sel[:, None],
                        jnp.pad(vid_k, pad, constant_values=-1), vid)
    # materialize the merged planes: the select/pad merge chain (and, for
    # quantized layouts, the decode gathers feeding it) must not fuse into
    # the O(W*E) visibility fold downstream, which re-evaluates its input
    # expression per edge (identity op — bitwise answers untouched)
    return jax.lax.optimization_barrier((hub, xy, vd, vid))


def query_batch_at_bucket(bx: BucketedIndex, s: jnp.ndarray, t: jnp.ndarray,
                          bucket: int, use_kernels: bool = False,
                          want_argmin: bool = False):
    """Eq. 1-3 over one dispatch bucket (per-bucket fold + join jit entries).

    Every query's endpoint regions must live in buckets <= ``bucket``
    (i.e. ``bucket == max(endpoint buckets)`` after routing); the result is
    then bitwise-identical to the full-width ``query_batch`` because the
    extra slots it would have carried are all inf/HUB_PAD padding.
    """
    s = jnp.asarray(s).astype(jnp.float32)
    t = jnp.asarray(t).astype(jnp.float32)
    ms = _fold_endpoint(bx, s, bucket=bucket, use_kernels=use_kernels)
    mt = _fold_endpoint(bx, t, bucket=bucket, use_kernels=use_kernels)
    qerr2 = (bx.qerr + bx.qerr
             if bx.layout.quantized and want_argmin else None)
    return _join_endpoints(bx, ms, mt, s, t, use_kernels=use_kernels,
                           want_argmin=want_argmin, qerr2=qerr2)


# ---------------------------------------------------------------------------
# sharded dispatch primitives (repro.sharding)
# ---------------------------------------------------------------------------

@_jit_entry("gather_labels_at_width", static_argnames=("width",))  # repolint: disable=jit-registry -- library-only full-gather API; no engine calls it, so warmup cannot reach it
def gather_labels_at_width(bx: BucketedIndex, regions: jnp.ndarray,
                           width: int):
    """Gather [B] regions' labels as dense [B, width] tensors.

    ``width`` must be >= the widest bucket any of ``regions`` lives in —
    the host router guarantees that by dispatching at ``max(endpoint
    widths)``.
    """
    TRACES.bump("gather_labels_at_width")
    bucket = max((k for k, w in enumerate(bx.widths) if w <= width),
                 default=0)
    return _gather_bucketed(bx, regions, bucket, width)


@_jit_entry("join_gathered", static_argnames=("use_kernels", "want_argmin"))  # repolint: disable=jit-registry -- library-only full-gather API; no engine calls it, so warmup cannot reach it
def join_gathered(labels_s, labels_t, s: jnp.ndarray, t: jnp.ndarray,
                  edges_a: jnp.ndarray, edges_b: jnp.ndarray,
                  edges_c: jnp.ndarray | None = None,
                  grid: EdgeGrid | None = None,
                  use_kernels: bool = False, want_argmin: bool = False,
                  qerr2=None):
    """Eq. 1-3 over pre-gathered label tensors (both sides [B, W]).

    Single-device convenience form (one edge set answers both sides).  The
    sharded router uses the split-phase entries below instead, so each
    side's visibility runs on the device whose clipped edge set covers it.
    ``qerr2``: see :func:`_join_masked` (quantized argmin ambiguity).
    """
    TRACES.bump("join_gathered")
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    edges = (edges_a, edges_b, edges_b if edges_c is None else edges_c, grid)
    return _labels_to_distances(labels_s, labels_t, s, t, edges,
                                use_kernels, want_argmin, qerr2=qerr2)


@_jit_entry("gather_masked_labels", static_argnames=("width", "use_kernels"))
def gather_masked_labels(bx: BucketedIndex, regions: jnp.ndarray,
                         pts: jnp.ndarray, width: int,
                         use_kernels: bool = False):
    """Gather + visibility-fold one endpoint side on its owning shard.

    The device half of sharded routing (DESIGN.md §9/§10): the owning
    shard's edge subset is clipped to its owned regions dilated by their
    label reach, which covers every (query point -> via) segment of
    queries located in those regions — so the returned (hub, vd, vid)
    triple is byte-identical to the full-edge single-device fold.  For a
    cross-shard query the t-side triple then ships to the s-side device
    ([B, W] tensors, not slabs) for :func:`join_masked`.
    """
    TRACES.bump("gather_masked_labels")
    bucket = max((k for k, w in enumerate(bx.widths) if w <= width),
                 default=0)
    labels = _gather_bucketed(bx, regions, bucket, width)
    return _mask_labels(labels, pts.astype(jnp.float32), _edges_of(bx),
                        use_kernels)


@_jit_entry("covis_blocked", static_argnames=("use_kernels",))
def covis_blocked(s: jnp.ndarray, t: jnp.ndarray, edges_a, edges_b, edges_c,
                  grid: EdgeGrid | None = None,
                  use_kernels: bool = False) -> jnp.ndarray:
    """[B] int32 — 1 where a *local* edge blocks the direct s->t segment.

    The distributed co-visibility test: each shard whose owned bounding box
    the batch touches answers against its own clipped edges, and the router
    ORs the verdicts — the union of participating clips covers every edge
    the segment can cross, so the OR equals the single-device covis bit.
    """
    TRACES.bump("covis_blocked")
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    vis = _segvis(s, t, (edges_a, edges_b, edges_c, grid), use_kernels)
    return (~vis).astype(jnp.int32)


@_jit_entry("join_masked", static_argnames=("use_kernels", "want_argmin"))
def join_masked(masked_s, masked_t, s: jnp.ndarray, t: jnp.ndarray,
                covis: jnp.ndarray, use_kernels: bool = False,
                want_argmin: bool = False, qerr2=None):
    """Eq. 1-3 join over visibility-masked label triples (both sides [B, W]).

    Runs on the s-side device; ``covis`` is the merged co-visibility bit
    from :func:`covis_blocked`.  With identical masked inputs this is
    bitwise-identical to the single-device ``query_batch_at_bucket`` tail —
    it is the same code.  ``qerr2``: see :func:`_join_masked` (quantized
    argmin ambiguity; pass the *sum* of the two shards' error bounds).
    """
    TRACES.bump("join_masked")
    s = s.astype(jnp.float32)
    t = t.astype(jnp.float32)
    return _join_masked(masked_s, masked_t, s, t, covis.astype(bool),
                        use_kernels, want_argmin, qerr2=qerr2)


# ---------------------------------------------------------------------------
# quantized layouts: exact-argmin rescue + cross-shard quantized wire
# ---------------------------------------------------------------------------

@_jit_entry("gather_masked_exact", static_argnames=("width", "use_kernels"))
def gather_masked_exact(idx, pts: jnp.ndarray, d_exact: jnp.ndarray,
                        width: int, use_kernels: bool = False):
    """Rescue gather: quantized slabs with the exact f32 distance rows.

    ``d_exact`` is the [B, width] residual gather
    (:meth:`ResidualTable.gather_d`) for these points.  Ids and via
    coordinates decode exactly from the device slabs, so substituting the
    exact distances makes the returned masked triple *bitwise-identical*
    to the f32 engine's visibility fold — the rescue join then reproduces
    the f32 argmin exactly.
    """
    TRACES.bump("gather_masked_exact")
    pts = pts.astype(jnp.float32)
    regions = locate_regions(idx, pts)
    if isinstance(idx, PackedIndex):
        hub, xy, _, vid = _gather_packed(idx, regions)
    else:
        bucket = max((k for k, w in enumerate(idx.widths) if w <= width),
                     default=0)
        hub, xy, _, vid = _gather_bucketed(idx, regions, bucket, width)
    return _mask_labels((hub, xy, d_exact.astype(jnp.float32), vid), pts,
                        _edges_of(idx), use_kernels)


def rescue_exact(idx, s, t, width: int, covis, use_kernels: bool = False):
    """Re-answer a batch with exact distances (host residual -> device).

    Full-batch recomputation (shapes match the quantized run, so traces
    are reused); the caller splices only the ambiguous rows.  ``covis`` is
    the quantized run's co-visibility bit — pure geometry, identical in
    both layouts.  Returns the exact 5-tuple.
    """
    res = idx.residual
    if res is None:
        raise ValueError("rescue_exact needs a quantized index with its "
                         "ResidualTable attached")
    s = np.asarray(s, np.float32)
    t = np.asarray(t, np.float32)
    ds = res.gather_d(res.locate(s), width)
    dt = res.gather_d(res.locate(t), width)
    ms = gather_masked_exact(idx, jnp.asarray(s), jnp.asarray(ds), width,
                             use_kernels=use_kernels)
    mt = gather_masked_exact(idx, jnp.asarray(t), jnp.asarray(dt), width,
                             use_kernels=use_kernels)
    return join_masked(ms, mt, jnp.asarray(s), jnp.asarray(t), covis,
                       use_kernels=use_kernels, want_argmin=True)


def splice_rescue(quant6, exact5) -> tuple:
    """Host splice: overwrite ambiguous rows of the quantized answers with
    the exact rescue rows.  Returns the engine's plain 5-tuple (numpy)."""
    d, cv, vs, hb, vt, amb = quant6
    outs = [np.asarray(a).copy() for a in (d, cv, vs, hb, vt)]
    m = np.asarray(amb)
    for o, e in zip(outs, exact5):
        o[m] = np.asarray(e)[m]
    return tuple(outs)


def wire_dtypes(bx: BucketedIndex) -> tuple:
    """(id_dtype, dist_dtype) of the cross-shard quantized wire.

    Unified per artifact: if *any* bucket fell back to raw i32 ids (range
    overflow) the whole wire ships i32; likewise any f32 distance fallback
    widens the distance plane.  Keeps the wire a single dtype so one trace
    serves every bucket mix.
    """
    id_dt = np.dtype(np.uint16)
    for arr in (*bx.hub_ids, *bx.via_ids):
        if np.dtype(arr.dtype) != np.uint16:
            id_dt = np.dtype(np.int32)
    dist_dt = np.dtype(bx.layout.dist_dtype)
    for arr in bx.via_d:
        if np.dtype(arr.dtype) != dist_dt:
            dist_dt = np.dtype(np.float32)
    return id_dt, dist_dt


def _gather_quant_plane(slabs, bases, src_bucket, src_row, widths,
                        bucket: int, W: int, wire_i32: bool, pad_raw,
                        B: int):
    """One id plane of the quantized wire gather (hub or via)."""
    if wire_i32:
        enc = jnp.full((B, W), jnp.int32(pad_raw), jnp.int32)
    else:
        enc = jnp.full((B, W), U16_PAD, jnp.uint16)
    base = jnp.zeros((B,), jnp.int32)
    for k in range(bucket + 1):
        rows = jnp.clip(src_row, 0, slabs[k].shape[0] - 1)
        sel = src_bucket == k
        pad = ((0, 0), (0, W - widths[k]))
        if wire_i32:
            plane = _decode_ids(slabs[k][rows], bases[k][rows], pad_raw)
            enc = jnp.where(sel[:, None],
                            jnp.pad(plane, pad, constant_values=pad_raw),
                            enc)
        else:
            enc = jnp.where(sel[:, None],
                            jnp.pad(slabs[k][rows], pad,
                                    constant_values=int(U16_PAD)), enc)
            base = jnp.where(sel, bases[k][rows], base)
    return enc, base


@_jit_entry("gather_quant_rows", static_argnames=("width", "use_kernels"))
def gather_quant_rows(bx: BucketedIndex, regions: jnp.ndarray,
                      pts: jnp.ndarray, width: int,
                      use_kernels: bool = False):
    """Owner-side half of the quantized cross-shard gather.

    Ships the *encoded* label rows — (hub_enc, hub_base, dq, via_enc,
    via_base, vis) — instead of the decoded f32 masked triple, cutting the
    wire from 12 to ~7 bytes per slot.  The visibility fold's verdict is
    computed here (the owner holds the clipped edge set) but the decode +
    distance sum happen on the joining device
    (:func:`dequant_masked_labels`), which reproduces the owner-side fold
    bit for bit (same expression, same input bits).
    """
    TRACES.bump("gather_quant_rows")
    pts = pts.astype(jnp.float32)
    bucket = max((k for k, w in enumerate(bx.widths) if w <= width),
                 default=0)
    id_dt, dist_dt = wire_dtypes(bx)
    wire_i32 = id_dt == np.int32
    src_bucket = bx.region_bucket[regions]
    src_row = bx.region_row[regions]
    B = regions.shape[0]
    henc, hbase = _gather_quant_plane(
        bx.hub_ids, bx.hub_base, src_bucket, src_row, bx.widths, bucket,
        width, wire_i32, HUB_PAD, B)
    venc, vbase = _gather_quant_plane(
        bx.via_ids, bx.vid_base, src_bucket, src_row, bx.widths, bucket,
        width, wire_i32, -1, B)
    dq = jnp.full((B, width), jnp.asarray(np.inf, dist_dt), dist_dt)
    for k in range(bucket + 1):
        rows = jnp.clip(src_row, 0, bx.via_d[k].shape[0] - 1)
        sel = src_bucket == k
        pad = ((0, 0), (0, width - bx.widths[k]))
        dq = jnp.where(sel[:, None],
                       jnp.pad(bx.via_d[k][rows].astype(dist_dt), pad,
                               constant_values=np.inf), dq)
    vid = _decode_ids(venc, vbase, -1)
    xy = _via_xy_of(vid, bx.vert_xy)
    vis = _segvis(jnp.repeat(pts, width, axis=0), xy.reshape(-1, 2),
                  _edges_of(bx), use_kernels).reshape(B, width)
    return henc, hbase, dq, venc, vbase, vis


@_jit_entry("dequant_masked_labels")
def dequant_masked_labels(henc, hbase, dq, venc, vbase, vis,
                          pts: jnp.ndarray, vert_xy: jnp.ndarray):
    """Joining-device half: decode shipped quantized rows into the masked
    triple — the same ``where(vis, norm + d, inf)`` expression as the
    owner-side fold, so the result is bitwise-identical to having shipped
    the decoded rows."""
    TRACES.bump("dequant_masked_labels")
    pts = pts.astype(jnp.float32)
    hub = _decode_ids(henc, hbase, HUB_PAD)
    vid = _decode_ids(venc, vbase, -1)
    xy = _via_xy_of(vid, vert_xy)
    vd = jnp.where(vis, jnp.linalg.norm(pts[:, None] - xy, axis=-1)
                   + dq.astype(jnp.float32), jnp.float32(jnp.inf))
    # materialize before the O(L^2) join fusion (see _gather_bucketed)
    return jax.lax.optimization_barrier((hub, vd, vid))


def _region_clip_boxes(index: EHLIndex, live: list, packs: list,
                       cell_region: np.ndarray) -> np.ndarray:
    """[R, 4] per-region visibility-reach boxes (xmin, ymin, xmax, ymax).

    The box spans the region's own cells *and* every via vertex its labels
    reach: any (query point -> via) segment of a query located in the
    region stays inside the box (a segment lies in the bounding box of its
    endpoints), and so does the region-local part of any s->t segment.
    Dilated by a small slack so float32 sign tests on nearly-touching
    edges can never disagree with the clip.
    """
    R = len(live)
    cs = float(index.cell_size)
    iy, ix = np.divmod(np.arange(index.mapper.size), index.nx)
    boxes = np.full((R, 4), np.inf)
    boxes[:, 2:] = -np.inf
    np.minimum.at(boxes[:, 0], cell_region, ix * cs)
    np.minimum.at(boxes[:, 1], cell_region, iy * cs)
    np.maximum.at(boxes[:, 2], cell_region, (ix + 1) * cs)
    np.maximum.at(boxes[:, 3], cell_region, (iy + 1) * cs)
    for r, p in enumerate(packs):
        xy = p["via_xy"]
        if len(xy):
            boxes[r, 0] = min(boxes[r, 0], xy[:, 0].min())
            boxes[r, 1] = min(boxes[r, 1], xy[:, 1].min())
            boxes[r, 2] = max(boxes[r, 2], xy[:, 0].max())
            boxes[r, 3] = max(boxes[r, 3], xy[:, 1].max())
    slack = 1e-3 * max(index.scene.width, index.scene.height)
    boxes[:, :2] -= slack
    boxes[:, 2:] += slack
    return boxes


def _shard_edge_mask(index: EHLIndex, clip_boxes: np.ndarray,
                     members: np.ndarray) -> np.ndarray:
    """[E] bool — edges whose bbox meets any owned region's clip box."""
    edges = index.scene.edges
    if edges.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    ex0 = np.minimum(edges[:, 0, 0], edges[:, 1, 0])
    ex1 = np.maximum(edges[:, 0, 0], edges[:, 1, 0])
    ey0 = np.minimum(edges[:, 0, 1], edges[:, 1, 1])
    ey1 = np.maximum(edges[:, 0, 1], edges[:, 1, 1])
    bx = clip_boxes[members]                            # [Rk, 4]
    hit = ((ex0[None] <= bx[:, 2:3]) & (ex1[None] >= bx[:, 0:1]) &
           (ey0[None] <= bx[:, 3:4]) & (ey1[None] >= bx[:, 1:2]))
    return hit.any(axis=0)


def pack_bucketed_split(index: EHLIndex, region_shard: np.ndarray,
                        num_shards: int | None = None, lane: int = 128,
                        reuse_edges_from=None, reuse_edge_masks=None,
                        edge_grid: bool | None = None,
                        layout: SlabLayout = LAYOUT_F32):
    """Freeze a host index into per-shard width-bucketed slabs.

    The shard-aware sibling of :func:`pack_bucketed`: ``region_shard`` maps
    each live region (in live-rid order, as ``packed_label_counts``) to a
    shard; each shard gets its own :class:`BucketedIndex` holding only its
    regions' slabs, with the bucket ladder recomputed from its own label
    counts (a region's bucket *width* is invariant — smallest power-of-two
    multiple of ``lane`` — so sharded join widths match the unsharded
    dispatch widths exactly).

    **Edges are no longer replicated**: each shard carries only the edges
    whose bounding box meets one of its owned regions' clip boxes (region
    cells + every via vertex its labels reach, slack-dilated) — sufficient
    for both the label-visibility fold of queries it owns and its share of
    the distributed co-visibility test (DESIGN.md §9/§10).  Each subset
    gets its own edge grid per the ``edge_grid`` policy.

    Every shard's mapper covers the full grid; cells owned by other shards
    resolve to local row 0 — harmless, because the host-side routing table
    returned alongside is what decides which shard a query is sent to.

    ``reuse_edges_from`` (+ ``reuse_edge_masks``): previous-generation
    per-shard artifacts and their edge masks — a shard's device-resident
    edge tensors/grid are aliased iff its clip mask is unchanged (the
    recompression may have changed label reach, so masks are compared, not
    assumed).

    Returns ``(shards, route)``: the per-shard ``BucketedIndex`` list plus
    the host-side routing table — cell arrays (``cell_shard``,
    ``cell_local``, ``cell_bucket``, ``cell_row``, ``cell_width``) and the
    per-shard ``edge_mask`` list and owned bounding ``shard_rects`` the
    router's distributed covis test uses.
    """
    live, packs = _host_packs(index)
    R = len(live)
    region_shard = np.asarray(region_shard, dtype=np.int32)
    if region_shard.shape != (R,):
        raise ValueError(f"region_shard has shape {region_shard.shape}, "
                         f"index has {R} live regions")
    S = int(num_shards) if num_shards is not None \
        else int(region_shard.max(initial=-1)) + 1
    counts = index.packed_label_counts()
    if reuse_edges_from is None or hasattr(reuse_edges_from, "edges_a"):
        reuse_edges_from = [reuse_edges_from] * S
    if reuse_edge_masks is None:
        reuse_edge_masks = [None] * S

    # global region -> (local id, local bucket, local row) within its shard
    region_local = np.zeros(R, dtype=np.int32)
    region_lbucket = np.zeros(R, dtype=np.int32)
    region_lrow = np.zeros(R, dtype=np.int32)
    region_width = np.array([bucket_width(max(1, int(c)), lane)
                             for c in counts], dtype=np.int32)
    cell_region = _cell_mapper(index, live)
    clip_boxes = _region_clip_boxes(index, live, packs, cell_region)

    shards, edge_masks, shard_rects = [], [], np.zeros((S, 4))
    for k in range(S):
        members = np.nonzero(region_shard == k)[0]
        if members.size == 0:
            raise ValueError(f"shard {k} owns no regions — plan fewer "
                             "shards or rebalance")
        region_local[members] = np.arange(members.size, dtype=np.int32)
        widths_k = sorted({int(region_width[i]) for i in members})
        bucket_of_width = {w: b for b, w in enumerate(widths_k)}
        lbucket = np.array([bucket_of_width[int(region_width[i])]
                            for i in members], dtype=np.int32)
        lrow = np.zeros(members.size, dtype=np.int32)
        slab_members: list[list[int]] = [[] for _ in widths_k]
        for li, gi in enumerate(members):
            b = lbucket[li]
            lrow[li] = len(slab_members[b])
            slab_members[b].append(int(gi))
        region_lbucket[members] = lbucket
        region_lrow[members] = lrow

        slabs = []
        for b, w in enumerate(widths_k):
            arrs = _alloc_slab(max(1, len(slab_members[b])), w)
            for row, gi in enumerate(slab_members[b]):
                _fill_row(arrs, row, packs[gi])
            slabs.append(arrs)

        mask = _shard_edge_mask(index, clip_boxes, members)
        edge_masks.append(mask)
        # owned bounding rect: which batches this shard's covis test covers
        cells_k = np.nonzero(region_shard[cell_region] == k)[0]
        iy, ix = np.divmod(cells_k, index.nx)
        cs = float(index.cell_size)
        shard_rects[k] = (ix.min() * cs, iy.min() * cs,
                          (ix.max() + 1) * cs, (iy.max() + 1) * cs)

        reuse = reuse_edges_from[k]
        prev_mask = reuse_edge_masks[k]
        if reuse is not None and prev_mask is not None \
                and np.array_equal(prev_mask, mask):
            ea, eb, ec = reuse.edges_a, reuse.edges_b, reuse.edges_c
            grid = reuse.grid
        else:
            ea, eb, ec = _pack_edges(index, lane, mask=mask)
            grid = _maybe_grid(ea, eb, int(mask.sum()), index.scene,
                               edge_grid)
            ea, eb, ec = jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(ec)

        # full-grid mapper: owned cells -> local id, foreign cells -> 0
        mapper_k = np.where(region_shard[cell_region] == k,
                            region_local[cell_region], 0).astype(np.int32)
        if layout.quantized:
            quant = [_quantize_slab(a, layout) for a in slabs]
            residual = ResidualTable(
                [a[2] for a in slabs], lbucket, lrow, mapper_k,
                widths_k, index.nx, index.ny, float(index.cell_size))
            shards.append(BucketedIndex(
                hub_ids=tuple(jnp.asarray(q[0]) for q in quant),
                via_xy=(),
                via_d=tuple(jnp.asarray(q[1]) for q in quant),
                via_ids=tuple(jnp.asarray(q[2]) for q in quant),
                mapper=jnp.asarray(mapper_k),
                region_bucket=jnp.asarray(lbucket),
                region_row=jnp.asarray(lrow),
                edges_a=ea, edges_b=eb, edges_c=ec, grid=grid,
                nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
                width=float(index.scene.width),
                height=float(index.scene.height),
                widths=tuple(widths_k),
                vert_xy=_vert_table(index),
                hub_base=tuple(jnp.asarray(q[3]) for q in quant),
                vid_base=tuple(jnp.asarray(q[4]) for q in quant),
                qerr=jnp.float32(max((q[5] for q in quant), default=0.0)),
                layout=layout, residual=residual))
        else:
            shards.append(BucketedIndex(
                hub_ids=tuple(jnp.asarray(a[0]) for a in slabs),
                via_xy=tuple(jnp.asarray(a[1]) for a in slabs),
                via_d=tuple(jnp.asarray(a[2]) for a in slabs),
                via_ids=tuple(jnp.asarray(a[3]) for a in slabs),
                mapper=jnp.asarray(mapper_k),
                region_bucket=jnp.asarray(lbucket),
                region_row=jnp.asarray(lrow),
                edges_a=ea, edges_b=eb, edges_c=ec, grid=grid,
                nx=index.nx, ny=index.ny, cell_size=float(index.cell_size),
                width=float(index.scene.width),
                height=float(index.scene.height),
                widths=tuple(widths_k)))

    route = dict(
        region_shard=region_shard,
        region_local=region_local,
        cell_region=cell_region,
        cell_shard=region_shard[cell_region],
        cell_local=region_local[cell_region],
        cell_bucket=region_lbucket[cell_region],
        cell_row=region_lrow[cell_region],
        cell_width=region_width[cell_region],
        edge_mask=edge_masks,
        shard_rects=shard_rects)
    return shards, route


def dispatch_buckets(bx: BucketedIndex, s, t) -> np.ndarray:
    """[B] dispatch bucket per query: max of the two endpoint buckets."""
    s = jnp.asarray(s, jnp.float32)
    t = jnp.asarray(t, jnp.float32)
    bs = bx.region_bucket[locate_regions(bx, s)]
    bt = bx.region_bucket[locate_regions(bx, t)]
    return np.asarray(jnp.maximum(bs, bt))


def query_batch_bucketed(bx: BucketedIndex, s, t,
                         use_kernels: bool = False,
                         want_argmin: bool = False):
    """Route a batch through per-bucket dispatch and scatter results back.

    Host-side convenience wrapper (PathServer does the same routing with
    fixed batch shapes and per-bucket stats): group queries by dispatch
    bucket, answer each group at its own width, reassemble in input order.
    """
    s = np.asarray(s, np.float32)
    t = np.asarray(t, np.float32)
    n = len(s)
    buckets = dispatch_buckets(bx, s, t) if n else np.zeros(0, np.int32)
    outs = empty_results(n, want_argmin)
    for k in np.unique(buckets):
        m = buckets == k
        res = query_batch_at_bucket(bx, jnp.asarray(s[m]), jnp.asarray(t[m]),
                                    bucket=int(k), use_kernels=use_kernels,
                                    want_argmin=want_argmin)
        if want_argmin and bx.layout.quantized:
            # 6-tuple: rescue ambiguous-margin rows against the residual
            if bool(np.asarray(res[5]).any()):
                exact = rescue_exact(bx, s[m], t[m], bx.widths[int(k)],
                                     res[1], use_kernels=use_kernels)
                res = splice_rescue(res, exact)
            else:
                res = res[:5]
        for o, r in zip(outs, res if want_argmin else (res,)):
            o[m] = np.asarray(r)
    return tuple(outs) if want_argmin else outs[0]


def empty_results(n: int, want_argmin: bool) -> list:
    """Output buffers matching the engine dtypes: d [+ covis, label ids]."""
    if not want_argmin:
        return [np.empty(n, np.float32)]
    return [np.empty(n, np.float32), np.empty(n, bool),
            np.empty(n, np.int32), np.empty(n, np.int32),
            np.empty(n, np.int32)]
