"""Synthetic benchmark map generators.

The paper evaluates on MovingAI game benchmarks (DAO/DA/BG/SC), which are not
redistributable offline.  These generators produce polygonal scenes with the
same qualitative structure (rooms/corridors, convex clutter, maze walls) at
three sizes so every paper table has a stand-in suite.  All generators are
deterministic in the seed.
"""

from __future__ import annotations

import numpy as np

from .geometry import Scene


def _rect(x0, y0, x1, y1):
    return np.array([[x0, y0], [x1, y0], [x1, y1], [x0, y1]], dtype=np.float64)


def _overlaps(r, placed, margin):
    x0, y0, x1, y1 = r
    for (a0, b0, a1, b1) in placed:
        if x0 - margin < a1 and x1 + margin > a0 and y0 - margin < b1 and y1 + margin > b0:
            return True
    return False


def rooms_map(seed: int = 0, width: float = 100.0, height: float = 100.0,
              n_rooms: int = 14, min_side: float = 5.0, max_side: float = 22.0
              ) -> Scene:
    """Axis-aligned rectangular obstacles ('rooms/buildings')."""
    rng = np.random.default_rng(seed)
    placed = []
    margin = 2.0
    tries = 0
    while len(placed) < n_rooms and tries < 4000:
        tries += 1
        w = rng.uniform(min_side, max_side)
        h = rng.uniform(min_side, max_side)
        x0 = rng.uniform(1.0, width - w - 1.0)
        y0 = rng.uniform(1.0, height - h - 1.0)
        r = (x0, y0, x0 + w, y0 + h)
        if not _overlaps(r, placed, margin):
            placed.append(r)
    polys = [_rect(*r) for r in placed]
    return Scene.build(polys, width, height)


def scatter_map(seed: int = 0, width: float = 100.0, height: float = 100.0,
                n_obstacles: int = 16, radius: float = 7.0, kmax: int = 8
                ) -> Scene:
    """Random convex polygons (convex hulls of point clouds) — open terrain."""
    rng = np.random.default_rng(seed)
    from scipy.spatial import ConvexHull

    polys = []
    placed = []
    margin = 2.0
    tries = 0
    while len(polys) < n_obstacles and tries < 4000:
        tries += 1
        c = rng.uniform([radius + 1, radius + 1],
                        [width - radius - 1, height - radius - 1])
        r = rng.uniform(0.35 * radius, radius)
        bbox = (c[0] - r, c[1] - r, c[0] + r, c[1] + r)
        if _overlaps(bbox, placed, margin):
            continue
        k = rng.integers(4, kmax + 1)
        ang = np.sort(rng.uniform(0, 2 * np.pi, size=k))
        rad = rng.uniform(0.4 * r, r, size=k)
        pts = c + np.stack([rad * np.cos(ang), rad * np.sin(ang)], axis=1)
        try:
            hull = ConvexHull(pts)
        except Exception:
            continue
        poly = pts[hull.vertices]
        if len(poly) >= 3:
            polys.append(poly)
            placed.append(bbox)
    return Scene.build(polys, width, height)


def maze_map(seed: int = 0, width: float = 100.0, height: float = 100.0,
             n_walls: int = 12, wall_len: float = 30.0, thickness: float = 2.0
             ) -> Scene:
    """Thin axis-aligned wall segments — corridor/maze structure."""
    rng = np.random.default_rng(seed)
    placed = []
    margin = 3.0
    tries = 0
    while len(placed) < n_walls and tries < 4000:
        tries += 1
        horizontal = rng.random() < 0.5
        L = rng.uniform(0.5 * wall_len, wall_len)
        if horizontal:
            x0 = rng.uniform(1.0, width - L - 1.0)
            y0 = rng.uniform(1.0, height - thickness - 1.0)
            r = (x0, y0, x0 + L, y0 + thickness)
        else:
            x0 = rng.uniform(1.0, width - thickness - 1.0)
            y0 = rng.uniform(1.0, height - L - 1.0)
            r = (x0, y0, x0 + thickness, y0 + L)
        if not _overlaps(r, placed, margin):
            placed.append(r)
    polys = [_rect(*q) for q in placed]
    return Scene.build(polys, width, height)


SUITES = {
    # name -> (generator, kwargs) — S/M/L roughly track DA / DAO-BG / SC scale
    "rooms-S": (rooms_map, dict(n_rooms=8, width=60.0, height=60.0)),
    "rooms-M": (rooms_map, dict(n_rooms=14)),
    "rooms-L": (rooms_map, dict(n_rooms=34, width=180.0, height=180.0)),
    "scatter-S": (scatter_map, dict(n_obstacles=8, width=60.0, height=60.0)),
    "scatter-M": (scatter_map, dict(n_obstacles=16)),
    "scatter-L": (scatter_map, dict(n_obstacles=40, width=180.0, height=180.0)),
    "maze-S": (maze_map, dict(n_walls=7, width=60.0, height=60.0)),
    "maze-M": (maze_map, dict(n_walls=12)),
    "maze-L": (maze_map, dict(n_walls=30, width=180.0, height=180.0)),
}


def make_map(name: str, seed: int = 0) -> Scene:
    gen, kw = SUITES[name]
    return gen(seed=seed, **kw)
