"""EHL* compression phase — Algorithm 1 of the paper, faithful.

Greedy region merging under a byte budget:

* every cell starts as its own region with score ``s(c)`` (uniform 1, or
  workload-aware ``1 + w_c``),
* a min-heap keyed on score pops the cheapest region ``e``,
* ``adjacentRegionSelection`` picks the neighbouring region with the highest
  Jaccard similarity of *hub sets* (Eq. 4), or the blended criterion
  ``(1-alpha)*Jaccard + alpha/s(r')`` when a workload is supplied (Eq. 5,
  alpha = 0.2 per the paper),
* via-labels are merged by set union (identical copies collapse — the whole
  point), scores add, the mapper re-targets the absorbed cells,
* loop until ``label_memory() <= budget`` or one region remains (the paper's
  "budget unreachable" halt).

The loop is host-side numpy on purpose: it is the paper's *offline* phase and
inherently sequential (heap); the online phase is what runs on TPU.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .grid import EHLIndex, Region


@dataclasses.dataclass
class CompressionStats:
    initial_bytes: int
    final_bytes: int
    budget: int
    merges: int
    regions: int
    hit_single_region: bool
    device_bytes: int | None = None   # set by compress_to_device_budget


def jaccard(a: np.ndarray, b: np.ndarray) -> float:
    """Jaccard similarity of two sorted int arrays (hub sets, Eq. 4)."""
    if a.size == 0 and b.size == 0:
        return 1.0   # merging two empty regions is free
    inter = np.intersect1d(a, b, assume_unique=True).size
    union = a.size + b.size - inter
    return inter / union


def adjacent_regions(index: EHLIndex, e: Region) -> list:
    """Live regions sharing a grid boundary with e (via the mapper)."""
    seen = {e.rid}
    out = []
    for ci in e.cells:
        for nb in index.cell_neighbors(ci):
            rid = int(index.mapper[nb])
            if rid not in seen:
                seen.add(rid)
                out.append(index.regions[rid])
    return out


def select_merge_target(e: Region, candidates: list,
                        alpha: float = 0.0) -> Region | None:
    """Eq. 4 (alpha=0) / Eq. 5 (alpha>0) adjacent-region selection."""
    best, best_val = None, -np.inf
    for r in candidates:
        sim = jaccard(e.hubs, r.hubs)
        val = sim if alpha == 0.0 else (1 - alpha) * sim + alpha / r.score
        if val > best_val:
            best, best_val = r, val
    return best


def merge_regions(index: EHLIndex, e: Region, r: Region) -> int:
    """Merge r into e (paper steps 1-3). Returns bytes saved."""
    from .grid import LABEL_BYTES

    before = e.n_labels + r.n_labels
    e.keys = np.union1d(e.keys, r.keys)
    e.hubs = np.union1d(e.hubs, r.hubs)
    e.cells.extend(r.cells)
    e.score += r.score
    e.version += 1
    e.packed = None
    index.mapper[np.asarray(r.cells, dtype=np.int64)] = e.rid
    del index.regions[r.rid]
    return LABEL_BYTES * (before - e.n_labels)


def rescore_regions(index: EHLIndex, cell_scores: np.ndarray) -> None:
    """``initializeScores`` over the *current* region set.

    Region score = sum of its member-cell scores, so re-scoring an already
    merged index with the cell scores it was merged under is a no-op — which
    is what lets :func:`compress_incremental` re-enter the loop with a fresh
    workload without resetting the merge state.
    """
    for r in index.regions.values():
        r.score = float(sum(cell_scores[c] for c in r.cells))


def compress(index: EHLIndex, budget_bytes: int,
             cell_scores: np.ndarray | None = None,
             alpha: float = 0.0,
             verbose: bool = False) -> CompressionStats:
    """Algorithm 1.  Mutates ``index`` in place; returns statistics.

    cell_scores: optional [C] array of initial per-cell scores
    (``initializeScores``); defaults to all-ones.  Workload-aware callers pass
    ``1 + w_c`` and ``alpha=0.2``.

    The loop itself never assumes singleton start regions, so this *is* the
    incremental form — :func:`compress_incremental` is the explicitly-named
    entry point the adaptive planner uses to resume a partially merged index
    under a new budget / workload.
    """
    initial = index.label_memory()
    if cell_scores is not None:
        rescore_regions(index, cell_scores)
    heap = [(r.score, r.rid, r.version) for r in index.regions.values()]
    heapq.heapify(heap)

    merges = 0
    mem = initial
    hit_single = False
    while mem > budget_bytes:
        if len(index.regions) <= 1:
            hit_single = True
            break
        score, rid, version = heapq.heappop(heap)
        e = index.regions.get(rid)
        if e is None or e.version != version:
            continue                         # stale heap entry
        cands = adjacent_regions(index, e)
        if not cands:                        # only possible when e is alone
            hit_single = True
            break
        r = select_merge_target(e, cands, alpha=alpha)
        mem -= merge_regions(index, e, r)
        heapq.heappush(heap, (e.score, e.rid, e.version))
        merges += 1
        if verbose and merges % 500 == 0:
            print(f"  merge {merges}: {mem / 1e6:.2f} MB, "
                  f"{len(index.regions)} regions")
    return CompressionStats(initial_bytes=initial, final_bytes=mem,
                            budget=budget_bytes, merges=merges,
                            regions=len(index.regions),
                            hit_single_region=hit_single)


def compress_to_fraction(index: EHLIndex, fraction: float, **kw
                         ) -> CompressionStats:
    """EHL*-x convenience: budget = x% of the index's current label memory."""
    return compress(index, int(index.label_memory() * fraction), **kw)


def compress_incremental(index: EHLIndex, budget_bytes: int,
                         cell_scores: np.ndarray | None = None,
                         alpha: float = 0.2,
                         verbose: bool = False) -> CompressionStats:
    """Resume Algorithm 1 from the index's **current** region set.

    The adaptive-serving entry point: instead of rebuilding from singleton
    cells (``build_ehl`` + :func:`compress`), re-score the live regions with
    a freshly recorded workload and keep merging until the — possibly
    smaller — budget holds again.  Already under budget -> zero merges, a
    cheap no-op.  Merging is correctness-preserving regardless of scores
    (label sets only ever grow per cell), so a resumed index answers every
    query identically to a fresh one at the same region partition.

    Merges cannot be undone here; when the planner decides newly hot cells
    need *finer* regions it restores the pre-merge snapshot
    (:meth:`EHLIndex.snapshot_regions`) and re-enters this same loop.
    """
    return compress(index, budget_bytes, cell_scores=cell_scores,
                    alpha=alpha, verbose=verbose)


def compress_to_device_budget(index: EHLIndex, device_budget_bytes: int,
                              cell_scores: np.ndarray | None = None,
                              alpha: float = 0.0, lane: int = 128,
                              max_rounds: int = 16,
                              verbose: bool = False,
                              layout=None) -> CompressionStats:
    """Merge until the packed *bucketed artifact* fits ``device_budget_bytes``.

    Algorithm 1's budget constrains host label memory; what serving actually
    pays is ``BucketedIndex.device_bytes()`` — labels plus bucket padding,
    mapper, indirection and edge tensors.  Outer loop: measure the analytic
    device footprint (``bucketed_device_bytes``, no device allocation),
    derive a proportional label-byte target, resume the incremental merge,
    repeat until the artifact fits or one region remains.

    ``layout``: the :class:`~repro.core.packed.SlabLayout` the artifact will
    be packed with (default f32).  A quantized layout packs ~3x more labels
    into the same budget, so the same device budget admits a much finer
    region partition — the dtype must be decided *before* merging, not after.
    """
    from .packed import LAYOUT_F32, bucketed_device_bytes

    if layout is None:
        layout = LAYOUT_F32
    initial = index.label_memory()
    merges = 0
    hit_single = False
    if cell_scores is not None:
        rescore_regions(index, cell_scores)
    for _ in range(max_rounds):
        dev = bucketed_device_bytes(index, lane, layout=layout)
        if dev <= device_budget_bytes or len(index.regions) <= 1:
            break
        # labels shrink, fixed overhead (mapper/edges) doesn't: aim the label
        # budget proportionally below the overshoot, with a 5% safety margin
        ratio = min(0.95 * device_budget_bytes / dev, 0.95)
        target = int(index.label_memory() * ratio)
        st = compress(index, target, alpha=alpha, verbose=verbose)
        merges += st.merges
        if st.hit_single_region:
            hit_single = True
            break
    return CompressionStats(
        initial_bytes=initial, final_bytes=index.label_memory(),
        budget=device_budget_bytes, merges=merges,
        regions=len(index.regions), hit_single_region=hit_single,
        device_bytes=bucketed_device_bytes(index, lane, layout=layout))
