"""EHL* compression phase — Algorithm 1 of the paper, faithful.

Greedy region merging under a byte budget:

* every cell starts as its own region with score ``s(c)`` (uniform 1, or
  workload-aware ``1 + w_c``),
* a min-heap keyed on score pops the cheapest region ``e``,
* ``adjacentRegionSelection`` picks the neighbouring region with the highest
  Jaccard similarity of *hub sets* (Eq. 4), or the blended criterion
  ``(1-alpha)*Jaccard + alpha/s(r')`` when a workload is supplied (Eq. 5,
  alpha = 0.2 per the paper),
* via-labels are merged by set union (identical copies collapse — the whole
  point), scores add, the mapper re-targets the absorbed cells,
* loop until ``label_memory() <= budget`` or one region remains (the paper's
  "budget unreachable" halt).

The loop is host-side numpy on purpose: it is the paper's *offline* phase and
inherently sequential (heap); the online phase is what runs on TPU.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .grid import EHLIndex, Region


@dataclasses.dataclass
class CompressionStats:
    initial_bytes: int
    final_bytes: int
    budget: int
    merges: int
    regions: int
    hit_single_region: bool


def jaccard(a: np.ndarray, b: np.ndarray) -> float:
    """Jaccard similarity of two sorted int arrays (hub sets, Eq. 4)."""
    if a.size == 0 and b.size == 0:
        return 1.0   # merging two empty regions is free
    inter = np.intersect1d(a, b, assume_unique=True).size
    union = a.size + b.size - inter
    return inter / union


def adjacent_regions(index: EHLIndex, e: Region) -> list:
    """Live regions sharing a grid boundary with e (via the mapper)."""
    seen = {e.rid}
    out = []
    for ci in e.cells:
        for nb in index.cell_neighbors(ci):
            rid = int(index.mapper[nb])
            if rid not in seen:
                seen.add(rid)
                out.append(index.regions[rid])
    return out


def select_merge_target(e: Region, candidates: list,
                        alpha: float = 0.0) -> Region | None:
    """Eq. 4 (alpha=0) / Eq. 5 (alpha>0) adjacent-region selection."""
    best, best_val = None, -np.inf
    for r in candidates:
        sim = jaccard(e.hubs, r.hubs)
        val = sim if alpha == 0.0 else (1 - alpha) * sim + alpha / r.score
        if val > best_val:
            best, best_val = r, val
    return best


def merge_regions(index: EHLIndex, e: Region, r: Region) -> int:
    """Merge r into e (paper steps 1-3). Returns bytes saved."""
    from .grid import LABEL_BYTES

    before = e.n_labels + r.n_labels
    e.keys = np.union1d(e.keys, r.keys)
    e.hubs = np.union1d(e.hubs, r.hubs)
    e.cells.extend(r.cells)
    e.score += r.score
    e.version += 1
    e.packed = None
    index.mapper[np.asarray(r.cells, dtype=np.int64)] = e.rid
    del index.regions[r.rid]
    return LABEL_BYTES * (before - e.n_labels)


def compress(index: EHLIndex, budget_bytes: int,
             cell_scores: np.ndarray | None = None,
             alpha: float = 0.0,
             verbose: bool = False) -> CompressionStats:
    """Algorithm 1.  Mutates ``index`` in place; returns statistics.

    cell_scores: optional [C] array of initial per-cell scores
    (``initializeScores``); defaults to all-ones.  Workload-aware callers pass
    ``1 + w_c`` and ``alpha=0.2``.
    """
    initial = index.label_memory()
    if cell_scores is not None:
        for r in index.regions.values():
            r.score = float(sum(cell_scores[c] for c in r.cells))
    heap = [(r.score, r.rid, r.version) for r in index.regions.values()]
    heapq.heapify(heap)

    merges = 0
    mem = initial
    hit_single = False
    while mem > budget_bytes:
        if len(index.regions) <= 1:
            hit_single = True
            break
        score, rid, version = heapq.heappop(heap)
        e = index.regions.get(rid)
        if e is None or e.version != version:
            continue                         # stale heap entry
        cands = adjacent_regions(index, e)
        if not cands:                        # only possible when e is alone
            hit_single = True
            break
        r = select_merge_target(e, cands, alpha=alpha)
        mem -= merge_regions(index, e, r)
        heapq.heappush(heap, (e.score, e.rid, e.version))
        merges += 1
        if verbose and merges % 500 == 0:
            print(f"  merge {merges}: {mem / 1e6:.2f} MB, "
                  f"{len(index.regions)} regions")
    return CompressionStats(initial_bytes=initial, final_bytes=mem,
                            budget=budget_bytes, merges=merges,
                            regions=len(index.regions),
                            hit_single_region=hit_single)


def compress_to_fraction(index: EHLIndex, fraction: float, **kw
                         ) -> CompressionStats:
    """EHL*-x convenience: budget = x% of the index's current label memory."""
    return compress(index, int(index.label_memory() * fraction), **kw)
