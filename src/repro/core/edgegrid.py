"""Spatially-bucketed obstacle edges: the O(L·E) -> O(L·E_local) subsystem.

The query-phase visibility predicate tests every candidate segment against
every obstacle edge (DESIGN.md §3) — O(L·E) per query, dominant on
edge-heavy maps.  :class:`EdgeGrid` rasterizes the packed edge tensors into
a uniform cell grid (ELL layout: per-cell edge-id lists, padded with a
degenerate *sentinel* edge id), and the query side walks only the cells a
segment passes through, gathering per-segment edge tiles for the same
VMEM-resident OR-reduction (``kernels.segvis_tiles`` /
``ref.segvis_tiles_ref``).  See DESIGN.md §10.

Correctness is a *superset* argument, so grid pruning is bitwise-identical
to the dense predicate by construction:

* every edge is registered in every cell its bounding box overlaps (host
  float64, exact);
* the walk visits every cell the segment touches, dilated by ``eps`` (a
  1e-3 fraction of a cell) so float32 clipping arithmetic on device can
  never round a visited cell away;
* any edge that blocks a segment intersects it, the intersection point
  lies in a cell both registered for the edge and visited by the walk, so
  the edge id is always gathered; every gathered edge evaluates the exact
  same per-(segment, edge) predicate as the dense path, and extra gathered
  edges contribute ``False`` to the OR.

The walk is a dominant-axis column scan in fixed shapes: at most
``max(gnx, gny)`` columns, at most 3 rows per column (cells are square and
the minor-axis slope is <= 1), so every segment visits <= ``3*max(gnx,gny)``
cell slots — long map-crossing segments and degenerate (point, axis-aligned,
cell-boundary) segments included, with no data-dependent shapes anywhere.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EdgeGrid:
    """Uniform-cell edge buckets over the packed edge tensors.

    ``cell_ids[c]`` lists the edge ids whose bounding box overlaps cell
    ``c`` (row-major, ``iy * gnx + ix``), padded to the ELL width ``M``
    with ``sentinel`` — the id of a degenerate (a == b == c) slot in the
    packed edge tensors, which the §5 predicate can never block on.  Row
    ``gnx * gny`` is the all-sentinel row that out-of-walk cell slots
    resolve to.
    """

    cell_ids: jnp.ndarray       # [C+1, M] int32 edge ids, sentinel padded
    cell_len: jnp.ndarray       # [C+1] int32 real ids per cell (stats)
    # static metadata
    gnx: int
    gny: int
    gcell: float                # exactly representable in float32
    sentinel: int               # padding edge id (degenerate packed slot)
    eps: float                  # walk dilation, world units

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.cell_ids, self.cell_len)
        aux = (self.gnx, self.gny, self.gcell, self.sentinel, self.eps)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    # -- properties ----------------------------------------------------------
    @property
    def num_cells(self) -> int:
        return self.gnx * self.gny

    @property
    def ell_width(self) -> int:
        return self.cell_ids.shape[1]

    @property
    def walk_slots(self) -> int:
        """Cell slots per segment walk (3 rows x max(gnx, gny) columns)."""
        return 3 * max(self.gnx, self.gny)

    @property
    def tile_slots(self) -> int:
        """Edge slots gathered per segment — the padded per-segment cost."""
        return self.walk_slots * self.ell_width

    def device_bytes(self) -> int:
        return int(np.prod(self.cell_ids.shape) * 4
                   + np.prod(self.cell_len.shape) * 4)

    # ------------------------------------------------------------------ walk
    def visited_cells(self, p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
        """[N, walk_slots] cell ids each segment touches (pad = num_cells).

        Dominant-axis column walk: for each grid column the segment's
        bounding box overlaps (dilated by ``eps``), the segment is clipped
        to the column's slab and the minor-axis interval (again dilated)
        yields at most 3 rows.  Every cell containing any point of the
        segment — including points landing exactly on cell boundaries —
        appears in the output; slots beyond the segment's span resolve to
        the empty sentinel row.
        """
        g = jnp.float32(self.gcell)
        eps = jnp.float32(self.eps)
        gnx, gny = self.gnx, self.gny
        KA = max(gnx, gny)
        px, py = p[:, 0], p[:, 1]
        qx, qy = q[:, 0], q[:, 1]
        dx = qx - px
        dy = qy - py
        swap = jnp.abs(dy) > jnp.abs(dx)        # dominant axis = y
        u0 = jnp.where(swap, py, px)
        u1 = jnp.where(swap, qy, qx)
        v0 = jnp.where(swap, px, py)
        v1 = jnp.where(swap, qx, qy)
        du = u1 - u0
        dv = v1 - v0
        Gu = jnp.where(swap, gny, gnx)
        Gv = jnp.where(swap, gnx, gny)
        ulo = jnp.minimum(u0, u1)
        uhi = jnp.maximum(u0, u1)
        col0 = jnp.clip(jnp.floor((ulo - eps) / g).astype(jnp.int32),
                        0, Gu - 1)
        col1 = jnp.clip(jnp.floor((uhi + eps) / g).astype(jnp.int32),
                        0, Gu - 1)
        k = jnp.arange(KA, dtype=jnp.int32)[None, :]
        col = col0[:, None] + k                              # [N, KA]
        valid_col = col <= col1[:, None]
        # clip to the column's (dilated) u-slab; degenerate du -> whole seg
        slab_lo = col.astype(jnp.float32) * g - eps
        slab_hi = (col + 1).astype(jnp.float32) * g + eps
        degen = (du == 0)[:, None]
        safe_du = jnp.where(du == 0, 1.0, du)[:, None]
        t0 = (slab_lo - u0[:, None]) / safe_du
        t1 = (slab_hi - u0[:, None]) / safe_du
        tlo = jnp.where(degen, 0.0, jnp.clip(jnp.minimum(t0, t1), 0.0, 1.0))
        thi = jnp.where(degen, 1.0, jnp.clip(jnp.maximum(t0, t1), 0.0, 1.0))
        va = v0[:, None] + tlo * dv[:, None]
        vb = v0[:, None] + thi * dv[:, None]
        vlo = jnp.minimum(va, vb) - eps
        vhi = jnp.maximum(va, vb) + eps
        r0 = jnp.clip(jnp.floor(vlo / g).astype(jnp.int32),
                      0, Gv[:, None] - 1)
        r1 = jnp.clip(jnp.floor(vhi / g).astype(jnp.int32),
                      0, Gv[:, None] - 1)
        r = r0[:, :, None] + jnp.arange(3, dtype=jnp.int32)[None, None, :]
        valid = valid_col[:, :, None] & (r <= r1[:, :, None])
        sw = swap[:, None, None]
        ix = jnp.where(sw, r, col[:, :, None])
        iy = jnp.where(sw, col[:, :, None], r)
        cell = jnp.where(valid, iy * gnx + ix, gnx * gny)
        return cell.reshape(p.shape[0], KA * 3)

    # ---------------------------------------------------------------- stats
    def edges_touched(self, p, q) -> np.ndarray:
        """[N] real edge slots each segment's walk gathers (bench metric).

        Dense visibility tests every segment against every edge; this is
        the grid path's actual predicate workload (duplicate registrations
        counted — they are evaluated).
        """
        cells = self.visited_cells(jnp.asarray(p, jnp.float32),
                                   jnp.asarray(q, jnp.float32))
        return np.asarray(self.cell_len[cells].sum(axis=1))


# ---------------------------------------------------------------------------
# host-side construction
# ---------------------------------------------------------------------------

def plan_grid_shape(num_real: int, width: float, height: float,
                    target_cells: int | None = None
                    ) -> tuple[int, int, float]:
    """(gnx, gny, gcell) for ``num_real`` edges over a width x height map.

    Resolution targets ~O(sqrt(E)) cells per axis so mean occupancy stays
    O(1); ``gcell`` is snapped to its float32 value so the host
    rasterization and the device walk divide by the *same* number.
    Deterministic — the analytic byte accounting in ``core.packed`` calls
    this too.
    """
    if target_cells is None:
        target_cells = int(np.clip(
            1 << int(np.ceil(np.log2(max(8.0, np.sqrt(2.0 * max(num_real,
                                                                1)))))),
            8, 64))
    side = max(float(width), float(height))
    gcell = float(np.float32(side / target_cells))
    gnx = max(1, int(np.ceil(width / gcell)))
    gny = max(1, int(np.ceil(height / gcell)))
    return gnx, gny, gcell


def _cell_lists(ea: np.ndarray, eb: np.ndarray, num_real: int,
                gnx: int, gny: int, gcell: float) -> list:
    """Per-cell edge-id lists from exact float64 bounding boxes."""
    lists: list[list[int]] = [[] for _ in range(gnx * gny)]
    a = np.asarray(ea[:num_real], dtype=np.float64)
    b = np.asarray(eb[:num_real], dtype=np.float64)
    if num_real == 0:
        return lists
    x0 = np.clip(np.floor(np.minimum(a[:, 0], b[:, 0]) / gcell), 0,
                 gnx - 1).astype(np.int64)
    x1 = np.clip(np.floor(np.maximum(a[:, 0], b[:, 0]) / gcell), 0,
                 gnx - 1).astype(np.int64)
    y0 = np.clip(np.floor(np.minimum(a[:, 1], b[:, 1]) / gcell), 0,
                 gny - 1).astype(np.int64)
    y1 = np.clip(np.floor(np.maximum(a[:, 1], b[:, 1]) / gcell), 0,
                 gny - 1).astype(np.int64)
    for e in range(num_real):
        for iy in range(y0[e], y1[e] + 1):
            base = iy * gnx
            for ix in range(x0[e], x1[e] + 1):
                lists[base + ix].append(e)
    return lists


def plan_grid(ea: np.ndarray, eb: np.ndarray, num_real: int,
              width: float, height: float,
              target_cells: int | None = None) -> tuple[int, int, float, int]:
    """Host-only grid plan ``(gnx, gny, gcell, ell_width)`` — no device
    arrays, so the analytic byte estimators (called repeatedly inside
    compression budget searches) can mirror :func:`build_edge_grid`'s
    shape and the packers' attach policy without allocating anything."""
    gnx, gny, gcell = plan_grid_shape(num_real, width, height, target_cells)
    lists = _cell_lists(ea, eb, num_real, gnx, gny, gcell)
    M = _round_up(max([len(l) for l in lists], default=0) or 1, 4)
    return gnx, gny, gcell, M


def ell_bytes(gnx: int, gny: int, ell_width: int) -> int:
    """``EdgeGrid.device_bytes()`` of a planned grid: [C+1, M] ids + [C+1]
    lengths, int32.  Single definition shared by the analytic estimators."""
    C = gnx * gny
    return (C + 1) * ell_width * 4 + (C + 1) * 4


def plan_grid_bytes(ea: np.ndarray, eb: np.ndarray, num_real: int,
                    width: float, height: float,
                    target_cells: int | None = None) -> int:
    """Exact ``EdgeGrid.device_bytes()`` without materializing device arrays.

    ``ea``/``eb`` (the packed edge tensors) size the ELL width exactly —
    one host rasterization pass, no device allocation.
    """
    gnx, gny, _, M = plan_grid(ea, eb, num_real, width, height, target_cells)
    return ell_bytes(gnx, gny, M)


def build_edge_grid(ea: np.ndarray, eb: np.ndarray, num_real: int,
                    width: float, height: float, sentinel: int,
                    target_cells: int | None = None) -> EdgeGrid:
    """Rasterize packed edge tensors into an :class:`EdgeGrid`.

    ``ea``/``eb`` are the *packed* [Ep, 2] tensors (real edges first,
    degenerate padding after); ``sentinel`` is the id of a degenerate
    padding slot — asserted here, because every unused ELL slot must be
    provably non-blocking for every query segment.
    """
    ea = np.asarray(ea)
    eb = np.asarray(eb)
    if not (0 <= sentinel < ea.shape[0]):
        raise ValueError(f"sentinel id {sentinel} outside packed edges "
                         f"[0, {ea.shape[0]})")
    if not np.array_equal(ea[sentinel], eb[sentinel]):
        raise ValueError("sentinel edge must be degenerate (a == b) so "
                         "padding slots can never block")
    gnx, gny, gcell = plan_grid_shape(num_real, width, height, target_cells)
    lists = _cell_lists(ea, eb, num_real, gnx, gny, gcell)
    C = gnx * gny
    M = _round_up(max([len(l) for l in lists], default=0) or 1, 4)
    ids = np.full((C + 1, M), sentinel, dtype=np.int32)
    lens = np.zeros(C + 1, dtype=np.int32)
    for c, l in enumerate(lists):
        ids[c, :len(l)] = l
        lens[c] = len(l)
    return EdgeGrid(cell_ids=jnp.asarray(ids), cell_len=jnp.asarray(lens),
                    gnx=gnx, gny=gny, gcell=gcell, sentinel=int(sentinel),
                    eps=float(np.float32(1e-3 * gcell)))


# ---------------------------------------------------------------------------
# query side
# ---------------------------------------------------------------------------

def gather_edge_tiles(grid: EdgeGrid, ea: jnp.ndarray, eb: jnp.ndarray,
                      ec: jnp.ndarray, p: jnp.ndarray, q: jnp.ndarray):
    """Per-segment edge tiles: six [N, S] coordinate arrays.

    S = ``grid.tile_slots``; unused slots point at the degenerate sentinel
    and contribute nothing to the OR-reduction.
    """
    cells = grid.visited_cells(p, q)                    # [N, K]
    ids = grid.cell_ids[cells].reshape(p.shape[0], -1)  # [N, K*M]
    return (ea[ids, 0], ea[ids, 1], eb[ids, 0], eb[ids, 1],
            ec[ids, 0], ec[ids, 1])


def segvis_grid(p: jnp.ndarray, q: jnp.ndarray, ea: jnp.ndarray,
                eb: jnp.ndarray, ec: jnp.ndarray, grid: EdgeGrid,
                use_kernels: bool = False, chunk: int = 8192) -> jnp.ndarray:
    """[N] bool visibility through the edge grid (dense-path bitwise twin).

    Chunks the segment axis so the gathered [chunk, S] tiles bound peak
    memory regardless of batch size; shapes stay static inside jit (N is a
    trace-time constant).
    """
    from repro.kernels import ops
    fn = ops.segvis_tiles_kernel if use_kernels else ops.segvis_tiles_ref
    N = p.shape[0]
    if N <= chunk:
        return fn(p, q, *gather_edge_tiles(grid, ea, eb, ec, p, q))
    outs = []
    for lo in range(0, N, chunk):
        sl = slice(lo, min(N, lo + chunk))
        outs.append(fn(p[sl], q[sl],
                       *gather_edge_tiles(grid, ea, eb, ec, p[sl], q[sl])))
    return jnp.concatenate(outs)
