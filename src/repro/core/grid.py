"""EHL index: uniform grid overlay + per-cell via-labels.

Offline phase of the paper's EHL baseline:

* overlay a uniform grid (cell size = ``cell_size``; EHL-k uses ``k`` x the
  base size),
* for every convex vertex v compute its visibility polygon and mark every
  intersected cell (exact polygon/rect intersection, inflated by 1e-6 so
  sliver visibility errs toward inclusion — extra labels are always safe),
* copy the hub labels H(v) of every visible vertex into the cell as
  *via-labels* ``h : (v, d_vh)``.

A via-label is identified by the integer key ``h * V + v`` — the distance
``d_vh`` (and the next-hop used for path unwinding) is a function of (h, v)
and is re-attached when a region is *packed* for querying.  Regions (merged
cell groups, EHL* §Compression) keep two sorted int64 arrays: the label keys
and the distinct hub ids.

The device layouts built from this index (single slab vs width-bucketed
slabs, ``repro.core.packed``) and their padding trade-offs are described in
DESIGN.md §4; :meth:`EHLIndex.packed_label_counts` is the pack metadata the
bucketing decision is made from.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .geometry import Scene, visibility_polygon, vispoly_intersects_rects
from .hublabel import HubLabels, build_hub_labels
from .visgraph import VisGraph, build_visgraph

LABEL_BYTES = 16   # (hub id, via id, dist, next-hop) — mirrors EHL's C++ entry
MAPPER_BYTES = 4


@dataclasses.dataclass
class Region:
    rid: int
    cells: list                 # cell ids
    keys: np.ndarray            # sorted int64 label keys (h*V + v)
    hubs: np.ndarray            # sorted int64 distinct hub ids
    score: float = 1.0
    version: int = 0            # bumped on every merge (lazy heap deletion)
    packed: dict | None = None  # query-time cache, invalidated on merge

    @property
    def n_labels(self) -> int:
        return int(self.keys.size)


@dataclasses.dataclass
class EHLIndex:
    scene: Scene
    graph: VisGraph
    hl: HubLabels
    cell_size: float
    nx: int
    ny: int
    mapper: np.ndarray           # [C] cell -> region id
    regions: dict                # rid -> Region (live regions only)

    # ------------------------------------------------------------------ grid
    def cell_of_point(self, p) -> int:
        ix = min(int(p[0] / self.cell_size), self.nx - 1)
        iy = min(int(p[1] / self.cell_size), self.ny - 1)
        return iy * self.nx + ix

    def cell_rect(self, ci: int) -> np.ndarray:
        iy, ix = divmod(ci, self.nx)
        cs = self.cell_size
        return np.array([ix * cs, iy * cs,
                         min((ix + 1) * cs, self.scene.width),
                         min((iy + 1) * cs, self.scene.height)])

    def cell_neighbors(self, ci: int):
        iy, ix = divmod(ci, self.nx)
        if ix > 0:
            yield ci - 1
        if ix < self.nx - 1:
            yield ci + 1
        if iy > 0:
            yield ci - self.nx
        if iy < self.ny - 1:
            yield ci + self.nx

    # ---------------------------------------------------------------- memory
    def label_memory(self) -> int:
        """Bytes of via-label storage (the quantity the budget constrains)."""
        return LABEL_BYTES * sum(r.n_labels for r in self.regions.values())

    def total_memory(self) -> int:
        return self.label_memory() + MAPPER_BYTES * self.mapper.size

    def region_of_point(self, p) -> Region:
        return self.regions[int(self.mapper[self.cell_of_point(p)])]

    def packed_label_counts(self) -> np.ndarray:
        """Per live region (rid order): packed label count — the row widths
        the device layouts pad from (single global Lmax vs per-bucket)."""
        live = sorted(self.regions.keys())
        return np.array([self.regions[rid].n_labels for rid in live],
                        dtype=np.int64)

    # ------------------------------------------------------------- snapshot
    def snapshot_regions(self) -> dict:
        """Cheap copy of the merge state (mapper + regions) for later restore.

        ``keys``/``hubs`` arrays and ``packed`` caches are shared by
        reference — merges *replace* them (``np.union1d`` allocates, the
        cache is dropped), never mutate in place — so a snapshot costs O(R)
        small objects, not a deep copy of the label data.  The adaptive
        planner snapshots the freshly built singleton index once and
        restores it when a workload shift demands re-splitting regions that
        earlier merges coarsened (merges are irreversible in Algorithm 1).
        """
        return dict(
            mapper=self.mapper.copy(),
            regions={rid: (list(r.cells), r.keys, r.hubs, r.score,
                           r.version, r.packed)
                     for rid, r in self.regions.items()})

    def restore_regions(self, snap: dict) -> None:
        """Reset mapper + regions to a :meth:`snapshot_regions` state."""
        self.mapper = snap["mapper"].copy()
        self.regions = {
            rid: Region(rid=rid, cells=list(cells), keys=keys, hubs=hubs,
                        score=score, version=version, packed=packed)
            for rid, (cells, keys, hubs, score, version, packed)
            in snap["regions"].items()}

    # ---------------------------------------------------------------- pack
    def pack_region(self, r: Region) -> dict:
        """Attach distances / coords to a region's label keys (cached)."""
        if r.packed is not None:
            return r.packed
        V = self.graph.num_nodes
        hubs = (r.keys // V).astype(np.int64)
        vias = (r.keys % V).astype(np.int64)
        d = np.empty(len(r.keys), dtype=np.float64)
        for i, (h, v) in enumerate(zip(hubs, vias)):
            hs, ds, _ = self.hl.labels[v]
            k = np.searchsorted(hs, h)
            d[i] = ds[k]
        order = np.lexsort((vias, hubs))
        uniq_vias, via_inv = np.unique(vias[order], return_inverse=True)
        r.packed = dict(hubs=hubs[order], vias=vias[order], d=d[order],
                        uniq_vias=uniq_vias, via_inv=via_inv,
                        via_xy=self.graph.nodes[vias[order]] if len(vias)
                        else np.zeros((0, 2)))
        return r.packed


def build_ehl(scene: Scene, cell_size: float,
              graph: VisGraph | None = None,
              hl: HubLabels | None = None,
              verbose: bool = False) -> EHLIndex:
    """Construct the (uncompressed) EHL index — one region per grid cell."""
    graph = graph if graph is not None else build_visgraph(scene)
    hl = hl if hl is not None else build_hub_labels(graph)
    V = graph.num_nodes
    nx = max(1, int(np.ceil(scene.width / cell_size)))
    ny = max(1, int(np.ceil(scene.height / cell_size)))
    C = nx * ny

    xs = np.arange(nx) * cell_size
    ys = np.arange(ny) * cell_size
    gx, gy = np.meshgrid(xs, ys)                       # [ny,nx]
    rects = np.stack([gx.ravel(), gy.ravel(),
                      np.minimum(gx.ravel() + cell_size, scene.width),
                      np.minimum(gy.ravel() + cell_size, scene.height)],
                     axis=1)                           # [C,4]

    # per-vertex label keys h*V+v (precomputed once)
    vkeys = [hl.labels[v][0] * V + v for v in range(V)]

    cell_key_parts: list[list[np.ndarray]] = [[] for _ in range(C)]
    for v in range(V):
        vp = visibility_polygon(scene, graph.nodes[v])
        # candidate cells from the polygon bbox
        bb = (vp[:, 0].min(), vp[:, 1].min(), vp[:, 0].max(), vp[:, 1].max())
        ix0 = max(0, int(bb[0] / cell_size) - 1)
        iy0 = max(0, int(bb[1] / cell_size) - 1)
        ix1 = min(nx - 1, int(bb[2] / cell_size) + 1)
        iy1 = min(ny - 1, int(bb[3] / cell_size) + 1)
        cand = (np.arange(iy0, iy1 + 1)[:, None] * nx
                + np.arange(ix0, ix1 + 1)[None, :]).ravel()
        hit = vispoly_intersects_rects(vp, graph.nodes[v], rects[cand])
        for ci in cand[hit]:
            cell_key_parts[ci].append(vkeys[v])
        if verbose and v % 50 == 0:
            print(f"  visibility {v}/{V}")

    mapper = np.arange(C, dtype=np.int64)
    regions = {}
    for ci in range(C):
        if cell_key_parts[ci]:
            keys = np.unique(np.concatenate(cell_key_parts[ci]))
            hubs = np.unique(keys // V)
        else:
            keys = np.zeros(0, dtype=np.int64)
            hubs = np.zeros(0, dtype=np.int64)
        regions[ci] = Region(rid=ci, cells=[ci], keys=keys, hubs=hubs)
    return EHLIndex(scene=scene, graph=graph, hl=hl, cell_size=cell_size,
                    nx=nx, ny=ny, mapper=mapper, regions=regions)
