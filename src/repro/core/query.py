"""Online query phase — scalar reference engine (paper Eqs. 1-3).

This is the host-side oracle the JAX/Pallas batched engine is validated
against (see ``repro.core.packed`` and ``repro.kernels``).  It follows the
paper exactly:

1. if s and t are co-visible -> d = Edist(s, t);
2. otherwise locate regions via the mapper (O(1)), compute the minimal
   via-distance per hub (Eq. 2) with a query-time visibility check on each
   via vertex, and merge-join the two hub lists (Eq. 3);
3. the optimal path is unwound from the winning (via_s, hub, via_t) triple
   using the hub labels' next-hop pointers.
"""

from __future__ import annotations

import numpy as np

from .geometry import edist, visible_batch, visible_from_point
from .grid import EHLIndex


def _vdist_min(index: EHLIndex, p: np.ndarray, packed: dict
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-hub minimal via-distance for point p over a packed region.

    Returns (uniq_hubs [Hk], vdmin [Hk], argmin via vertex id [Hk]).
    """
    hubs = packed["hubs"]
    if hubs.size == 0:
        return (np.zeros(0, np.int64), np.zeros(0), np.zeros(0, np.int64))
    vis = visible_from_point(index.scene, p, index.graph.nodes[packed["uniq_vias"]])
    lab_vis = vis[packed["via_inv"]]
    vd = np.where(lab_vis,
                  edist(p[None], packed["via_xy"]) + packed["d"], np.inf)
    uniq_hubs, start = np.unique(hubs, return_index=True)
    vdmin = np.minimum.reduceat(vd, start)
    # argmin via id within each hub group
    arg = np.empty(len(uniq_hubs), dtype=np.int64)
    bounds = np.append(start, len(hubs))
    for k in range(len(uniq_hubs)):
        seg = slice(bounds[k], bounds[k + 1])
        arg[k] = packed["vias"][seg][np.argmin(vd[seg])]
    return uniq_hubs, vdmin, arg


def query_distance(index: EHLIndex, s, t) -> float:
    """Shortest obstacle-avoiding distance (inf if unreachable)."""
    d, _ = query(index, s, t, want_path=False)
    return d


def query(index: EHLIndex, s, t, want_path: bool = True
          ) -> tuple[float, list]:
    s = np.asarray(s, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    if visible_batch(index.scene, s[None], t[None])[0]:
        return float(edist(s, t)), [s, t]

    rs = index.region_of_point(s)
    rt = index.region_of_point(t)
    ps = index.pack_region(rs)
    pt = index.pack_region(rt)
    hs, vs, args_ = _vdist_min(index, s, ps)
    ht, vt, argt_ = _vdist_min(index, t, pt)

    # merge-join the two sorted unique-hub lists
    i = j = 0
    best = np.inf
    best_triple = None
    while i < len(hs) and j < len(ht):
        if hs[i] == ht[j]:
            tot = vs[i] + vt[j]
            if tot < best:
                best = tot
                best_triple = (int(args_[i]), int(hs[i]), int(argt_[j]))
            i += 1
            j += 1
        elif hs[i] < ht[j]:
            i += 1
        else:
            j += 1
    if not np.isfinite(best):
        return float("inf"), []
    if not want_path:
        return float(best), []
    return float(best), unwind_path(index, s, t, *best_triple)


def unwind_path(index: EHLIndex, s, t, via_s: int, hub: int, via_t: int
                ) -> list:
    """Reconstruct the optimal polyline from a winning (via_s, hub, via_t).

    Shared by the scalar oracle above and the batched argmin engines
    (``repro.core.packed.query_batch_argmin`` & the serving layer): the
    device side only identifies the winning label triple; the hub labels'
    next-hop pointers live host-side.
    """
    seq = index.hl.unwind(via_s, hub) + index.hl.unwind(via_t, hub)[::-1][1:]
    pts = [np.asarray(s, np.float64)] + \
        [index.graph.nodes[u] for u in seq] + [np.asarray(t, np.float64)]
    path = [pts[0]]
    for p in pts[1:]:
        if edist(path[-1], p) > 1e-12:
            path.append(p)
    return path


def path_length(path) -> float:
    if len(path) < 2:
        return 0.0 if path else float("inf")
    return float(sum(edist(path[k], path[k + 1]) for k in range(len(path) - 1)))
