"""Hub labeling (2-hop cover) on the visibility graph.

Pruned Landmark Labeling (Akiba et al. 2013) adapted to real-weighted graphs:
process vertices in importance order; for hub ``h`` run a pruned Dijkstra —
when a vertex ``u`` pops at distance ``d`` and the *current* labels already
certify ``dist(h,u) <= d``, prune the branch; otherwise record label
``(h, d, next_hop)`` where ``next_hop`` is u's neighbour toward ``h`` (for
path unwinding, as in the EHL paper).  The canonical ordering guarantees the
2-hop *coverage property* used by Eq.(1) of the paper.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .visgraph import VisGraph


@dataclasses.dataclass
class HubLabels:
    """Per-vertex sorted label arrays.

    labels[v] = (hubs [k] int64 ascending, dists [k] float64, nexthop [k] int64)
    ``nexthop`` is the neighbour of v that is next on the shortest path from v
    toward the hub (== v itself when v is the hub).
    """

    order: np.ndarray                 # importance order (hub rank -> vertex)
    labels: list

    def label_count(self) -> int:
        return sum(len(h) for (h, _, _) in self.labels)

    def avg_label_size(self) -> float:
        return self.label_count() / max(1, len(self.labels))

    def query(self, a: int, b: int) -> float:
        """Eq.(1): min over common hubs of d(a,h)+d(h,b)."""
        ha, da, _ = self.labels[a]
        hb, db, _ = self.labels[b]
        i = j = 0
        best = np.inf
        while i < len(ha) and j < len(hb):
            if ha[i] == hb[j]:
                s = da[i] + db[j]
                if s < best:
                    best = s
                i += 1
                j += 1
            elif ha[i] < hb[j]:
                i += 1
            else:
                j += 1
        return float(best)

    def unwind(self, v: int, hub: int) -> list[int]:
        """Vertex sequence from v to hub following next-hop pointers."""
        path = [v]
        cur = v
        guard = 0
        while cur != hub:
            hs, _, nh = self.labels[cur]
            k = np.searchsorted(hs, hub)
            if k >= len(hs) or hs[k] != hub:
                raise KeyError(f"hub {hub} not in labels of {cur}")
            cur = int(nh[k])
            path.append(cur)
            guard += 1
            if guard > len(self.labels) + 1:
                raise RuntimeError("next-hop cycle")
        return path


def build_hub_labels(g: VisGraph, order: np.ndarray | None = None) -> HubLabels:
    """Pruned landmark labeling; default order = degree desc (ties by id)."""
    V = g.num_nodes
    if order is None:
        deg = np.array([len(a) for a in g.adj_idx])
        order = np.lexsort((np.arange(V), -deg))
    rank = np.empty(V, dtype=np.int64)
    rank[order] = np.arange(V)

    tmp: list[list[tuple[int, float, int]]] = [[] for _ in range(V)]
    # fast pruning query: for each vertex keep dict hub->dist
    lab_dict: list[dict[int, float]] = [dict() for _ in range(V)]

    dist = np.full(V, np.inf)
    touched: list[int] = []
    for hub in order:
        hub = int(hub)
        hub_labs = lab_dict[hub]
        pq = [(0.0, hub, hub)]   # (dist, vertex, next_hop_toward_hub)
        dist[hub] = 0.0
        touched.append(hub)
        settled = set()
        while pq:
            d, u, nh = heapq.heappop(pq)
            if u in settled or d > dist[u] + 1e-12:
                continue
            settled.add(u)
            # prune: existing labels already cover (hub, u) at <= d
            labs_u = lab_dict[u]
            pruned = False
            if len(labs_u) < len(hub_labs):
                for h, dv in labs_u.items():
                    dh = hub_labs.get(h)
                    if dh is not None and dh + dv <= d + 1e-12:
                        pruned = True
                        break
            else:
                for h, dh in hub_labs.items():
                    dv = labs_u.get(h)
                    if dv is not None and dh + dv <= d + 1e-12:
                        pruned = True
                        break
            if pruned:
                continue
            tmp[u].append((hub, d, nh))
            labs_u[hub] = d
            for v, w in zip(g.adj_idx[u], g.adj_w[u]):
                if rank[v] <= rank[hub]:
                    continue   # only lower-importance vertices get labels
                nd = d + w
                if nd < dist[v] - 1e-12:
                    dist[v] = nd
                    touched.append(v)
                    # next hop from v toward hub is u
                    heapq.heappush(pq, (nd, v, u))
        for v in touched:
            dist[v] = np.inf
        touched.clear()

    labels = []
    for v in range(V):
        if tmp[v]:
            hs = np.array([h for h, _, _ in tmp[v]], dtype=np.int64)
            ds = np.array([d for _, d, _ in tmp[v]], dtype=np.float64)
            ns = np.array([n for _, _, n in tmp[v]], dtype=np.int64)
            srt = np.argsort(hs)
            labels.append((hs[srt], ds[srt], ns[srt]))
        else:
            labels.append((np.zeros(0, np.int64), np.zeros(0), np.zeros(0, np.int64)))
    return HubLabels(order=np.asarray(order), labels=labels)
