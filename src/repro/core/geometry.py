"""Geometry substrate for EHL*.

Euclidean plane with polygonal obstacles.  Everything here is exact-enough
float64 computational geometry executed host-side (offline phase); the online
phase consumes the flat edge tensors exported by :class:`Scene` (see
``repro.core.packed`` / ``repro.kernels``).

Conventions
-----------
* Obstacle polygons are simple, non-self-intersecting, stored CCW.
* Free space is the map rectangle minus open polygon interiors.  An agent may
  graze a polygon boundary (standard ESPP semantics).
* A *convex vertex* is a polygon corner whose interior angle is < 180 deg —
  the only points where optimal Euclidean paths bend.

Blocking convention (DESIGN.md §5): **touching != blocked, interior
penetration = blocked**.  A segment may slide along an obstacle edge, graze
a vertex tangentially, or end exactly on the boundary — none of that blocks
it.  It is blocked exactly when its open interior enters an obstacle's open
interior, *including* the degenerate entries: transversally through a
vertex, or from a point on an open edge heading strictly inside.  The host
oracle (:func:`visible_batch`, midpoint containment) realizes this
convention exactly in float64; :func:`segments_block_strict` is the same
convention written as the sign-rule predicate the device kernels implement
(``repro.kernels``), so the two backends agree on every degenerate class
and differ only by float32 rounding.  The sign rules detect boundary
*crossings*, so their precondition is one endpoint in free space — every
engine segment satisfies it (query points are free, vias are boundary
vertices); a fully-interior segment is the oracle's job alone.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

EPS = 1e-9          # absolute tolerance in map units
ANG_EPS = 1e-7      # angular jitter for visibility-polygon rays


# ---------------------------------------------------------------------------
# scene
# ---------------------------------------------------------------------------

def _ensure_ccw(poly: np.ndarray) -> np.ndarray:
    """Return polygon with positive (CCW) signed area."""
    x, y = poly[:, 0], poly[:, 1]
    area2 = np.sum(x * np.roll(y, -1) - np.roll(x, -1) * y)
    return poly if area2 > 0 else poly[::-1].copy()


@dataclasses.dataclass(frozen=True)
class Scene:
    """Immutable obstacle scene with precomputed flat edge/vertex tensors."""

    polygons: tuple          # tuple of [k,2] float64 arrays, CCW
    width: float
    height: float
    # derived, filled by `build`
    edges: np.ndarray        # [E,2,2] obstacle edges (a, b)
    edge_poly: np.ndarray    # [E] polygon id per edge
    vertices: np.ndarray     # [V,2] all polygon vertices
    vertex_poly: np.ndarray  # [V] polygon id per vertex
    convex_mask: np.ndarray  # [V] bool, True at convex corners
    edge_next: np.ndarray    # [E,2] vertex after b along the CCW boundary
    #   (through-vertex rule input; at a reflex b it is the sentinel
    #   2b - a, which makes the arm-straddle test fire for any segment
    #   through b that is not collinear with the incoming arm — correct,
    #   because every non-collinear direction enters a reflex interior)

    @staticmethod
    def build(polygons: Iterable[np.ndarray], width: float, height: float) -> "Scene":
        polys = tuple(_ensure_ccw(np.asarray(p, dtype=np.float64)) for p in polygons)
        edges, edge_poly, verts, vert_poly, convex, enext = [], [], [], [], [], []
        for pid, poly in enumerate(polys):
            n = len(poly)
            nxt = np.roll(poly, -1, axis=0)
            prv = np.roll(poly, 1, axis=0)
            edges.append(np.stack([poly, nxt], axis=1))
            edge_poly.append(np.full(n, pid))
            verts.append(poly)
            vert_poly.append(np.full(n, pid))
            e1 = poly - prv
            e2 = nxt - poly
            conv = e1[:, 0] * e2[:, 1] - e1[:, 1] * e2[:, 0] > EPS
            convex.append(conv)
            # per edge i: a=poly[i], b=poly[i+1], c=poly[i+2] when b is
            # convex, else the reflex sentinel 2b - a
            conv_b = np.roll(conv, -1)
            nxt2 = np.roll(poly, -2, axis=0)
            enext.append(np.where(conv_b[:, None], nxt2, 2 * nxt - poly))
        if polys:
            E = np.concatenate(edges)
            EP = np.concatenate(edge_poly)
            V = np.concatenate(verts)
            VP = np.concatenate(vert_poly)
            C = np.concatenate(convex)
            EN = np.concatenate(enext)
        else:
            E = np.zeros((0, 2, 2))
            EP = np.zeros((0,), dtype=np.int64)
            V = np.zeros((0, 2))
            VP = np.zeros((0,), dtype=np.int64)
            C = np.zeros((0,), dtype=bool)
            EN = np.zeros((0, 2))
        return Scene(polys, float(width), float(height), E, EP, V, VP, C, EN)

    @property
    def convex_vertices(self) -> np.ndarray:
        """[CV,2] coordinates of convex corners (the visibility-graph nodes)."""
        return self.vertices[self.convex_mask]

    def boundary_edges(self) -> np.ndarray:
        """[4,2,2] map-rectangle edges (used to terminate visibility rays)."""
        w, h = self.width, self.height
        c = np.array([[0.0, 0.0], [w, 0.0], [w, h], [0.0, h]])
        return np.stack([c, np.roll(c, -1, axis=0)], axis=1)


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

def _cross(o, a, b):
    return (a[..., 0] - o[..., 0]) * (b[..., 1] - o[..., 1]) - (
        a[..., 1] - o[..., 1]
    ) * (b[..., 0] - o[..., 0])


def points_strictly_inside(scene: Scene, pts: np.ndarray) -> np.ndarray:
    """[N] bool — point strictly inside ANY obstacle polygon (boundary = out).

    Even-odd crossing number computed per polygon, with an explicit
    on-boundary override.
    """
    pts = np.atleast_2d(np.asarray(pts, dtype=np.float64))
    n = len(pts)
    if scene.edges.shape[0] == 0 or n == 0:
        return np.zeros(n, dtype=bool)
    a = scene.edges[:, 0]  # [E,2]
    b = scene.edges[:, 1]
    px = pts[:, 0, None]   # [N,1]
    py = pts[:, 1, None]
    ax, ay = a[None, :, 0], a[None, :, 1]
    bx, by = b[None, :, 0], b[None, :, 1]

    # crossing test (half-open rule avoids double counting at shared vertices)
    cond = (ay > py) != (by > py)
    with np.errstate(divide="ignore", invalid="ignore"):
        xint = ax + (py - ay) * (bx - ax) / (by - ay)
    crosses = cond & (px < xint)

    # on-boundary: distance point-to-segment < EPS
    abx, aby = bx - ax, by - ay
    apx, apy = px - ax, py - ay
    denom = abx * abx + aby * aby
    t = np.clip((apx * abx + apy * aby) / np.maximum(denom, 1e-30), 0.0, 1.0)
    dx = apx - t * abx
    dy = apy - t * aby
    on_bnd = (dx * dx + dy * dy) < EPS * EPS

    npoly = len(scene.polygons)
    pid = scene.edge_poly
    # [N, P] odd-crossing-count parity per polygon
    onehot = (pid[:, None] == np.arange(npoly)[None]).astype(np.int64)  # [E,P]
    cross_cnt = crosses.astype(np.int64) @ onehot                        # [N,P]
    inside_any = (cross_cnt % 2 == 1).any(axis=1)
    return inside_any & ~on_bnd.any(axis=1)


def _segment_edge_params(p, q, a, b):
    """Intersection parameters t along segment p->q for edges (a,b).

    Returns [*, 3] array of t values in [0,1] (NaN where no intersection):
    slot 0 = proper/touching crossing, slots 1,2 = collinear-overlap ends.
    Shapes: p,q [N,2]; a,b [E,2] -> out [N,E,3].
    """
    p = p[:, None, :]
    q = q[:, None, :]
    a = a[None, :, :]
    b = b[None, :, :]
    r = q - p                     # [N,1,2]
    s = b - a                     # [1,E,2]
    denom = r[..., 0] * s[..., 1] - r[..., 1] * s[..., 0]      # [N,E]
    ap = a - p
    ap_x_s = ap[..., 0] * s[..., 1] - ap[..., 1] * s[..., 0]
    ap_x_r = ap[..., 0] * r[..., 1] - ap[..., 1] * r[..., 0]

    with np.errstate(divide="ignore", invalid="ignore"):
        t = ap_x_s / denom
        u = ap_x_r / denom
    parallel = np.abs(denom) < EPS
    hit = (~parallel) & (t >= -EPS) & (t <= 1 + EPS) & (u >= -EPS) & (u <= 1 + EPS)
    t0 = np.where(hit, np.clip(t, 0.0, 1.0), np.nan)

    # collinear overlap
    rr = (r * r).sum(-1)                                       # [N,1]
    collinear = parallel & (np.abs(ap_x_r) < EPS * np.sqrt(np.maximum(rr, 1e-30)))
    with np.errstate(divide="ignore", invalid="ignore"):
        ta = ((a - p) * r).sum(-1) / rr
        tb = ((b - p) * r).sum(-1) / rr
    lo = np.minimum(ta, tb)
    hi = np.maximum(ta, tb)
    ov = collinear & (hi >= -EPS) & (lo <= 1 + EPS)
    t1 = np.where(ov, np.clip(lo, 0.0, 1.0), np.nan)
    t2 = np.where(ov, np.clip(hi, 0.0, 1.0), np.nan)
    return np.stack([t0, t1, t2], axis=-1)                     # [N,E,3]


def visible(scene: Scene, p, q) -> bool:
    """Exact single-pair visibility (convenience wrapper)."""
    return visible_batch(scene, np.asarray(p)[None], np.asarray(q)[None])[0]


def visible_batch(scene: Scene, P: np.ndarray, Q: np.ndarray,
                  chunk: int = 512) -> np.ndarray:
    """[N] bool — open segment P[i]->Q[i] avoids all obstacle interiors.

    Method: collect every intersection parameter of the segment with any
    obstacle edge (crossings, touches, collinear overlaps), then test the
    midpoint of every consecutive parameter interval for strict containment
    in an obstacle.  Visible iff no midpoint is strictly inside.  This single
    rule subsumes proper crossings, tangencies, vertex grazing and
    fully-contained segments.
    """
    P = np.asarray(P, dtype=np.float64)
    Q = np.asarray(Q, dtype=np.float64)
    n = len(P)
    out = np.ones(n, dtype=bool)
    if scene.edges.shape[0] == 0 or n == 0:
        return out
    a = scene.edges[:, 0]
    b = scene.edges[:, 1]
    for lo in range(0, n, chunk):
        sl = slice(lo, min(lo + chunk, n))
        p, q = P[sl], Q[sl]
        ts = _segment_edge_params(p, q, a, b).reshape(len(p), -1)  # [n, 3E]
        ones = np.ones((len(p), 1))
        ts = np.concatenate([np.zeros_like(ones), ones, ts], axis=1)
        ts.sort(axis=1)  # NaNs go last
        mids_t = 0.5 * (ts[:, :-1] + ts[:, 1:])                    # [n, K]
        valid = np.isfinite(mids_t) & (ts[:, 1:] - ts[:, :-1] > EPS)
        ii, jj = np.nonzero(valid)
        if len(ii) == 0:
            continue
        mpts = p[ii] + mids_t[ii, jj, None] * (q[ii] - p[ii])
        inside = points_strictly_inside(scene, mpts)
        bad = np.zeros(len(p), dtype=bool)
        np.logical_or.at(bad, ii, inside)
        out[sl] = ~bad
    return out


def visible_from_point(scene: Scene, p: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """[M] bool — visibility of each target from a single point p."""
    P = np.broadcast_to(np.asarray(p, dtype=np.float64), (len(targets), 2))
    return visible_batch(scene, P, np.asarray(targets, dtype=np.float64))


# ---------------------------------------------------------------------------
# visibility polygon (angular sweep, star-shaped around the viewpoint)
# ---------------------------------------------------------------------------

def visibility_polygon(scene: Scene, v: np.ndarray) -> np.ndarray:
    """Star-shaped visibility polygon around viewpoint ``v``.

    Rays are cast at the angle of every scene vertex (obstacle + map corner)
    plus +-ANG_EPS jitter; each ray is clipped to the nearest obstacle / map
    boundary edge.  Returns [R,2] polygon vertices ordered by angle.
    """
    v = np.asarray(v, dtype=np.float64)
    edges = np.concatenate([scene.edges, scene.boundary_edges()], axis=0)
    pts = np.concatenate([scene.vertices,
                          scene.boundary_edges()[:, 0]], axis=0)
    rel = pts - v
    base = np.arctan2(rel[:, 1], rel[:, 0])
    angles = np.concatenate([base - ANG_EPS, base, base + ANG_EPS])
    angles = np.unique(angles)
    d = np.stack([np.cos(angles), np.sin(angles)], axis=1)      # [R,2]

    a = edges[:, 0][None]            # [1,E,2]
    b = edges[:, 1][None]
    s = b - a
    dr = d[:, None, :]               # [R,1,2]
    denom = dr[..., 0] * s[..., 1] - dr[..., 1] * s[..., 0]     # [R,E]
    av = a - v                        # [1,E,2]
    t = (av[..., 0] * s[..., 1] - av[..., 1] * s[..., 0])
    u = (av[..., 0] * dr[..., 1] - av[..., 1] * dr[..., 0])
    with np.errstate(divide="ignore", invalid="ignore"):
        t = t / denom
        u = u / denom
    ok = (np.abs(denom) > 1e-15) & (t > EPS) & (u >= -EPS) & (u <= 1 + EPS)
    t = np.where(ok, t, np.inf)
    tmin = t.min(axis=1)                                        # [R]
    tmin = np.where(np.isfinite(tmin), tmin, 0.0)
    return v[None] + tmin[:, None] * d                          # [R,2]


def _point_in_star(vispoly: np.ndarray, v: np.ndarray, pts: np.ndarray,
                   slack: float = 1e-7) -> np.ndarray:
    """[N] bool — points inside the star-shaped polygon around v.

    Uses the radial lookup: a point at angle theta is inside iff it is on
    the v-side of the boundary *chord* between the two ray hits bracketing
    theta (the visible boundary between consecutive rays is the straight
    edge r0->r1).  ``slack`` is a world-units distance tolerance toward
    inclusion at the boundary.

    The side test must stay meaningful on *degenerate chords*: at a shadow
    discontinuity the ±ANG_EPS bracket rays hit the same point, the chord
    collapses, and both cross products shrink to ~0 — an absolute product
    slack then classified every point at that exact angle as inside, no
    matter how far out (scene vertices and map corners are all in the ray
    angle set, so e.g. a map corner sat at such an angle for *every*
    viewpoint, handing far-away cells phantom visibility).  Sign agreement
    is therefore exact, and the tolerance is the point's geometric distance
    to the chord (falling back to distance-to-hit when the chord length
    vanishes), which goes to zero only when the point really approaches
    the boundary.
    """
    rel = vispoly - v
    ang = np.arctan2(rel[:, 1], rel[:, 0])
    order = np.argsort(ang)
    ang = ang[order]
    rad = np.linalg.norm(rel[order], axis=1)
    # wrap
    ang = np.concatenate([ang, ang[:1] + 2 * np.pi])
    rad = np.concatenate([rad, rad[:1]])

    prel = pts - v
    pang = np.arctan2(prel[:, 1], prel[:, 0])
    prad = np.linalg.norm(prel, axis=1)
    pang = np.where(pang < ang[0], pang + 2 * np.pi, pang)   # wrap-around
    idx = np.searchsorted(ang, pang, side="right")
    idx = np.clip(idx, 1, len(ang) - 1)
    a0, a1 = ang[idx - 1], ang[idx]
    r0, r1 = rad[idx - 1], rad[idx]
    p0 = v + r0[:, None] * np.stack([np.cos(a0), np.sin(a0)], axis=1)
    p1 = v + r1[:, None] * np.stack([np.cos(a1), np.sin(a1)], axis=1)
    crossv = _cross(p0, p1, pts)
    crossc = _cross(p0, p1, np.broadcast_to(v, pts.shape))
    same_side = crossv * crossc > 0
    clen = np.linalg.norm(p1 - p0, axis=-1)
    dist = np.where(clen > 1e-12,
                    np.abs(crossv) / np.maximum(clen, 1e-12),
                    np.linalg.norm(pts - p0, axis=-1))
    return (same_side | (dist <= slack)) & (prad > 0)


def _segs_properly_cross(p0, p1, q0, q1):
    """Vectorized strict proper segment crossing ([N] bools).

    Sign-based (scale-invariant); touching/collinear contact is deliberately
    excluded — callers cover it with the containment conditions.
    """
    d1 = _cross(q0, q1, p0)
    d2 = _cross(q0, q1, p1)
    d3 = _cross(p0, p1, q0)
    d4 = _cross(p0, p1, q1)
    return (((d1 > 0) & (d2 < 0)) | ((d1 < 0) & (d2 > 0))) & \
           (((d3 > 0) & (d4 < 0)) | ((d3 < 0) & (d4 > 0)))


def _filtered_signs(t1, t2, band: float):
    """(pos, neg) of ``t1 - t2`` with a relative zero band.

    Float64 twin of ``repro.kernels.ref.filtered_signs`` — values within
    ``band * eps * (|t1| + |t2|)`` of zero classify as neither, so exact
    contact stays contact under any evaluation order of the products.
    """
    eps = band * np.finfo(np.float64).eps
    d = t1 - t2
    tau = eps * (np.abs(t1) + np.abs(t2))
    return d > tau, d < -tau


def segments_block_strict(P, Q, A, B, C, band: float = 8.0) -> np.ndarray:
    """[N, E] bool — sign-rule blocking predicate (module convention).

    The float64 twin of the device predicate in ``repro.kernels``: segment
    ``P[i]->Q[i]`` vs CCW obstacle edge ``(A[j], B[j])`` with ``C[j]`` the
    vertex after ``B[j]`` (:attr:`Scene.edge_next`).  Blocked iff

    * **proper crossing** — both sign straddles, outside the zero band; or
    * **endpoint-on-open-edge penetration** — a segment endpoint lies on the
      open edge (in-band) and the other endpoint is strictly on the interior
      (left) side of the edge line; or
    * **through-vertex transversal** — the edge's b-vertex lies strictly
      inside the segment (cross in-band, projection strictly interior) and
      the two boundary arms (a, c) strictly straddle the segment line.

    All contact that does not enter the interior (collinear slide, tangent
    graze, endpoint touch) is non-blocking.  Sign tests are banded
    (:func:`_filtered_signs`, same ``SIGN_BAND`` structure as the kernels)
    so degenerate contact classifies identically across compilers and
    precisions.  Degenerate edges (a == b) never block — two in-band values
    cannot carry opposite filtered signs, the padding/sentinel guarantee the
    device layouts rely on.

    Known boundary of the sign rules (shared by every device backend, so
    backends still agree): a segment that penetrates *collinearly through
    a reflex vertex* — sliding along an edge line and continuing into the
    interior where the boundary turns away — fires no rule (the arm it
    must straddle is collinear with it).  Reaching that configuration
    needs a reflex (non-convex) obstacle vertex plus a segment collinear
    with its edge whose continuation is interior; with the engine's
    segment population (both endpoints free or on the boundary) and
    convex-polygon scenes it cannot occur.  The midpoint oracle handles
    it; tests pin the limitation explicitly.
    """
    P = np.asarray(P, dtype=np.float64)[:, None, :]
    Q = np.asarray(Q, dtype=np.float64)[:, None, :]
    A = np.asarray(A, dtype=np.float64)[None, :, :]
    B = np.asarray(B, dtype=np.float64)[None, :, :]
    C = np.asarray(C, dtype=np.float64)[None, :, :]

    def signs(o, a, b):
        t1 = (a[..., 0] - o[..., 0]) * (b[..., 1] - o[..., 1])
        t2 = (a[..., 1] - o[..., 1]) * (b[..., 0] - o[..., 0])
        return _filtered_signs(t1, t2, band)

    pos1, neg1 = signs(A, B, P)
    pos2, neg2 = signs(A, B, Q)
    pos3, neg3 = signs(P, Q, A)
    pos4, neg4 = signs(P, Q, B)
    pos5, neg5 = signs(P, Q, C)
    straddle12 = (pos1 & neg2) | (neg1 & pos2)
    straddle34 = (pos3 & neg4) | (neg3 & pos4)
    proper = straddle12 & straddle34
    # endpoint on the open edge, other endpoint strictly interior-side
    zero1 = ~pos1 & ~neg1
    zero2 = ~pos2 & ~neg2
    touch_pen = ((zero1 & pos2) | (zero2 & pos1)) & straddle34
    # edge's b-vertex strictly inside the segment, arms straddle
    d = Q - P
    tb = ((B - P) * d).sum(-1)
    L2 = (d * d).sum(-1)
    tau = band * np.finfo(np.float64).eps * L2
    on_seg = (~pos4 & ~neg4) & (tb > tau) & (tb < L2 - tau)
    vert_pen = on_seg & ((pos3 & neg5) | (neg3 & pos5))
    return proper | touch_pen | vert_pen


def blocked_strict_batch(scene: Scene, P, Q) -> np.ndarray:
    """[N] bool — any obstacle edge blocks, per :func:`segments_block_strict`.

    Float64 reference for the device backends; on degenerate (exact-contact)
    configurations it agrees with :func:`visible_batch` by construction.
    """
    if scene.edges.shape[0] == 0:
        return np.zeros(len(np.atleast_2d(P)), dtype=bool)
    return segments_block_strict(P, Q, scene.edges[:, 0], scene.edges[:, 1],
                                 scene.edge_next).any(axis=1)


def vispoly_intersects_rects(vispoly: np.ndarray, v: np.ndarray,
                             rects: np.ndarray, inflate: float = 1e-6
                             ) -> np.ndarray:
    """[C] bool — does the visibility polygon meet each axis rect?

    rects: [C,4] as (xmin, ymin, xmax, ymax).  Standard polygon/rect
    intersection: corner-in-polygon OR polygon-vertex-in-rect OR edge
    crossing.  Rects are inflated by ``inflate`` so sliver-visibility at
    region borders errs toward inclusion (extra labels are always safe).
    """
    rects = np.asarray(rects, dtype=np.float64)
    C = len(rects)
    xmin = rects[:, 0] - inflate
    ymin = rects[:, 1] - inflate
    xmax = rects[:, 2] + inflate
    ymax = rects[:, 3] + inflate

    # (1) any rect corner inside the star polygon
    corners = np.stack([
        np.stack([xmin, ymin], 1), np.stack([xmax, ymin], 1),
        np.stack([xmax, ymax], 1), np.stack([xmin, ymax], 1)], axis=1)  # [C,4,2]
    cin = _point_in_star(vispoly, v, corners.reshape(-1, 2)).reshape(C, 4).any(1)

    # (2) any vispoly vertex inside the rect (or the viewpoint itself)
    allpts = np.concatenate([vispoly, np.asarray(v, dtype=np.float64)[None]])
    px, py = allpts[:, 0], allpts[:, 1]
    pin = ((px[None] >= xmin[:, None]) & (px[None] <= xmax[:, None]) &
           (py[None] >= ymin[:, None]) & (py[None] <= ymax[:, None])).any(1)

    # (3) any vispoly edge crossing any rect edge
    e0 = vispoly
    e1 = np.roll(vispoly, -1, axis=0)                       # [R,2]
    rc = corners                                            # [C,4,2]
    rc1 = np.roll(corners, -1, axis=1)
    # broadcast [C,4,R]
    p0 = e0[None, None]
    p1 = e1[None, None]
    q0 = rc[:, :, None]
    q1 = rc1[:, :, None]
    xing = _segs_properly_cross(p0, p1, q0, q1).any(axis=(1, 2))
    return cin | pin | xing


def random_free_points(scene: Scene, n: int, rng: np.random.Generator
                       ) -> np.ndarray:
    """Sample n points uniformly from free space (rejection sampling)."""
    out = np.zeros((n, 2))
    got = 0
    while got < n:
        cand = rng.uniform([0, 0], [scene.width, scene.height],
                           size=(max(64, 2 * (n - got)), 2))
        keep = cand[~points_strictly_inside(scene, cand)]
        take = min(len(keep), n - got)
        out[got:got + take] = keep[:take]
        got += take
    return out


def edist(p, q) -> np.ndarray:
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    return np.sqrt(((p - q) ** 2).sum(-1))
