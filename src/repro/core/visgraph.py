"""Visibility graph over convex obstacle vertices + exact ground truth.

The visibility graph G=(V,E) has a node per convex obstacle vertex and an
edge between every co-visible pair, weighted by Euclidean distance.  The
classic ESPP reduction: every optimal obstacle-avoiding path is a path in G
augmented with s and t.  ``astar`` on the augmented graph is this repo's
ground-truth oracle (and the stand-in online competitor a la Polyanya in the
benchmark tables).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .geometry import Scene, edist, visible_batch, visible_from_point


@dataclasses.dataclass
class VisGraph:
    scene: Scene
    nodes: np.ndarray        # [V,2] convex-vertex coordinates
    adj_idx: list            # V lists of neighbour node ids
    adj_w: list              # V lists of edge weights

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_edges(self) -> int:
        return sum(len(a) for a in self.adj_idx) // 2


def build_visgraph(scene: Scene, chunk: int = 4096) -> VisGraph:
    """All-pairs co-visibility among convex vertices (vectorized, chunked)."""
    nodes = scene.convex_vertices
    V = len(nodes)
    adj_idx = [[] for _ in range(V)]
    adj_w = [[] for _ in range(V)]
    if V >= 2:
        iu, ju = np.triu_indices(V, k=1)
        P = nodes[iu]
        Q = nodes[ju]
        vis = visible_batch(scene, P, Q, chunk=chunk)
        w = edist(P, Q)
        for i, j, ok, d in zip(iu, ju, vis, w):
            if ok and d > 0:
                adj_idx[i].append(int(j))
                adj_w[i].append(float(d))
                adj_idx[j].append(int(i))
                adj_w[j].append(float(d))
    return VisGraph(scene, nodes, adj_idx, adj_w)


def dijkstra(g: VisGraph, src: int) -> tuple[np.ndarray, np.ndarray]:
    """Single-source distances + predecessor array over the visgraph."""
    V = g.num_nodes
    dist = np.full(V, np.inf)
    pred = np.full(V, -1, dtype=np.int64)
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u] + 1e-12:
            continue
        for v, w in zip(g.adj_idx[u], g.adj_w[u]):
            nd = d + w
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                pred[v] = u
                heapq.heappush(pq, (nd, v))
    return dist, pred


def astar(g: VisGraph, s: np.ndarray, t: np.ndarray
          ) -> tuple[float, list[np.ndarray]]:
    """Exact ESPP oracle: A* over the s/t-augmented visibility graph.

    Returns (distance, path points).  distance = inf when unreachable.
    """
    s = np.asarray(s, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    scene = g.scene
    if visible_batch(scene, s[None], t[None])[0]:
        return float(edist(s, t)), [s, t]
    V = g.num_nodes
    svis = visible_from_point(scene, s, g.nodes) if V else np.zeros(0, bool)
    tvis = visible_from_point(scene, t, g.nodes) if V else np.zeros(0, bool)
    if not svis.any() or not tvis.any():
        return float("inf"), []

    h = edist(g.nodes, t[None])                  # admissible heuristic
    dist = np.full(V, np.inf)
    pred = np.full(V, -2, dtype=np.int64)        # -1 marks source
    pq = []
    for i in np.nonzero(svis)[0]:
        d = float(edist(s, g.nodes[i]))
        if d < dist[i]:
            dist[i] = d
            pred[i] = -1
            heapq.heappush(pq, (d + h[i], d, int(i)))
    t_edge = {int(i): float(edist(g.nodes[i], t)) for i in np.nonzero(tvis)[0]}
    best = np.inf
    best_end = -1
    while pq:
        f, d, u = heapq.heappop(pq)
        if d > dist[u] + 1e-12 or f >= best - 1e-12:
            continue
        if u in t_edge and d + t_edge[u] < best:
            best = d + t_edge[u]
            best_end = u
        for v, w in zip(g.adj_idx[u], g.adj_w[u]):
            nd = d + w
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                pred[v] = u
                heapq.heappush(pq, (nd + h[v], nd, v))
    if not np.isfinite(best):
        return float("inf"), []
    path = [t]
    u = best_end
    while u != -1:
        path.append(g.nodes[u])
        u = int(pred[u])
    path.append(s)
    return float(best), path[::-1]
