"""Pallas TPU kernels for the EHL* online phase (+ jnp oracles).

segvis     — batched segment-vs-obstacle visibility predicate (VPU)
label_join — dense hub-label merge-join, Eq. 3 of the paper
ops        — jit'd dispatch wrappers (kernel vs reference)
ref        — pure-jnp oracles; also the non-TPU production path
"""

from . import ops, ref  # noqa: F401
