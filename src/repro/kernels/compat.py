"""Version-tolerant shims over the moving Pallas TPU API surface.

JAX has renamed the TPU compiler-parameter container across releases
(``pltpu.TPUCompilerParams`` in the 0.4.x line, ``pltpu.CompilerParams``
in newer releases, a plain dict before either existed).  Every kernel in
this package goes through :func:`tpu_compiler_params` so the kernels
themselves stay pinned to one spelling.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics=None):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    Tries the current class name first, then the legacy one; falls back to
    the dict form (accepted by old pallas_call signatures) if neither class
    exists.  Unknown kwargs degrade to a parameterless instance rather than
    failing — the semantics hint is an optimization, not a correctness knob.
    """
    kw = {}
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is None:
            continue
        try:
            return cls(**kw)
        except TypeError:
            return cls()
    return dict(mosaic=kw) if kw else None
