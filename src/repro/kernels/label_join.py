"""Pallas TPU kernel: hub-label merge-join (paper Eq. 3), dense form.

The CPU EHL join is a two-pointer scan over two sorted label lists — a
pointer-chasing pattern with data-dependent branches that maps terribly onto
the VPU.  TPU adaptation (DESIGN.md §3): compute the full ``[L, L]`` hub
equality mask and reduce with min-plus.  O(L^2) flops instead of O(L), but
branch-free, layout-regular and entirely VMEM-resident — the standard TPU
trade of redundant flops for regularity.  The kernel emits the *row join*
``out[b, i] = vd_s[b, i] + min_{j : hub_t[b,j] == hub_s[b,i]} vd_t[b, j]``
so the output tile keeps the lane-aligned [B_BLK, L] shape; the final
min-over-L happens in the jit wrapper (fused by XLA).

Memory: per grid step the kernel holds 4 label tiles of [B_BLK, L] plus one
[B_BLK, L, T_BLK] broadcast temp in VMEM; B_BLK=8, L<=2048, T_BLK=128 keeps
the footprint under ~5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import tpu_compiler_params


DEF_B_BLK = 8
DEF_T_BLK = 128


def _join_kernel(hub_s_ref, vd_s_ref, hub_t_ref, vd_t_ref, out_ref,
                 *, t_blk: int):
    L = hub_s_ref.shape[1]
    hub_s = hub_s_ref[...]             # [BB, L] int32
    vd_s = vd_s_ref[...]               # [BB, L] f32
    inf = jnp.float32(jnp.inf)

    def body(k, matchmin):
        hub_t = hub_t_ref[:, pl.ds(k * t_blk, t_blk)]       # [BB, T]
        vd_t = vd_t_ref[:, pl.ds(k * t_blk, t_blk)]
        eq = hub_s[:, :, None] == hub_t[:, None, :]         # [BB, L, T]
        cand = jnp.min(jnp.where(eq, vd_t[:, None, :], inf), axis=-1)
        return jnp.minimum(matchmin, cand)

    matchmin = jax.lax.fori_loop(
        0, L // t_blk, body, jnp.full(hub_s.shape, inf, dtype=jnp.float32))
    out_ref[...] = vd_s + matchmin


# repolint: disable=jit-registry -- kernel microbench entry; serving wraps it via packed join entries
@functools.partial(jax.jit,
                   static_argnames=("b_blk", "t_blk", "interpret"))
def label_join_rowmin(hub_s: jnp.ndarray, vd_s: jnp.ndarray,
                      hub_t: jnp.ndarray, vd_t: jnp.ndarray,
                      *, b_blk: int = DEF_B_BLK, t_blk: int = DEF_T_BLK,
                      interpret: bool = False) -> jnp.ndarray:
    """[B, L] row join via the Pallas kernel (pads handled here).

    Pad rows use hub id HUB_PAD on the s side only — HUB_PAD == HUB_PAD
    matches pad-to-pad, but vd is +inf there so the min is unaffected.
    Quantized (bf16/f16) ``vd`` inputs are widened in-register; the kernel
    body always accumulates the distance sum in f32 (DESIGN.md §11).
    """
    B, L = hub_s.shape
    b_pad = (-B) % b_blk
    l_pad = (-L) % t_blk
    inf = jnp.float32(jnp.inf)

    def padded(x, fill):
        return jnp.pad(x, ((0, b_pad), (0, l_pad)), constant_values=fill)

    hs = padded(hub_s.astype(jnp.int32), 2 ** 30)
    ht = padded(hub_t.astype(jnp.int32), 2 ** 30)
    vs = padded(vd_s.astype(jnp.float32), inf)
    vt = padded(vd_t.astype(jnp.float32), inf)
    Bp, Lp = hs.shape

    out = pl.pallas_call(
        functools.partial(_join_kernel, t_blk=t_blk),
        grid=(Bp // b_blk,),
        in_specs=[pl.BlockSpec((b_blk, Lp), lambda i: (i, 0))] * 4,
        out_specs=pl.BlockSpec((b_blk, Lp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Lp), jnp.float32),
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(hs, vs, ht, vt)
    return out[:B, :L]


def label_join(hub_s, vd_s, hub_t, vd_t, **kw) -> jnp.ndarray:
    """[B] Eq. 3 distances (min over the row join)."""
    return label_join_rowmin(hub_s, vd_s, hub_t, vd_t, **kw).min(axis=-1)
