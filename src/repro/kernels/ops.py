"""Jit'd public wrappers: kernel / reference dispatch.

``*_kernel`` entry points run the Pallas kernels (interpret=True off-TPU, so
CPU CI exercises the exact kernel bodies); ``*_ref`` entry points are the
pure-jnp oracles.  ``repro.core.packed.query_batch`` picks via its
``use_kernels`` flag; tests assert both paths agree.
"""

from __future__ import annotations

import jax

from . import ref as _ref
from .label_join import label_join as _label_join_pallas
from .label_join import label_join_rowmin as _label_join_rowmin_pallas
from .segvis import segvis as _segvis_pallas
from .segvis import segvis_tiles as _segvis_tiles_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- references (also the non-TPU production path) ---------------------------
segvis_ref = _ref.segvis_ref
segvis_tiles_ref = _ref.segvis_tiles_ref
label_join_ref = _ref.label_join_ref
label_join_rowmin_ref = _ref.label_join_rowmin_ref
label_join_hubdense_ref = _ref.label_join_hubdense_ref


def segvis_kernel(p, q, ea, eb, ec=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _segvis_pallas(p, q, ea, eb, ec, **kw)


def segvis_tiles_kernel(p, q, ax, ay, bx, by, cx, cy, **kw):
    kw.setdefault("interpret", _interpret())
    return _segvis_tiles_pallas(p, q, ax, ay, bx, by, cx, cy, **kw)


def label_join_kernel(hub_s, vd_s, hub_t, vd_t, **kw):
    kw.setdefault("interpret", _interpret())
    return _label_join_pallas(hub_s, vd_s, hub_t, vd_t, **kw)


def label_join_rowmin_kernel(hub_s, vd_s, hub_t, vd_t, **kw):
    kw.setdefault("interpret", _interpret())
    return _label_join_rowmin_pallas(hub_s, vd_s, hub_t, vd_t, **kw)
