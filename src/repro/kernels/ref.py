"""Pure-jnp oracles for the Pallas kernels (bit-for-bit semantics).

Every kernel in this package has its reference here; tests sweep shapes and
assert allclose(kernel(interpret=True), ref).  These references are also the
production fallback on non-TPU backends.
"""

from __future__ import annotations

import jax.numpy as jnp


def cross3(ax, ay, bx, by, px, py):
    """2D cross product (b - a) x (p - a), broadcasting."""
    return (bx - ax) * (py - ay) - (by - ay) * (px - ax)


def segvis_ref(p: jnp.ndarray, q: jnp.ndarray,
               ea: jnp.ndarray, eb: jnp.ndarray) -> jnp.ndarray:
    """[N] bool — True where segment p[i]->q[i] crosses NO obstacle edge.

    Strict proper-crossing predicate (scale-invariant sign tests): grazing a
    vertex or sliding along an edge counts as visible, matching ESPP
    semantics.  p, q: [N,2]; ea, eb: [E,2].
    """
    px, py = p[:, 0, None], p[:, 1, None]      # [N,1]
    qx, qy = q[:, 0, None], q[:, 1, None]
    ax, ay = ea[None, :, 0], ea[None, :, 1]    # [1,E]
    bx, by = eb[None, :, 0], eb[None, :, 1]

    d1 = cross3(ax, ay, bx, by, px, py)        # [N,E]
    d2 = cross3(ax, ay, bx, by, qx, qy)
    d3 = cross3(px, py, qx, qy, ax, ay)
    d4 = cross3(px, py, qx, qy, bx, by)
    proper = (((d1 > 0) & (d2 < 0)) | ((d1 < 0) & (d2 > 0))) & \
             (((d3 > 0) & (d4 < 0)) | ((d3 < 0) & (d4 > 0)))
    return ~proper.any(axis=1)


def label_join_rowmin_ref(hub_s: jnp.ndarray, vd_s: jnp.ndarray,
                          hub_t: jnp.ndarray, vd_t: jnp.ndarray
                          ) -> jnp.ndarray:
    """[B, L] — per s-label: vd_s[i] + min over t-labels with equal hub.

    The dense-TPU form of the paper's sorted merge-join (Eq. 3): hub match is
    an L x L equality mask instead of a two-pointer scan.
    """
    inf = jnp.float32(jnp.inf)
    eq = hub_s[:, :, None] == hub_t[:, None, :]           # [B,L,L]
    matchmin = jnp.min(jnp.where(eq, vd_t[:, None, :], inf), axis=-1)
    return vd_s + matchmin


def label_join_ref(hub_s, vd_s, hub_t, vd_t) -> jnp.ndarray:
    """[B] — Eq. 3 distance through the best common hub."""
    return label_join_rowmin_ref(hub_s, vd_s, hub_t, vd_t).min(axis=-1)


def label_join_hubdense_ref(hub_s, vd_s, hub_t, vd_t, num_hubs: int
                            ) -> jnp.ndarray:
    """[B] — beyond-paper 'hub-scatter' join: segmented min into dense hub
    space then a min-plus reduction.  O(B*(L+H)) instead of O(B*L^2) and
    shardable over the label axis (each shard scatters locally, combine with
    a min-reduction collective).  Pads (hub id >= num_hubs) are dropped.
    """
    inf = jnp.float32(jnp.inf)
    B, L = hub_s.shape
    safe_s = jnp.clip(hub_s, 0, num_hubs - 1)
    safe_t = jnp.clip(hub_t, 0, num_hubs - 1)
    valid_s = hub_s < num_hubs
    valid_t = hub_t < num_hubs
    dense_s = jnp.full((B, num_hubs), inf).at[
        jnp.arange(B)[:, None], safe_s].min(jnp.where(valid_s, vd_s, inf))
    dense_t = jnp.full((B, num_hubs), inf).at[
        jnp.arange(B)[:, None], safe_t].min(jnp.where(valid_t, vd_t, inf))
    return (dense_s + dense_t).min(axis=-1)
