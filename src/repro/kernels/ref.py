"""Pure-jnp oracles for the Pallas kernels (bit-for-bit semantics).

Every kernel in this package has its reference here; tests sweep shapes and
assert allclose(kernel(interpret=True), ref).  These references are also the
production fallback on non-TPU backends.
"""

from __future__ import annotations

import jax.numpy as jnp


def cross3(ax, ay, bx, by, px, py):
    """2D cross product (b - a) x (p - a), broadcasting."""
    return (bx - ax) * (py - ay) - (by - ay) * (px - ax)


# Zero-band width in units of machine epsilon.  A cross product whose two
# partial products mathematically cancel (endpoint exactly on a vertex or
# edge line, degenerate a == b edge, degenerate p == q segment) can come back
# as a few-ulp residual instead of 0.0 once XLA/Mosaic contracts the
# ``t1 - t2`` expression into an fma — the residual is bounded by ~1 ulp of
# the larger partial product, regardless of how the compiler fuses.  An 8x
# margin keeps every exact-contact class inside the band under any fusion
# while the band itself (~1e-6 relative) stays far below any genuine
# non-degenerate cross on map-scale coordinates.
SIGN_BAND = 8.0


def filtered_signs(t1, t2):
    """(pos, neg) of ``t1 - t2`` with a fusion-proof relative zero band.

    ``|t1 - t2| <= SIGN_BAND * eps * (|t1| + |t2|)`` classifies as zero
    (neither pos nor neg), so the §5 degenerate rules see exact contact as
    contact no matter how the backend compiled the arithmetic.
    """
    eps = SIGN_BAND * jnp.finfo(jnp.result_type(t1, t2)).eps
    d = t1 - t2
    tau = eps * (jnp.abs(t1) + jnp.abs(t2))
    return d > tau, d < -tau


def blocked_pairs(px, py, qx, qy, ax, ay, bx, by, cx, cy):
    """Per-(segment, edge) blocking predicate — the DESIGN.md §5 convention.

    All ten operands broadcast together.  Touching never blocks; interior
    penetration always blocks, including the degenerate entries:

    * proper crossing (both sign straddles, signs outside the zero band);
    * a segment endpoint on the open edge (in-band) with the other endpoint
      strictly on the interior (left, CCW) side;
    * the edge's b-vertex on the open segment (in-band, projection strictly
      interior) with the boundary arms ``a`` and ``c``
      (:attr:`Scene.edge_next`) strictly straddling it.

    Every sign test runs through :func:`filtered_signs`, so the predicate is
    stable under compiler fusion (fma contraction) and float32 coordinate
    rounding: a segment anchored exactly on a vertex stays "touching", never
    a phantom proper crossing.  Passing ``c == b`` disables the vertex rule
    (no adjacency information), and degenerate edges ``a == b`` never block
    — the padding guarantee (opposite filtered signs of two in-band values
    would need a residual larger than the band, which cannot happen).  This
    is the single predicate body shared by the jnp reference and both Pallas
    kernels (dense and grid-gathered tiles), so grid pruning and kernel/ref
    swaps stay bitwise-identical.
    """
    pos1, neg1 = filtered_signs((bx - ax) * (py - ay), (by - ay) * (px - ax))
    pos2, neg2 = filtered_signs((bx - ax) * (qy - ay), (by - ay) * (qx - ax))
    pos3, neg3 = filtered_signs((qx - px) * (ay - py), (qy - py) * (ax - px))
    pos4, neg4 = filtered_signs((qx - px) * (by - py), (qy - py) * (bx - px))
    pos5, neg5 = filtered_signs((qx - px) * (cy - py), (qy - py) * (cx - px))
    straddle12 = (pos1 & neg2) | (neg1 & pos2)
    straddle34 = (pos3 & neg4) | (neg3 & pos4)
    proper = straddle12 & straddle34
    zero1 = ~pos1 & ~neg1
    zero2 = ~pos2 & ~neg2
    touch_pen = ((zero1 & pos2) | (zero2 & pos1)) & straddle34
    dx = qx - px
    dy = qy - py
    tb = (bx - px) * dx + (by - py) * dy
    l2 = dx * dx + dy * dy
    tau = SIGN_BAND * jnp.finfo(jnp.result_type(l2)).eps * l2
    on_seg = (~pos4 & ~neg4) & (tb > tau) & (tb < l2 - tau)
    vert_pen = on_seg & ((pos3 & neg5) | (neg3 & pos5))
    return proper | touch_pen | vert_pen


def segvis_ref(p: jnp.ndarray, q: jnp.ndarray,
               ea: jnp.ndarray, eb: jnp.ndarray,
               ec: jnp.ndarray | None = None) -> jnp.ndarray:
    """[N] bool — True where segment p[i]->q[i] is blocked by NO edge.

    Sign-rule convention of :func:`blocked_pairs` (touching != blocked,
    interior penetration = blocked).  p, q: [N,2]; ea, eb, ec: [E,2];
    ``ec`` defaults to ``eb`` (vertex rule off) when adjacency is unknown.
    """
    if ec is None:
        ec = eb
    blocked = blocked_pairs(
        p[:, 0, None], p[:, 1, None], q[:, 0, None], q[:, 1, None],
        ea[None, :, 0], ea[None, :, 1], eb[None, :, 0], eb[None, :, 1],
        ec[None, :, 0], ec[None, :, 1])
    return ~blocked.any(axis=1)


def segvis_tiles_ref(p: jnp.ndarray, q: jnp.ndarray,
                     ax: jnp.ndarray, ay: jnp.ndarray,
                     bx: jnp.ndarray, by: jnp.ndarray,
                     cx: jnp.ndarray, cy: jnp.ndarray) -> jnp.ndarray:
    """[N] bool visibility over per-segment gathered edge tiles.

    The grid-pruned form: each segment i carries its own [S] edge slots
    (``repro.core.edgegrid.gather_edge_tiles``); unused slots hold the
    degenerate sentinel (a == b == c), which :func:`blocked_pairs` never
    blocks on.  Same predicate body as :func:`segvis_ref`, so results are
    bitwise-identical whenever the tiles cover every blocking edge.
    """
    blocked = blocked_pairs(
        p[:, 0, None], p[:, 1, None], q[:, 0, None], q[:, 1, None],
        ax, ay, bx, by, cx, cy)
    return ~blocked.any(axis=1)


def label_join_rowmin_ref(hub_s: jnp.ndarray, vd_s: jnp.ndarray,
                          hub_t: jnp.ndarray, vd_t: jnp.ndarray
                          ) -> jnp.ndarray:
    """[B, L] — per s-label: vd_s[i] + min over t-labels with equal hub.

    The dense-TPU form of the paper's sorted merge-join (Eq. 3): hub match is
    an L x L equality mask instead of a two-pointer scan.

    Accepts quantized (bf16/f16) ``vd`` inputs: they are widened in-register
    and the distance sum always accumulates in f32 (DESIGN.md §11).
    """
    inf = jnp.float32(jnp.inf)
    vd_s = vd_s.astype(jnp.float32)
    vd_t = vd_t.astype(jnp.float32)
    eq = hub_s[:, :, None] == hub_t[:, None, :]           # [B,L,L]
    matchmin = jnp.min(jnp.where(eq, vd_t[:, None, :], inf), axis=-1)
    return vd_s + matchmin


def label_join_ref(hub_s, vd_s, hub_t, vd_t) -> jnp.ndarray:
    """[B] — Eq. 3 distance through the best common hub."""
    return label_join_rowmin_ref(hub_s, vd_s, hub_t, vd_t).min(axis=-1)


def label_join_hubdense_ref(hub_s, vd_s, hub_t, vd_t, num_hubs: int
                            ) -> jnp.ndarray:
    """[B] — beyond-paper 'hub-scatter' join: segmented min into dense hub
    space then a min-plus reduction.  O(B*(L+H)) instead of O(B*L^2) and
    shardable over the label axis (each shard scatters locally, combine with
    a min-reduction collective).  Pads (hub id >= num_hubs) are dropped.
    """
    inf = jnp.float32(jnp.inf)
    vd_s = vd_s.astype(jnp.float32)
    vd_t = vd_t.astype(jnp.float32)
    B, L = hub_s.shape
    safe_s = jnp.clip(hub_s, 0, num_hubs - 1)
    safe_t = jnp.clip(hub_t, 0, num_hubs - 1)
    valid_s = hub_s < num_hubs
    valid_t = hub_t < num_hubs
    dense_s = jnp.full((B, num_hubs), inf).at[
        jnp.arange(B)[:, None], safe_s].min(jnp.where(valid_s, vd_s, inf))
    dense_t = jnp.full((B, num_hubs), inf).at[
        jnp.arange(B)[:, None], safe_t].min(jnp.where(valid_t, vd_t, inf))
    return (dense_s + dense_t).min(axis=-1)
