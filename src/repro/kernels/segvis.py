"""Pallas TPU kernels: batched segment-vs-obstacle visibility predicate.

The query-phase hot spot of EHL on TPU (DESIGN.md §3): every query point must
test visibility against every via vertex of its region — N = B*L segments
against E obstacle edges, ~25 fused VPU ops per (segment, edge) pair with an
OR-reduction over edges.  Two forms:

* :func:`segvis` — dense: every segment against every edge, O(N*E).
  Segments stream through the grid's parallel axis in ``(2, SEG_BLK)``
  coordinate tiles (coords transposed so the lane dimension is the segment
  index); edges stream through an arbitrary-order reduction axis in
  ``(2, EDGE_BLK)`` tiles that stay resident in VMEM while a whole segment
  tile is processed.
* :func:`segvis_tiles` — grid-pruned: each segment carries its own ``[S]``
  pre-gathered edge slots (``repro.core.edgegrid``), O(N*S) with
  S = E_local << E on edge-heavy maps.  The [SEG_BLK, TILE_BLK] predicate
  tile never leaves VMEM; only the per-segment OR accumulator is written
  back.

Both kernels inline the exact predicate body of ``kernels.ref.blocked_pairs``
(DESIGN.md §5 convention: touching != blocked, interior penetration =
blocked, degenerate edges never block), so kernel/ref and dense/grid swaps
are bitwise-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref
from .compat import tpu_compiler_params


DEF_SEG_BLK = 256
DEF_EDGE_BLK = 512
DEF_TILE_BLK = 512


# The predicate tile IS ``ref.blocked_pairs`` — pure jnp arithmetic traces
# unchanged inside a Pallas kernel body, so the banded §5 convention (and
# ``ref.SIGN_BAND``) has exactly one jnp definition shared by the reference
# and both kernels; the float64 host twin lives in ``core.geometry``.
_blocked_tile = _ref.blocked_pairs


def _segvis_kernel(p_ref, q_ref, ea_ref, eb_ref, ec_ref, out_ref):
    """Grid = (num_seg_blocks, num_edge_blocks); out revisited over axis 1."""
    j = pl.program_id(1)

    px = p_ref[0, :][:, None]       # [SB,1]
    py = p_ref[1, :][:, None]
    qx = q_ref[0, :][:, None]
    qy = q_ref[1, :][:, None]
    ax = ea_ref[0, :][None, :]      # [1,EB]
    ay = ea_ref[1, :][None, :]
    bx = eb_ref[0, :][None, :]
    by = eb_ref[1, :][None, :]
    cx = ec_ref[0, :][None, :]
    cy = ec_ref[1, :][None, :]

    blocked = _blocked_tile(px, py, qx, qy, ax, ay, bx, by, cx, cy)
    blocked = blocked.any(axis=1).astype(jnp.int32)     # [SB]

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = blocked

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] | blocked


# repolint: disable=jit-registry -- build-time visibility kernel; never on the serving path
@functools.partial(jax.jit, static_argnames=("seg_blk", "edge_blk", "interpret"))
def segvis(p: jnp.ndarray, q: jnp.ndarray, ea: jnp.ndarray, eb: jnp.ndarray,
           ec: jnp.ndarray | None = None, *,
           seg_blk: int = DEF_SEG_BLK, edge_blk: int = DEF_EDGE_BLK,
           interpret: bool = False) -> jnp.ndarray:
    """[N] bool visibility via the Pallas kernel (pads handled here).

    Padding is loss-free: padded segments are degenerate points at the
    origin (no strict sign can fire), padded edges are degenerate repeats of
    the last edge slot (repeats never change the OR-reduction).  ``ec``
    defaults to ``eb`` — vertex rule off — when adjacency is unknown.
    """
    if ec is None:
        ec = eb
    N = p.shape[0]
    E = ea.shape[0]
    n_pad = (-N) % seg_blk
    e_pad = (-E) % edge_blk
    pT = jnp.pad(p.astype(jnp.float32), ((0, n_pad), (0, 0))).T  # [2, Np]
    qT = jnp.pad(q.astype(jnp.float32), ((0, n_pad), (0, 0))).T
    mode = "edge" if E else "constant"
    eaT = jnp.pad(ea.astype(jnp.float32), ((0, e_pad), (0, 0)), mode=mode).T
    ebT = jnp.pad(eb.astype(jnp.float32), ((0, e_pad), (0, 0)), mode=mode).T
    ecT = jnp.pad(ec.astype(jnp.float32), ((0, e_pad), (0, 0)), mode=mode).T
    Np = N + n_pad
    Ep = E + e_pad

    out = pl.pallas_call(
        _segvis_kernel,
        grid=(Np // seg_blk, Ep // edge_blk),
        in_specs=[
            pl.BlockSpec((2, seg_blk), lambda i, j: (0, i)),
            pl.BlockSpec((2, seg_blk), lambda i, j: (0, i)),
            pl.BlockSpec((2, edge_blk), lambda i, j: (0, j)),
            pl.BlockSpec((2, edge_blk), lambda i, j: (0, j)),
            pl.BlockSpec((2, edge_blk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, seg_blk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pT, qT, eaT, ebT, ecT)
    return out[0, :N] == 0


def _segvis_tiles_kernel(p_ref, q_ref, ax_ref, ay_ref, bx_ref, by_ref,
                         cx_ref, cy_ref, out_ref):
    """Grid = (num_seg_blocks, num_tile_blocks); out revisited over axis 1.

    Unlike the dense kernel, every edge-coordinate tile is [SEG_BLK,
    TILE_BLK]: segment i's row holds its own gathered edges, so the
    reduction axis is per-segment slots instead of the shared edge list.
    """
    j = pl.program_id(1)

    px = p_ref[0, :][:, None]       # [SB,1]
    py = p_ref[1, :][:, None]
    qx = q_ref[0, :][:, None]
    qy = q_ref[1, :][:, None]

    blocked = _blocked_tile(px, py, qx, qy,
                            ax_ref[...], ay_ref[...],
                            bx_ref[...], by_ref[...],
                            cx_ref[...], cy_ref[...])
    blocked = blocked.any(axis=1).astype(jnp.int32)     # [SB]

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = blocked

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] | blocked


# repolint: disable=jit-registry -- build-time visibility kernel; never on the serving path
@functools.partial(jax.jit, static_argnames=("seg_blk", "tile_blk",
                                             "interpret"))
def segvis_tiles(p: jnp.ndarray, q: jnp.ndarray,
                 ax: jnp.ndarray, ay: jnp.ndarray,
                 bx: jnp.ndarray, by: jnp.ndarray,
                 cx: jnp.ndarray, cy: jnp.ndarray, *,
                 seg_blk: int = DEF_SEG_BLK, tile_blk: int = DEF_TILE_BLK,
                 interpret: bool = False) -> jnp.ndarray:
    """[N] bool visibility over per-segment [N, S] gathered edge tiles.

    Kernel twin of ``ref.segvis_tiles_ref``.  Zero-padding is loss-free
    both ways: padded segments are degenerate origin points, padded slots
    are degenerate zero edges — neither can fire a strict sign rule.
    """
    N, S = ax.shape
    n_pad = (-N) % seg_blk
    s_blk = min(tile_blk, max(128, S))
    s_pad = (-S) % s_blk
    pT = jnp.pad(p.astype(jnp.float32), ((0, n_pad), (0, 0))).T  # [2, Np]
    qT = jnp.pad(q.astype(jnp.float32), ((0, n_pad), (0, 0))).T
    tiles = [jnp.pad(a.astype(jnp.float32), ((0, n_pad), (0, s_pad)))
             for a in (ax, ay, bx, by, cx, cy)]
    Np = N + n_pad
    Sp = S + s_pad

    seg_spec = pl.BlockSpec((2, seg_blk), lambda i, j: (0, i))
    tile_spec = pl.BlockSpec((seg_blk, s_blk), lambda i, j: (i, j))
    out = pl.pallas_call(
        _segvis_tiles_kernel,
        grid=(Np // seg_blk, Sp // s_blk),
        in_specs=[seg_spec, seg_spec] + [tile_spec] * 6,
        out_specs=pl.BlockSpec((1, seg_blk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pT, qT, *tiles)
    return out[0, :N] == 0
