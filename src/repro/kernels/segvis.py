"""Pallas TPU kernel: batched segment-vs-obstacle visibility predicate.

The query-phase hot spot of EHL on TPU (DESIGN.md §3): every query point must
test visibility against every via vertex of its region — N = B*L segments
against E obstacle edges, ~20 fused VPU ops per (segment, edge) pair with an
OR-reduction over edges.

TPU adaptation: segments stream through the grid's parallel axis in
``(2, SEG_BLK)`` coordinate tiles (coords transposed so the lane dimension is
the segment index); edges stream through an arbitrary-order reduction axis in
``(2, EDGE_BLK)`` tiles that stay resident in VMEM while a whole segment tile
is processed.  The [SEG_BLK, EDGE_BLK] predicate tile never leaves VMEM; only
the per-segment OR accumulator is written back.  Arithmetic intensity per
segment-tile pass = EDGE_BLK * ~20 flops per 16 bytes of edge traffic, so
EDGE_BLK >= 256 keeps the kernel compute-bound (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import tpu_compiler_params


DEF_SEG_BLK = 256
DEF_EDGE_BLK = 512


def _segvis_kernel(p_ref, q_ref, ea_ref, eb_ref, out_ref):
    """Grid = (num_seg_blocks, num_edge_blocks); out revisited over axis 1."""
    j = pl.program_id(1)

    px = p_ref[0, :][:, None]       # [SB,1]
    py = p_ref[1, :][:, None]
    qx = q_ref[0, :][:, None]
    qy = q_ref[1, :][:, None]
    ax = ea_ref[0, :][None, :]      # [1,EB]
    ay = ea_ref[1, :][None, :]
    bx = eb_ref[0, :][None, :]
    by = eb_ref[1, :][None, :]

    # d1/d2: query endpoints vs edge line; d3/d4: edge endpoints vs segment
    d1 = (bx - ax) * (py - ay) - (by - ay) * (px - ax)
    d2 = (bx - ax) * (qy - ay) - (by - ay) * (qx - ax)
    d3 = (qx - px) * (ay - py) - (qy - py) * (ax - px)
    d4 = (qx - px) * (by - py) - (qy - py) * (bx - px)
    proper = (((d1 > 0) & (d2 < 0)) | ((d1 < 0) & (d2 > 0))) & \
             (((d3 > 0) & (d4 < 0)) | ((d3 < 0) & (d4 > 0)))
    blocked = proper.any(axis=1).astype(jnp.int32)      # [SB]

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = blocked

    @pl.when(j > 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] | blocked


@functools.partial(jax.jit, static_argnames=("seg_blk", "edge_blk", "interpret"))
def segvis(p: jnp.ndarray, q: jnp.ndarray, ea: jnp.ndarray, eb: jnp.ndarray,
           *, seg_blk: int = DEF_SEG_BLK, edge_blk: int = DEF_EDGE_BLK,
           interpret: bool = False) -> jnp.ndarray:
    """[N] bool visibility via the Pallas kernel (pads handled here).

    Padding is loss-free: padded segments are degenerate points at the
    origin (never properly cross), padded edges are degenerate repeats of a
    real edge (d3 = d4 = 0 -> never proper).
    """
    N = p.shape[0]
    E = ea.shape[0]
    n_pad = (-N) % seg_blk
    e_pad = (-E) % edge_blk
    pT = jnp.pad(p.astype(jnp.float32), ((0, n_pad), (0, 0))).T  # [2, Np]
    qT = jnp.pad(q.astype(jnp.float32), ((0, n_pad), (0, 0))).T
    eaT = jnp.pad(ea.astype(jnp.float32), ((0, e_pad), (0, 0)),
                  mode="edge" if E else "constant").T             # [2, Ep]
    ebT = jnp.pad(eb.astype(jnp.float32), ((0, e_pad), (0, 0)),
                  mode="edge" if E else "constant").T
    Np = N + n_pad
    Ep = E + e_pad

    out = pl.pallas_call(
        _segvis_kernel,
        grid=(Np // seg_blk, Ep // edge_blk),
        in_specs=[
            pl.BlockSpec((2, seg_blk), lambda i, j: (0, i)),
            pl.BlockSpec((2, seg_blk), lambda i, j: (0, i)),
            pl.BlockSpec((2, edge_blk), lambda i, j: (0, j)),
            pl.BlockSpec((2, edge_blk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, seg_blk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pT, qT, eaT, ebT)
    return out[0, :N] == 0
