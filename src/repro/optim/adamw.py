"""AdamW in pure JAX — bf16-moment option, global-norm clip, cosine schedule.

State is a pytree mirroring params ({m, v} + scalars), so the same sharding
rules apply (optimizer state shards like its parameter: ZeRO semantics fall
out of FSDP param sharding for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32     # jnp.bfloat16 halves optimizer HBM


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def clip_by_global_norm(grads, max_norm):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vn = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mn / bc1
        vhat = vn / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        pn = p.astype(jnp.float32) - lr * delta
        return (pn.astype(p.dtype), mn.astype(cfg.moment_dtype),
                vn.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}
