"""Gradient compression for the data-parallel all-reduce.

int8 quantized all-reduce with error feedback (1-bit-Adam-family trick,
adapted to jax collectives): each DP worker quantizes its local gradient
shard to int8 with a shared per-tensor scale (psum-max), all-reduces the
int8 payload (8x less DCN/ICI traffic on the pod axis), dequantizes, and
keeps the quantization residual locally, adding it back into the next
step's gradient — unbiased in the long run.

Used inside shard_map over the DP axes (see repro/launch/train.py,
--grad-compress).  ``compress_psum_ref`` is the numerics oracle for tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_psum(g, axis, residual):
    """Error-feedback int8 psum of one tensor over mesh axis `axis`.

    Returns (mean gradient f32, new residual).  Runs inside shard_map.
    """
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0
    scale = jax.lax.pmax(scale, axis) + 1e-12          # shared scale
    q = jnp.clip(jnp.round(gf / scale), -127, 127)     # int8 payload
    deq = q * scale
    new_residual = gf - deq
    total = jax.lax.psum(q.astype(jnp.int32), axis)    # int32 accumulator
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (total.astype(jnp.float32) * scale) / n, new_residual


def compress_psum_tree(grads, residuals, axis):
    """Apply quantize_psum leaf-wise over a gradient pytree."""
    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    out = [quantize_psum(g, axis, r) for g, r in zip(flat_g, flat_r)]
    return (td.unflatten([o[0] for o in out]),
            td.unflatten([o[1] for o in out]))


def init_residuals(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)


def compress_psum_ref(local_grads: list, residuals: list):
    """Host-side oracle: emulate N workers' quantize/psum for tests."""
    import numpy as np
    gf = [np.asarray(g, np.float32) + np.asarray(r, np.float32)
          for g, r in zip(local_grads, residuals)]
    scale = max(np.max(np.abs(x)) for x in gf) / 127.0 + 1e-12
    qs = [np.clip(np.round(x / scale), -127, 127) for x in gf]
    new_res = [x - q * scale for x, q in zip(gf, qs)]
    mean = sum(qs) * scale / len(qs)
    return mean, new_res
