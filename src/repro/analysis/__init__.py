"""repolint: repo-specific invariants as a blocking static analysis pass.

Usage::

    python -m repro.analysis src benchmarks          # text report, exit 1
    python -m repro.analysis --format json src
    python -m repro.analysis --select lock-order src
    python -m repro.analysis --list-rules

The rules encode cross-cutting conventions the test suite cannot see
(DESIGN.md §14): jit entries registered with the TRACES taxonomy, no host
syncs on the staged dispatch path, subsystem import layering, monotonic
timing, and a deadlock-free lock acquisition order.  Suppress a single
site with ``# repolint: disable=<rule> -- <why>``.
"""

from __future__ import annotations

from . import checkers  # noqa: F401  (registers the built-in rules)
from .base import (Finding, Rule, get_rule, register, render_json,
                   render_text, rules, run, suppressed)
from .callgraph import CallGraph, ClassInfo, FuncInfo
from .loader import ImportEdge, Module, Project, load_file, load_project

__all__ = [
    "Finding", "Rule", "register", "rules", "get_rule", "run",
    "suppressed", "render_text", "render_json",
    "CallGraph", "FuncInfo", "ClassInfo",
    "ImportEdge", "Module", "Project", "load_file", "load_project",
    "main",
]

from .cli import main  # noqa: E402  (CLI reuse in tests)
