"""Checker framework: findings, registry, suppressions, reporters.

A checker is a callable over the parsed :class:`~repro.analysis.loader.
Project` that yields :class:`Finding` objects.  The driver collects
findings from every (selected) checker, drops the ones suppressed by
``# repolint: disable=<rule>`` comments, and renders the rest as text or
JSON.  Exit status is nonzero iff any finding survives — the pass is a
blocking CI step, so every rule here is an *invariant*, not a style nit
(DESIGN.md §14).

Suppression syntax (checked per finding against the finding's file/line):

* trailing, on the flagged line::

      t0 = time.time()   # repolint: disable=monotonic-time  -- wall ts

* on the immediately preceding line (for long flagged lines)::

      # repolint: disable=hot-path-sync -- rescue is a sanctioned sync
      flags = bool(np.asarray(res[5]).any())

* file-level, anywhere in the first comment block of the module::

      # repolint: disable-file=jit-registry -- offline tool, never served

Everything after ``--`` is the human justification; the checker framework
requires the marker but does not parse the prose.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from .loader import Module, Project

_SUPPRESS_RE = re.compile(
    r"#\s*repolint:\s*(disable|disable-file)=([A-Za-z0-9_,\- ]+)")

#: Lines scanned for file-level ``disable-file`` markers.
_FILE_SCOPE_LINES = 40


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation at a source location."""

    rule: str
    path: str               # repo-relative path
    line: int               # 1-indexed
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered checker."""

    name: str
    description: str
    check: Callable[[Project], Iterator[Finding]]


_RULES: Dict[str, Rule] = {}


def register(name: str, description: str):
    """Decorator registering ``fn(project) -> Iterator[Finding]``."""

    def deco(fn: Callable[[Project], Iterator[Finding]]):
        if name in _RULES:
            raise ValueError(f"duplicate checker name {name!r}")
        _RULES[name] = Rule(name=name, description=description, check=fn)
        return fn

    return deco


def rules() -> List[Rule]:
    return [_RULES[k] for k in sorted(_RULES)]


def get_rule(name: str) -> Rule:
    try:
        return _RULES[name]
    except KeyError:
        raise KeyError(f"unknown checker {name!r}; known: "
                       f"{', '.join(sorted(_RULES))}") from None


# --------------------------------------------------------------- suppression
def _suppressions(module: Module) -> Dict[int, set]:
    """line -> set of rule names disabled on that line (0 = whole file)."""
    out: Dict[int, set] = {}
    for i, text in enumerate(module.lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        # everything after `--` is the justification, not a rule name
        spec = m.group(2).split("--", 1)[0]
        names = {n.strip() for n in spec.split(",") if n.strip()}
        if m.group(1) == "disable-file":
            if i <= _FILE_SCOPE_LINES:
                out.setdefault(0, set()).update(names)
        else:
            out.setdefault(i, set()).update(names)
    return out


def suppressed(module: Module, finding: Finding) -> bool:
    sup = module.suppressions
    if finding.rule in sup.get(0, ()):  # file-level
        return True
    if finding.rule in sup.get(finding.line, ()):
        return True
    # marker on the line immediately above the flagged line
    return finding.rule in sup.get(finding.line - 1, ())


# -------------------------------------------------------------------- driver
def run(project: Project,
        select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the (selected) checkers; returns surviving findings, sorted."""
    chosen = rules() if select is None else [get_rule(n) for n in select]
    out: List[Finding] = []
    by_path = {m.path: m for m in project.modules}
    for rule in chosen:
        for f in rule.check(project):
            mod = by_path.get(f.path)
            if mod is not None and suppressed(mod, f):
                continue
            out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def render_text(findings: List[Finding]) -> str:
    lines = [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


def render_json(findings: List[Finding]) -> str:
    return json.dumps({"findings": [f.to_dict() for f in findings],
                       "count": len(findings)}, indent=2)
