"""CLI driver for the repolint pass (see ``__main__`` for -m entry).

Exit status: 0 clean, 1 findings, 2 usage error.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import checkers  # noqa: F401  (registers the built-in rules)
from .base import render_json, render_text, rules, run
from .loader import load_project


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Run the repo invariant checkers (DESIGN.md §14).")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--root", default=".",
                    help="repo root module names resolve against")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE",
                    help="run only this rule (repeatable)")
    ap.add_argument("--list-rules", action="store_true")
    try:
        ns = ap.parse_args(argv)
    except SystemExit as e:
        return 0 if not e.code else 2

    if ns.list_rules:
        for r in rules():
            print(f"{r.name}: {r.description}")
        return 0

    paths = ns.paths or ["src"]
    project = load_project(paths, root=ns.root)
    if not project.modules:
        print(f"no python sources found under: {' '.join(paths)}",
              file=sys.stderr)
        return 2
    try:
        findings = run(project, select=ns.select)
    except KeyError as e:
        print(str(e), file=sys.stderr)
        return 2
    out = render_json(findings) if ns.format == "json" else \
        render_text(findings)
    print(out)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
