"""Source discovery, parsing, and the project import graph.

A :class:`Project` is the parsed view of one or more source roots: each
``.py`` file becomes a :class:`Module` carrying its AST, ``symtable``, raw
lines and resolved import edges.  Module names mirror the runtime import
system: files under a root's ``src/`` layout get their dotted package path
(``src/repro/obs/events.py`` -> ``repro.obs.events``); loose scripts get
``<dirname>.<stem>`` (``benchmarks/common.py`` -> ``benchmarks.common``)
so layering rules can target them by prefix.

Import edges record *what was imported*, not just from where: layering
rules need to distinguish ``from repro.core import pack_bucketed`` (an
``__init__``-exported name) from ``from repro.core.packed import ...`` (a
deep module import).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import symtable
from typing import Dict, Iterator, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ImportEdge:
    """One import statement's resolved target.

    ``module`` is the dotted module named by the statement (for ``from m
    import a, b`` that is ``m``); ``names`` the imported attributes (empty
    for plain ``import m``); ``level`` the relative-import dot count
    (already folded into ``module``); ``toplevel`` whether the statement
    executes at module import time (False for function-local imports,
    which are the sanctioned lazy escape hatch for heavy deps).
    """

    module: str
    names: Tuple[str, ...]
    lineno: int
    col: int
    toplevel: bool


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: str                   # repo-relative, slash-separated
    name: str                   # dotted module name
    source: str
    tree: ast.AST
    lines: List[str]
    imports: List[ImportEdge]
    suppressions: Dict[int, set]
    table: Optional[symtable.SymbolTable]

    @property
    def package(self) -> str:
        """``repro.obs`` for ``repro.obs.events``; '' for top-level."""
        return self.name.rpartition(".")[0]


class Project:
    """All modules reachable under the given roots, plus lookups."""

    def __init__(self, modules: List[Module]):
        self.modules = modules
        self.by_name: Dict[str, Module] = {m.name: m for m in modules}

    def module(self, name: str) -> Optional[Module]:
        return self.by_name.get(name)

    def in_package(self, prefix: str) -> List[Module]:
        """Modules whose dotted name is ``prefix`` or under it."""
        return [m for m in self.modules
                if m.name == prefix or m.name.startswith(prefix + ".")]

    def imports_of(self, module: Module,
                   toplevel_only: bool = False) -> Iterator[ImportEdge]:
        for e in module.imports:
            if toplevel_only and not e.toplevel:
                continue
            yield e


# ------------------------------------------------------------------ loading
def _module_name(root: str, relpath: str) -> str:
    """Dotted name for ``relpath`` (slash-separated, .py) under ``root``."""
    parts = relpath[:-3].split("/")          # strip .py
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_imports(tree: ast.AST, module_name: str) -> List[ImportEdge]:
    edges: List[ImportEdge] = []
    # toplevel = the statement is a direct child of the Module body (or of
    # an `if` at module scope, e.g. TYPE_CHECKING blocks)
    toplevel_nodes: set = set()
    stack = list(getattr(tree, "body", []))
    while stack:
        n = stack.pop()
        toplevel_nodes.add(id(n))
        if isinstance(n, (ast.If, ast.Try)):
            for field in ("body", "orelse", "finalbody"):
                stack.extend(getattr(n, field, []))
            for h in getattr(n, "handlers", []):
                stack.extend(h.body)
    pkg_parts = module_name.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                edges.append(ImportEdge(a.name, (), node.lineno,
                                        node.col_offset,
                                        id(node) in toplevel_nodes))
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:
                # resolve `from ..x import y` against this module's package
                base = pkg_parts[:-node.level] if node.level <= len(pkg_parts) \
                    else []
                mod = ".".join(base + ([mod] if mod else []))
            edges.append(ImportEdge(mod,
                                    tuple(a.name for a in node.names),
                                    node.lineno, node.col_offset,
                                    id(node) in toplevel_nodes))
    edges.sort(key=lambda e: (e.lineno, e.col))
    return edges


def load_file(path: str, root: str = ".") -> Optional[Module]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    try:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
    except (OSError, UnicodeDecodeError):
        return None
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError:
        return None
    name = _module_name(root, rel)
    try:
        table = symtable.symtable(source, rel, "exec")
    except SyntaxError:          # pragma: no cover - parse already passed
        table = None
    from .base import _suppressions  # local: base imports loader
    mod = Module(path=rel, name=name, source=source, tree=tree,
                 lines=source.splitlines(), imports=[], suppressions={},
                 table=table)
    mod.imports = _collect_imports(tree, name)
    mod.suppressions = _suppressions(mod)
    return mod


def load_project(paths: Sequence[str], root: str = ".") -> Project:
    """Parse every ``.py`` under ``paths`` (files or directories)."""
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            files.extend(os.path.join(dirpath, f)
                         for f in sorted(filenames) if f.endswith(".py"))
    mods = []
    seen = set()
    for f in sorted(files):
        m = load_file(f, root=root)
        if m is not None and m.path not in seen:
            seen.add(m.path)
            mods.append(m)
    return Project(mods)
