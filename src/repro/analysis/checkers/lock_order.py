"""lock-order: the static lock-acquisition graph matches LOCK_RANKS.

The serving/indexing/obs mesh takes locks from four subsystems on one
request path (batcher condition -> engine pin -> registry -> event log);
an AB/BA inversion between any two of them is a deadlock that only
manifests under a hostile scheduler.  This checker builds the
acquisition graph statically and fails CI on any inversion:

* every lock in the monitored modules must be created through
  ``repro.obs.locks.make_lock("<name>")`` with a literal name that has a
  declared rank in ``LOCK_RANKS`` (``threading.Condition(self._lock)``
  wrapping a made lock is fine and aliases its rank);
* each ``with self._lock:`` site maps to its rank; while a lock is held,
  every directly nested ``with`` and every lock transitively acquired by
  a (precisely resolved, cross-module) callee must have a strictly
  greater rank;
* independent of ranks, any cycle in the acquisition graph is reported.

The same partial order is asserted at runtime by
``repro.obs.locks.OrderedLock`` when ``REPRO_LOCK_CHECK=1`` — the static
pass catches inversions on paths the stress tests never interleave; the
sanitizer catches acquisitions the precise call graph cannot see.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..base import Finding, register
from ..callgraph import CallGraph, FuncInfo
from ..loader import Module, Project

_LOCKS_MODULE = "repro.obs.locks"

#: Modules where every lock must go through make_lock.
_MONITORED = ("repro.serving.batcher", "repro.indexing.swap",
              "repro.indexing.manager", "repro.indexing.recorder",
              "repro.obs")


def _monitored(mod_name: str) -> bool:
    if mod_name == _LOCKS_MODULE:
        return False
    return any(mod_name == m or mod_name.startswith(m + ".")
               for m in _MONITORED)


def _ranks(project: Project) -> Dict[str, int]:
    locks = project.module(_LOCKS_MODULE)
    if locks is None:
        return {}
    for node in locks.tree.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign):
            targets, value = [node.target], node.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "LOCK_RANKS" and \
                    isinstance(value, ast.Dict):
                out: Dict[str, int] = {}
                for k, v in zip(value.keys, value.values):
                    if isinstance(k, ast.Constant) and \
                            isinstance(v, ast.Constant):
                        out[str(k.value)] = int(v.value)
                return out
    return {}


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


@dataclasses.dataclass(frozen=True)
class _Edge:
    src: str                    # lock name held
    dst: str                    # lock name acquired under it
    path: str
    line: int
    via: str                    # '' for direct nesting, else callee qname


class _LockIndex:
    """Maps ``self.attr`` / module globals to make_lock names."""

    def __init__(self, project: Project, cg: CallGraph,
                 ranks: Dict[str, int]):
        self.cg = cg
        self.ranks = ranks
        self.attr: Dict[Tuple[str, str], str] = {}   # (class, attr) -> name
        self.globals: Dict[Tuple[str, str], str] = {}  # (module, var) -> name
        self.findings: List[Finding] = []
        # two passes so Condition(self._lock) sees the lock mapping;
        # only the final pass's findings survive (no duplicates)
        for _ in range(2):
            self.findings.clear()
            for mod in project.modules:
                self._scan_module_body(mod)
            for ci in cg.classes.values():
                for meth in ci.methods.values():
                    self._scan_method(ci.module, ci.node.name, meth)

    # -------------------------------------------------------------- scanning
    def _lock_name_of(self, mod: Module, value: ast.expr,
                      cls: Optional[str]) -> Optional[str]:
        """make_lock name produced by ``value``, or None."""
        if not isinstance(value, ast.Call):
            return None
        name = _call_name(value)
        if name == "make_lock":
            if value.args and isinstance(value.args[0], ast.Constant):
                lit = str(value.args[0].value)
                if lit not in self.ranks:
                    self.findings.append(Finding(
                        "lock-order", mod.path, value.lineno,
                        value.col_offset,
                        f"make_lock({lit!r}) has no declared rank in "
                        f"repro.obs.locks.LOCK_RANKS"))
                return lit
            self.findings.append(Finding(
                "lock-order", mod.path, value.lineno, value.col_offset,
                "make_lock() requires a literal lock name so the static "
                "order checker can rank it"))
            return None
        if name == "Condition" and value.args:
            inner = value.args[0]
            if isinstance(inner, ast.Attribute) and \
                    isinstance(inner.value, ast.Name) and \
                    inner.value.id == "self" and cls is not None:
                return self._attr_lock(cls, inner.attr)
        return None

    def _raw_lock(self, mod: Module, value: ast.expr,
                  cls: Optional[str]) -> bool:
        """True if ``value`` creates a raw threading lock (monitored)."""
        if not (isinstance(value, ast.Call) and _monitored(mod.name)):
            return False
        name = _call_name(value)
        if name in ("Lock", "RLock"):
            return True
        if name == "Condition":
            # Condition wrapping a made lock aliases its rank; bare
            # Condition() (own hidden RLock) is raw.
            return self._lock_name_of(mod, value, cls) is None
        return False

    def _scan_assign(self, mod: Module, cls: Optional[str],
                     targets: List[ast.expr], value: ast.expr) -> None:
        lock = self._lock_name_of(mod, value, cls)
        if lock is None and self._raw_lock(mod, value, cls):
            self.findings.append(Finding(
                "lock-order", mod.path, value.lineno, value.col_offset,
                "raw threading lock in an order-monitored module; create "
                "it with repro.obs.locks.make_lock(\"<ranked name>\")"))
            return
        if lock is None:
            return
        for t in targets:
            if isinstance(t, ast.Attribute) and \
                    isinstance(t.value, ast.Name) and t.value.id == "self" \
                    and cls is not None:
                self.attr[(cls, t.attr)] = lock
            elif isinstance(t, ast.Name):
                if cls is None:
                    self.globals[(mod.name, t.id)] = lock

    def _scan_module_body(self, mod: Module) -> None:
        for node in mod.tree.body:
            if isinstance(node, ast.Assign):
                self._scan_assign(mod, None, node.targets, node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._scan_assign(mod, None, [node.target], node.value)

    def _scan_method(self, mod: Module, cls: str, meth: FuncInfo) -> None:
        for node in ast.walk(meth.node):
            if isinstance(node, ast.Assign):
                self._scan_assign(mod, cls, node.targets, node.value)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._scan_assign(mod, cls, [node.target], node.value)

    # -------------------------------------------------------------- lookups
    def _attr_lock(self, cls: str, attr: str) -> Optional[str]:
        for cn in self.cg.hierarchy(cls):
            hit = self.attr.get((cn, attr))
            if hit is not None:
                return hit
        return None

    def resolve(self, fn: FuncInfo, expr: ast.expr,
                local_locks: Dict[str, str]) -> Optional[str]:
        """Lock name acquired by ``with <expr>:``, or None."""
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name):
            if expr.value.id == "self" and fn.cls is not None:
                return self._attr_lock(fn.cls, expr.attr)
            return self.globals.get((fn.module.name, expr.attr)) if \
                expr.value.id != "self" else None
        if isinstance(expr, ast.Name):
            if expr.id in local_locks:
                return local_locks[expr.id]
            return self.globals.get((fn.module.name, expr.id))
        return None


@register("lock-order",
          "lock acquisition graph is acyclic and follows LOCK_RANKS")
def check(project: Project) -> Iterator[Finding]:
    ranks = _ranks(project)
    cg = CallGraph(project, precise=True)
    index = _LockIndex(project, cg, ranks)
    yield from index.findings

    # local `x = make_lock("n")` bindings, per function
    def local_locks(fn: FuncInfo) -> Dict[str, str]:
        out: Dict[str, str] = {}
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _call_name(node.value) == "make_lock" and \
                    node.value.args and \
                    isinstance(node.value.args[0], ast.Constant):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = str(node.value.args[0].value)
        return out

    # ---- pass 1: direct acquires per function, then transitive fixpoint
    direct: Dict[str, Set[str]] = {}
    for fn in cg.funcs.values():
        locs = local_locks(fn)
        acq: Set[str] = set()
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    name = index.resolve(fn, item.context_expr, locs)
                    if name is not None:
                        acq.add(name)
        direct[fn.qname] = acq

    trans: Dict[str, Set[str]] = {q: set(a) for q, a in direct.items()}
    changed = True
    while changed:
        changed = False
        for fn in cg.funcs.values():
            cur = trans[fn.qname]
            before = len(cur)
            for c in cg.callees(fn):
                cur |= trans.get(c, set())
            if len(cur) != before:
                changed = True

    # ---- pass 2: emit held->acquired edges with source sites
    edges: Dict[Tuple[str, str], _Edge] = {}

    def note(src: str, dst: str, fn: FuncInfo, line: int,
             via: str = "") -> None:
        key = (src, dst)
        if key not in edges:
            edges[key] = _Edge(src, dst, fn.module.path, line, via)

    def walk(fn: FuncInfo, node: ast.AST, held: List[str],
             locs: Dict[str, str]) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                walk(fn, item.context_expr, held, locs)
            acquired = []
            for item in node.items:
                name = index.resolve(fn, item.context_expr, locs)
                if name is not None:
                    for h in held + acquired:
                        note(h, name, fn, item.context_expr.lineno)
                    acquired.append(name)
            inner = held + acquired
            for stmt in node.body:
                walk(fn, stmt, inner, locs)
            return
        if isinstance(node, ast.Call) and held:
            for callee in cg.resolve_call(fn, node, cg._module_bindings(
                    fn.module), cg._local_types(fn)):
                for dst in trans.get(callee.qname, ()):
                    for h in held:
                        note(h, dst, fn, node.lineno, via=callee.qname)
        for child in ast.iter_child_nodes(node):
            walk(fn, child, held, locs)

    for fn in cg.funcs.values():
        walk(fn, fn.node, [], local_locks(fn))

    # ---- validation: rank inversions + cycles
    for (src, dst), e in sorted(edges.items()):
        via = f" via {e.via}" if e.via else ""
        if src == dst:
            yield Finding("lock-order", e.path, e.line, 0,
                          f"lock {src!r} acquired while already held"
                          f"{via}; self-deadlock on a non-reentrant lock")
            continue
        rs, rd = ranks.get(src), ranks.get(dst)
        if rs is not None and rd is not None and rs >= rd:
            yield Finding("lock-order", e.path, e.line, 0,
                          f"rank inversion: {dst!r} (rank {rd}) acquired "
                          f"while holding {src!r} (rank {rs}){via}; "
                          f"LOCK_RANKS requires strictly increasing ranks")

    # cycles (covers unranked fixtures; ranked cycles already contain an
    # inversion but reporting the cycle names the full loop)
    adj: Dict[str, Set[str]] = {}
    for (src, dst) in edges:
        if src != dst:
            adj.setdefault(src, set()).add(dst)
    seen: Set[str] = set()
    reported: Set[Tuple[str, ...]] = set()
    for start in sorted(adj):
        if start in seen:
            continue
        stack: List[Tuple[str, List[str]]] = [(start, [start])]
        while stack:
            node_name, path = stack.pop()
            seen.add(node_name)
            for nxt in sorted(adj.get(node_name, ())):
                if nxt in path:
                    cyc = tuple(sorted(path[path.index(nxt):]))
                    if cyc in reported:
                        continue
                    reported.add(cyc)
                    e = edges[(node_name, nxt)]
                    loop = " -> ".join(path[path.index(nxt):] + [nxt])
                    yield Finding("lock-order", e.path, e.line, 0,
                                  f"lock acquisition cycle: {loop}")
                else:
                    stack.append((nxt, path + [nxt]))
