"""Built-in checkers; importing this package registers all of them."""

from . import (hot_path_sync, jit_registry, layering,  # noqa: F401
               lock_order, monotonic_time)

__all__ = ["hot_path_sync", "jit_registry", "layering", "lock_order",
           "monotonic_time"]
