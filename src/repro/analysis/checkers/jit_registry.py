"""jit-registry: every jit trace point flows through ``_jit_entry``.

Compile attribution (obs.profile), trace-count regression tests, and
``PathServer.warmup()`` all key off the entry taxonomy in
``core.packed``: a jit body that bypasses ``@_jit_entry("name")`` is
invisible to all three — its compiles are unattributed and its first live
trace pays an XLA compile inside the serving loop.  Three sub-checks:

1. any reference to ``jax.jit`` in ``repro.*`` outside the ``_jit_entry``
   implementation is a finding (this catches direct calls, decorators,
   and ``partial(jax.jit, ...)`` alike, since all spell the attribute);
2. the ``@_jit_entry`` decorator names must match ``TRACE_ENTRIES`` in
   ``core.packed`` exactly, both directions — the static tuple is what
   tests and docs enumerate;
3. every entry's decorated function must be reachable from some engine
   ``warmup`` method (call-graph walk): an unreachable entry means
   ``PathServer.warmup()`` cannot pre-trace it and the taxonomy has
   drifted from the serving surface.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..base import Finding, register
from ..callgraph import CallGraph, FuncInfo
from ..loader import Module, Project

_PACKED = "repro.core.packed"


def _trace_entries(packed: Module) -> Tuple[Optional[int], Set[str]]:
    """(lineno, names) of the ``TRACE_ENTRIES`` literal, if present."""
    for node in packed.tree.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign):
            targets, value = [node.target], node.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "TRACE_ENTRIES":
                names: Set[str] = set()
                if isinstance(value, (ast.Tuple, ast.List)):
                    for el in value.elts:
                        if isinstance(el, ast.Constant) and \
                                isinstance(el.value, str):
                            names.add(el.value)
                return node.lineno, names
    return None, set()


def _decorated_entries(project: Project) -> Dict[str, FuncInfo]:
    """entry name -> decorated function, over all ``repro.*`` modules."""
    out: Dict[str, FuncInfo] = {}
    cg = CallGraph(project)
    for fi in cg.funcs.values():
        if not fi.module.name.startswith("repro"):
            continue
        for dec in getattr(fi.node, "decorator_list", ()):
            if not isinstance(dec, ast.Call):
                continue
            fname = ""
            if isinstance(dec.func, ast.Name):
                fname = dec.func.id
            elif isinstance(dec.func, ast.Attribute):
                fname = dec.func.attr
            if fname == "_jit_entry" and dec.args and \
                    isinstance(dec.args[0], ast.Constant):
                out[str(dec.args[0].value)] = fi
    return out


def _enclosing_ranges(mod: Module, names: Set[str]) -> List[Tuple[int, int]]:
    """(start, end) line ranges of top-level defs named in ``names``."""
    spans = []
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name in names:
            spans.append((node.lineno, node.end_lineno or node.lineno))
    return spans


@register("jit-registry",
          "jax.jit only via core.packed._jit_entry; entry names match "
          "TRACE_ENTRIES and are warmup-reachable")
def check(project: Project) -> Iterator[Finding]:
    packed = project.module(_PACKED)
    in_repro = project.in_package("repro")

    # (1) raw jax.jit references
    allowed: Dict[str, List[Tuple[int, int]]] = {}
    if packed is not None:
        allowed[packed.path] = _enclosing_ranges(packed, {"_jit_entry"})
    for mod in in_repro:
        spans = allowed.get(mod.path, [])
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Attribute) and node.attr == "jit"
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "jax"):
                continue
            if any(a <= node.lineno <= b for a, b in spans):
                continue
            yield Finding("jit-registry", mod.path, node.lineno,
                          node.col_offset,
                          "raw jax.jit reference; route this trace point "
                          "through core.packed._jit_entry so TRACES / "
                          "warmup / compile attribution see it")

    if packed is None:
        return
    ent_line, declared = _trace_entries(packed)
    decorated = _decorated_entries(project)

    # (2) taxonomy drift, both directions
    if ent_line is None:
        yield Finding("jit-registry", packed.path, 1, 0,
                      "core.packed has no TRACE_ENTRIES tuple to check "
                      "the jit entry taxonomy against")
    else:
        for name in sorted(set(decorated) - declared):
            fi = decorated[name]
            yield Finding("jit-registry", fi.module.path, fi.lineno, 0,
                          f"jit entry {name!r} is not listed in "
                          f"core.packed.TRACE_ENTRIES")
        for name in sorted(declared - set(decorated)):
            yield Finding("jit-registry", packed.path, ent_line, 0,
                          f"TRACE_ENTRIES lists {name!r} but no "
                          f"@_jit_entry({name!r}) definition exists")

    # (3) warmup reachability
    cg = CallGraph(project)
    seeds = [fi for fi in cg.funcs.values()
             if fi.name == "warmup" and fi.cls is not None
             and fi.module.name.startswith(("repro.serving",
                                            "repro.sharding"))]
    if not seeds:
        return
    reach = cg.reachable(seeds)
    for name, fi in sorted(decorated.items()):
        if fi.qname not in reach:
            yield Finding("jit-registry", fi.module.path, fi.lineno, 0,
                          f"jit entry {name!r} is not reachable from any "
                          f"serving warmup(); first live trace would "
                          f"compile inside the serving loop")
