"""monotonic-time: durations and ordering never use wall-clock time.

``time.time()`` jumps under NTP slew and DST; every span, stopwatch, and
latency histogram in the repo is monotonic (``time.perf_counter`` via
``obs.timing``).  PR 8 scrubbed wall-clock timing from ``launch/`` and it
immediately crept back in ``obs/events.py`` — so now it's a checker.
``time.time()`` is allowed only in ``repro/obs/timing.py`` (the one
module that owns clock choice) and at explicitly suppressed sites where
wall time *is* the datum (human-readable event timestamps, run metadata),
never a duration operand.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..base import Finding, register
from ..loader import Project

_ALLOWED_MODULES = {"repro.obs.timing"}


@register("monotonic-time",
          "time.time() banned outside repro/obs/timing.py")
def check(project: Project) -> Iterator[Finding]:
    for mod in project.modules:
        if mod.name in _ALLOWED_MODULES:
            continue
        # did this module do `from time import time`?
        bare_time = any(e.module == "time" and "time" in e.names
                        for e in mod.imports)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            hit = (isinstance(f, ast.Attribute) and f.attr == "time"
                   and isinstance(f.value, ast.Name)
                   and f.value.id == "time") or \
                  (bare_time and isinstance(f, ast.Name)
                   and f.id == "time")
            if hit:
                yield Finding("monotonic-time", mod.path, node.lineno,
                              node.col_offset,
                              "time.time() is wall-clock; use "
                              "obs.timing (perf_counter) for anything "
                              "ordered or subtracted")
