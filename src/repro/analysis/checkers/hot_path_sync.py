"""hot-path-sync: no host synchronization on the staged dispatch path.

The continuous-batching loop overlaps H2D transfer with device compute by
splitting every query into ``stage()`` (enqueue async copies) and
``dispatch_staged()`` (launch kernels, return device handles).  Any host
sync inside that path — ``.item()``, ``float(device_val)``,
``np.asarray(device_val)``, ``block_until_ready`` — collapses the overlap
and serializes the pipeline, without failing a single test: latency just
quietly doubles.

The walk is seeded from every ``stage`` / ``dispatch`` /
``dispatch_staged`` / ``join_staged`` method in ``repro.serving`` and
``repro.sharding`` (that covers each ``QueryEngine`` implementation, the
``ShardRouter``, and the batcher's dispatch), follows the precise call
graph, and flags sync constructs in any reached function that lives in
those packages.  Sanctioned syncs (the quantized argmin rescue, terminal
``_retire`` joins) carry ``# repolint: disable=hot-path-sync``
suppressions with their justification.
"""

from __future__ import annotations

import ast
from typing import Iterator, List

from ..base import Finding, register
from ..callgraph import CallGraph, FuncInfo
from ..loader import Project

_SEED_NAMES = {"stage", "dispatch", "dispatch_staged", "join_staged"}
_SCOPE = ("repro.serving", "repro.sharding")


def _in_scope(mod_name: str) -> bool:
    return mod_name.startswith(_SCOPE)


def _flag(node: ast.AST) -> str:
    """Reason string if ``node`` is a sync construct, else ''."""
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "item" and not node.args and not node.keywords:
                return ".item() forces a device->host sync"
            if f.attr == "block_until_ready":
                return "block_until_ready() blocks on device compute"
            if f.attr == "asarray" and isinstance(f.value, ast.Name) \
                    and f.value.id in ("np", "numpy"):
                return "np.asarray() on a device value copies to host"
        elif isinstance(f, ast.Name):
            if f.id == "float" and node.args and \
                    not isinstance(node.args[0], ast.Constant):
                return "float() on a device value forces a sync"
            if f.id == "block_until_ready":
                return "block_until_ready() blocks on device compute"
    return ""


@register("hot-path-sync",
          "no .item()/float()/np.asarray()/block_until_ready reachable "
          "from stage/dispatch/dispatch_staged")
def check(project: Project) -> Iterator[Finding]:
    cg = CallGraph(project, precise=True)
    seeds: List[FuncInfo] = [
        fi for fi in cg.funcs.values()
        if fi.name in _SEED_NAMES and fi.cls is not None
        and _in_scope(fi.module.name)]
    if not seeds:
        return
    reach = cg.reachable(seeds)
    for qname in sorted(reach):
        fi = cg.funcs.get(qname)
        if fi is None or not _in_scope(fi.module.name):
            continue
        path = reach[qname]
        via = "" if len(path) == 1 else \
            f" (reached from {path[0]} via {' -> '.join(path[1:])})"
        for node in ast.walk(fi.node):
            reason = _flag(node)
            if reason:
                yield Finding("hot-path-sync", fi.module.path, node.lineno,
                              node.col_offset,
                              f"{reason} inside hot function "
                              f"{qname}{via}")
