"""layering: the import graph respects the subsystem boundaries.

Three rules, one per boundary that has bitten before:

* ``repro.obs`` must be importable without jax at module scope — the obs
  layer runs in collectors, notebooks, and the launch CLI where jax may
  be absent or deliberately unloaded; function-local jax imports are the
  sanctioned lazy escape (obs/profile.py uses them);
* ``repro.core`` never imports ``repro.serving`` / ``repro.indexing`` —
  core is the leaf layer; a core->serving edge makes the pack/join
  kernels untestable in isolation and invites import cycles;
* ``benchmarks/`` never deep-imports past a package ``__init__`` — the
  package exports are the supported API surface; benches that reach into
  private modules break silently on refactors and bypass the lazy-import
  discipline the packages maintain.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..base import Finding, register
from ..loader import Module, Project


def _exported_names(mod: Module) -> Set[str]:
    """Names bound at ``mod``'s top level (incl. ``__all__`` entries)."""
    out: Set[str] = set()
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
            if any(isinstance(t, ast.Name) and t.id == "__all__"
                   for t in node.targets) and \
                    isinstance(node.value, (ast.List, ast.Tuple)):
                for el in node.value.elts:
                    if isinstance(el, ast.Constant) and \
                            isinstance(el.value, str):
                        out.add(el.value)
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            out.add(node.target.id)
    for e in mod.imports:
        out.update(e.names)
        if not e.names:          # plain `import x.y` binds `x`
            out.add(e.module.split(".")[0])
    return out


@register("layering",
          "obs stays jax-free at module scope; core never imports "
          "serving/indexing; benchmarks use package exports only")
def check(project: Project) -> Iterator[Finding]:
    # obs: no toplevel jax
    for mod in project.in_package("repro.obs"):
        for e in project.imports_of(mod, toplevel_only=True):
            if e.module == "jax" or e.module.startswith("jax."):
                yield Finding("layering", mod.path, e.lineno, e.col,
                              "repro.obs must stay importable without jax "
                              "at module scope; import jax inside the "
                              "function that needs it (DESIGN.md §12)")

    # core: never serving/indexing, even lazily
    for mod in project.in_package("repro.core"):
        for e in mod.imports:
            if e.module.startswith(("repro.serving", "repro.indexing")):
                yield Finding("layering", mod.path, e.lineno, e.col,
                              f"repro.core must not import {e.module} "
                              "(core is the leaf layer; invert the "
                              "dependency)")

    # benchmarks: package exports only
    for mod in project.in_package("benchmarks"):
        for e in mod.imports:
            if not e.module.startswith("repro"):
                continue
            target = project.module(e.module)
            if target is None:
                # not under the scanned roots (e.g. src/ not given);
                # a dotted submodule name is still detectably deep
                if e.module.count(".") >= 2:
                    yield Finding("layering", mod.path, e.lineno, e.col,
                                  f"benchmark deep-imports {e.module}; "
                                  "import from the package __init__ "
                                  "exports instead")
                continue
            if not target.path.endswith("__init__.py"):
                yield Finding("layering", mod.path, e.lineno, e.col,
                              f"benchmark deep-imports {e.module}; "
                              "import from the package __init__ exports "
                              "instead")
                continue
            exported = _exported_names(target)
            for n in e.names:
                if n == "*" or n in exported:
                    continue
                # `from repro import serving`-style subpackage pulls
                if project.module(f"{e.module}.{n}") is not None:
                    continue
                yield Finding("layering", mod.path, e.lineno, e.col,
                              f"benchmark imports {n!r} which "
                              f"{e.module}.__init__ does not export")
