"""Approximate intra-project call graph over the parsed AST.

Sound-ish and deliberately over-approximate: the hot-path and lock-order
checkers need "could f reach g", not a points-to analysis.  Resolution
strategy per call site, in decreasing precision:

1. ``name(...)``      — the enclosing module's imports and module-level
                        defs (``from repro.core.packed import join_masked``
                        binds ``join_masked`` to that function);
2. ``x.meth(...)``    — when ``x`` is a local assigned from a resolvable
                        project-class constructor, or a parameter whose
                        annotation names a project class, ``meth`` within
                        that class's hierarchy;
3. ``self.meth(...)`` — ``meth`` anywhere in the enclosing class's
                        hierarchy (ancestors *and* descendants — ``self``
                        may be any subclass);
4. ``self.attr.meth`` — when any method of the class assigns ``self.attr``
                        from a project-class constructor or a typed
                        parameter, ``meth`` within that class's hierarchy
                        (this is what carries cross-module edges like
                        ``IndexManager._adapt -> SwappableEngine.swap``);
5. ``mod.fn(...)``    — when ``mod`` names an imported project module,
                        ``fn`` at that module's top level;
6. ``obj.meth(...)``  — fallback: every project function/method named
                        ``meth`` (the over-approximation that keeps
                        reachability conservative).

``precise=True`` drops step 6: callers that must not invent edges (the
lock-order checker, where a coincidental method name would fabricate a
deadlock) trade recall for zero name-collision noise.

Dunder calls other than ``__init__``/``__enter__``/``__exit__`` are not
resolved (fallback noise outweighs the coverage).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .loader import Module, Project

_RESOLVED_DUNDERS = {"__init__", "__enter__", "__exit__"}


@dataclasses.dataclass
class FuncInfo:
    """One function or method definition."""

    qname: str                  # "repro.serving.batcher:CoalescingBatcher.submit"
    module: Module
    node: ast.AST               # FunctionDef | AsyncFunctionDef
    cls: Optional[str]          # enclosing class name, or None

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def lineno(self) -> int:
        return self.node.lineno


@dataclasses.dataclass
class ClassInfo:
    module: Module
    node: ast.ClassDef
    bases: List[str]            # base names as written (dotted tail)
    methods: Dict[str, FuncInfo]


class CallGraph:
    """Project-wide def tables + per-function callee resolution."""

    def __init__(self, project: Project, precise: bool = False):
        self.project = project
        self.precise = precise
        self.funcs: Dict[str, FuncInfo] = {}
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self.classes: Dict[str, ClassInfo] = {}      # "module:Class"
        self.class_by_name: Dict[str, List[ClassInfo]] = {}
        self._callees: Dict[str, Set[str]] = {}
        self._attr_types_cache: Dict[str, Dict[str, str]] = {}
        for mod in project.modules:
            self._index_module(mod)
        self._subclasses = self._build_hierarchy()

    # ------------------------------------------------------------- indexing
    def _index_module(self, mod: Module) -> None:
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(mod, node, cls=None)
            elif isinstance(node, ast.ClassDef):
                bases = [self._base_name(b) for b in node.bases]
                ci = ClassInfo(module=mod, node=node,
                               bases=[b for b in bases if b], methods={})
                key = f"{mod.name}:{node.name}"
                self.classes[key] = ci
                self.class_by_name.setdefault(node.name, []).append(ci)
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        fi = self._add_func(mod, sub, cls=node.name)
                        ci.methods[sub.name] = fi

    def _add_func(self, mod: Module, node: ast.AST,
                  cls: Optional[str]) -> FuncInfo:
        qual = f"{cls}.{node.name}" if cls else node.name
        fi = FuncInfo(qname=f"{mod.name}:{qual}", module=mod, node=node,
                      cls=cls)
        self.funcs[fi.qname] = fi
        self.by_name.setdefault(node.name, []).append(fi)
        return fi

    @staticmethod
    def _base_name(node: ast.expr) -> str:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Attribute):
            return node.attr
        return ""

    def _build_hierarchy(self) -> Dict[str, Set[str]]:
        """class name -> transitive subclass names (project-wide)."""
        children: Dict[str, Set[str]] = {}
        for ci in self.classes.values():
            for b in ci.bases:
                children.setdefault(b, set()).add(ci.node.name)
        closed: Dict[str, Set[str]] = {}
        for name in list(children):
            seen: Set[str] = set()
            stack = [name]
            while stack:
                for c in children.get(stack.pop(), ()):
                    if c not in seen:
                        seen.add(c)
                        stack.append(c)
            closed[name] = seen
        return closed

    def hierarchy(self, cls_name: str) -> Set[str]:
        """``cls_name`` + its project ancestors and descendants, by name."""
        out = {cls_name}
        # ancestors
        frontier = [cls_name]
        while frontier:
            n = frontier.pop()
            for ci in self.class_by_name.get(n, ()):
                for b in ci.bases:
                    if b not in out:
                        out.add(b)
                        frontier.append(b)
        # descendants (of everything gathered so far, incl. ancestors'
        # other subtrees — self may be any sibling implementation)
        for n in list(out):
            out |= self._subclasses.get(n, set())
        return out

    # ----------------------------------------------------------- resolution
    def _module_bindings(self, mod: Module) -> Dict[str, List[FuncInfo]]:
        """name -> project functions bound at ``mod``'s top level."""
        out: Dict[str, List[FuncInfo]] = {}
        for e in mod.imports:
            target = self.project.module(e.module)
            for n in e.names:
                if target is not None:
                    fi = self.funcs.get(f"{target.name}:{n}")
                    if fi is not None:
                        out.setdefault(n, []).append(fi)
                    for ci in self.class_by_name.get(n, ()):
                        if ci.module is target:
                            init = ci.methods.get("__init__")
                            if init is not None:
                                out.setdefault(n, []).append(init)
                # `from pkg import name` where name re-exported by __init__
                elif e.module and self.project.module(e.module) is None:
                    pass
        for fi in self.funcs.values():
            if fi.module is mod and fi.cls is None:
                out.setdefault(fi.name, []).append(fi)
        return out

    def _ann_class(self, ann: Optional[ast.expr]) -> str:
        """Project class named by an annotation node, or ''."""
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            name = ann.value.rpartition(".")[2].rpartition("[")[0] or \
                ann.value.rpartition(".")[2]
        elif isinstance(ann, ast.Name):
            name = ann.id
        elif isinstance(ann, ast.Attribute):
            name = ann.attr
        elif isinstance(ann, ast.Subscript):     # Optional[X] / list[X]
            return self._ann_class(ann.slice)
        else:
            return ""
        return name if name in self.class_by_name else ""

    def _ctor_class(self, value: ast.expr) -> str:
        """Project class constructed by ``value``, or ''.

        Sees through ``A(...) if cond else b`` / ``x or A(...)`` — the
        repo's lazy-default idiom (``obs.Telemetry() if telemetry is None
        else telemetry``) types the attribute by the constructed branch.
        """
        if isinstance(value, ast.IfExp):
            return self._ctor_class(value.body) or \
                self._ctor_class(value.orelse)
        if isinstance(value, ast.BoolOp):
            for v in value.values:
                cname = self._ctor_class(v)
                if cname:
                    return cname
            return ""
        if not isinstance(value, ast.Call):
            return ""
        callee = value.func
        cname = ""
        if isinstance(callee, ast.Name):
            cname = callee.id
        elif isinstance(callee, ast.Attribute):
            cname = callee.attr
        return cname if cname in self.class_by_name else ""

    def _expr_type(self, fn: FuncInfo, expr: ast.expr,
                   local_types: Dict[str, str]) -> str:
        """Project class an expression evaluates to, or '' (recursive:
        folds ``self.a.b`` chains through :meth:`attr_types`)."""
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return fn.cls or ""
            return local_types.get(expr.id, "")
        if isinstance(expr, ast.Attribute):
            base_t = self._expr_type(fn, expr.value, local_types)
            if base_t:
                for cn in self.hierarchy(base_t):
                    hit = self.attr_types(cn).get(expr.attr, "")
                    if hit:
                        return hit
            return ""
        return self._ctor_class(expr)

    def _local_types(self, fn: FuncInfo) -> Dict[str, str]:
        """local var -> class name: ``x = SomeClass(...)`` assignments plus
        parameters annotated with a project class."""
        out: Dict[str, str] = {}
        args = fn.node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + [x for x in (args.vararg, args.kwarg) if x]):
            cname = self._ann_class(a.annotation)
            if cname:
                out[a.arg] = cname
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign):
                cname = self._ctor_class(node.value)
                if cname:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = cname
            elif isinstance(node, ast.AnnAssign) and node.target and \
                    isinstance(node.target, ast.Name):
                cname = self._ann_class(node.annotation) or \
                    (self._ctor_class(node.value) if node.value else "")
                if cname:
                    out[node.target.id] = cname
        # second pass: locals assigned from typed attribute chains
        # (``tel = self.server.telemetry``) resolve against the map so far
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Attribute):
                cname = self._expr_type(fn, node.value, out)
                if cname:
                    for t in node.targets:
                        if isinstance(t, ast.Name) and t.id not in out:
                            out[t.id] = cname
        return out

    def attr_types(self, cls_name: str) -> Dict[str, str]:
        """``self.attr`` -> class name, over the class's own methods.

        An attribute gets a type when some method assigns it from a
        project-class constructor (``self._engine = SwappableEngine(...)``)
        or from a parameter annotated with a project class
        (``def __init__(self, engine: SwappableEngine): self._e = engine``).
        Conflicting assignments drop the attribute (unknown beats wrong).
        """
        cached = self._attr_types_cache.get(cls_name)
        if cached is not None:
            return cached
        # cache the (mutable) dict up front: recursive lookups through
        # _expr_type terminate on the partial map instead of recursing
        out: Dict[str, str] = {}
        self._attr_types_cache[cls_name] = out
        dropped: Set[str] = set()

        def note(attr: str, cname: str) -> None:
            if attr in dropped:
                return
            if attr in out and out[attr] != cname:
                del out[attr]
                dropped.add(attr)
            else:
                out[attr] = cname

        for ci in self.class_by_name.get(cls_name, ()):
            for meth in ci.methods.values():
                local = self._local_types(meth)
                for node in ast.walk(meth.node):
                    targets: List[ast.expr] = []
                    value: Optional[ast.expr] = None
                    if isinstance(node, ast.Assign):
                        targets, value = node.targets, node.value
                    elif isinstance(node, ast.AnnAssign):
                        targets, value = [node.target], node.value
                    for t in targets:
                        if not (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"):
                            continue
                        if isinstance(node, ast.AnnAssign):
                            cname = self._ann_class(node.annotation)
                            if cname:
                                note(t.attr, cname)
                                continue
                        if value is None:
                            continue
                        cname = self._ctor_class(value)
                        if not cname and isinstance(value, ast.Name):
                            cname = local.get(value.id, "")
                        if cname:
                            note(t.attr, cname)
        return out

    def resolve_call(self, fn: FuncInfo, call: ast.Call,
                     bindings: Dict[str, List[FuncInfo]],
                     local_types: Dict[str, str]) -> List[FuncInfo]:
        f = call.func
        if isinstance(f, ast.Name):
            if f.id in bindings:
                return bindings[f.id]
            # constructor by class name in scope
            hits: List[FuncInfo] = []
            for ci in self.class_by_name.get(f.id, ()):
                init = ci.methods.get("__init__")
                if init is not None:
                    hits.append(init)
            return hits
        if not isinstance(f, ast.Attribute):
            return []
        meth = f.attr
        if meth.startswith("__") and meth not in _RESOLVED_DUNDERS:
            return []
        base = f.value
        if isinstance(base, ast.Name):
            if base.id == "self" and fn.cls is not None:
                return self._methods_in(self.hierarchy(fn.cls), meth)
            if base.id in local_types:
                return self._methods_in(self.hierarchy(local_types[base.id]),
                                        meth)
            # imported project module: mod.fn(...)
            target = self._imported_module(fn.module, base.id)
            if target is not None:
                fi = self.funcs.get(f"{target.name}:{meth}")
                return [fi] if fi is not None else []
        # typed attribute chains: self.attr.meth(...), x.attr.meth(...)
        if isinstance(base, ast.Attribute):
            cname = self._expr_type(fn, base, local_types)
            if cname:
                return self._methods_in(self.hierarchy(cname), meth)
        if self.precise:
            return []
        # fallback: any project def with this method name
        return list(self.by_name.get(meth, ()))

    def _imported_module(self, mod: Module, alias: str) -> Optional[Module]:
        for e in mod.imports:
            if not e.names and (e.module == alias
                                or e.module.endswith("." + alias)):
                return self.project.module(e.module)
            if e.names and alias in e.names:
                sub = f"{e.module}.{alias}" if e.module else alias
                m = self.project.module(sub)
                if m is not None:
                    return m
        return None

    def _methods_in(self, class_names: Set[str], meth: str) -> List[FuncInfo]:
        out = []
        for cn in class_names:
            for ci in self.class_by_name.get(cn, ()):
                fi = ci.methods.get(meth)
                if fi is not None:
                    out.append(fi)
        return out

    # ------------------------------------------------------------- traversal
    def callees(self, fn: FuncInfo) -> Set[str]:
        """qnames of functions ``fn`` may call (cached)."""
        cached = self._callees.get(fn.qname)
        if cached is not None:
            return cached
        bindings = self._module_bindings(fn.module)
        local_types = self._local_types(fn)
        out: Set[str] = set()
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                for callee in self.resolve_call(fn, node, bindings,
                                                local_types):
                    out.add(callee.qname)
        self._callees[fn.qname] = out
        return out

    def reachable(self, seeds: List[FuncInfo]) -> Dict[str, List[str]]:
        """qname -> one call path from a seed, for every reachable func."""
        paths: Dict[str, List[str]] = {}
        frontier: List[Tuple[FuncInfo, List[str]]] = \
            [(s, [s.qname]) for s in seeds]
        for s, p in frontier:
            paths.setdefault(s.qname, p)
        while frontier:
            fn, path = frontier.pop()
            for q in self.callees(fn):
                if q in paths:
                    continue
                nxt = self.funcs.get(q)
                if nxt is None:
                    continue
                paths[q] = path + [q]
                frontier.append((nxt, path + [q]))
        return paths

    def iter_calls(self, fn: FuncInfo) -> Iterator[ast.Call]:
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Call):
                yield node
