"""Whisper large-v3 — enc-dec audio backbone (conv frontend stubbed).

[arXiv:2212.04356; unverified]  32L decoder + 32L encoder, d_model=1280
20H d_ff=5120 vocab=51866; encoder sees 1500 precomputed frame embeddings
(input_specs stub per the brief).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    encdec=True, enc_layers=32, enc_seq=1500,
    rope="rope", act="gelu", tie_embeddings=True,
)
