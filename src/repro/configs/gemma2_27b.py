"""Gemma-2 27B — local/global alternating attention + logit softcaps.

[arXiv:2408.00118; hf]  46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000, window 4096 on local layers, every 2nd layer global,
attn softcap 50, final-logit softcap 30.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    local_window=4096, global_every=2,
    softcap_attn=50.0, softcap_logits=30.0,
    act="gelu_glu", tie_embeddings=True, embed_scale=True,
)
