"""Llama-4 Scout 17B-active/16E — MoE, early fusion (text backbone).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, 16 routed experts top-1 + 1 shared.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    moe=True, n_experts=16, topk=1, n_shared=1, moe_d_ff=8192,
    n_dense_layers=0, router="sigmoid",
    rope_theta=500000.0, act="silu_glu", tie_embeddings=False,
)
