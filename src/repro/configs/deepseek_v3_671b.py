"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(moe expert)=2048
vocab=129280; first 3 layers dense (d_ff=18432); MLA: q_lora 1536,
kv_lora 512, qk_nope 128, qk_rope 64, v 128.
"""

from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=18432, vocab=129280,
    attn="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=True, n_experts=256, topk=8, n_shared=1, moe_d_ff=2048,
    n_dense_layers=3, router="sigmoid", mtp=True,
    act="silu_glu", tie_embeddings=False,
)
