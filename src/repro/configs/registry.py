"""Architecture registry: ``--arch <id>`` resolution + per-arch input specs.

Each assigned architecture lives in its own module exporting ``CONFIG``; the
registry also owns the (arch x shape) dry-run cell enumeration and the
``input_specs`` ShapeDtypeStruct builders (no allocation — the dry-run
pattern)."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models.config import LM_SHAPES, ModelConfig, ShapeSpec, shape_applicable

ARCH_IDS = (
    "llama4-scout-17b-a16e",
    "deepseek-v3-671b",
    "tinyllama-1.1b",
    "nemotron-4-340b",
    "gemma2-27b",
    "gemma3-12b",
    "hymba-1.5b",
    "mamba2-780m",
    "qwen2-vl-72b",
    "whisper-large-v3",
)

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch]}")
    return mod.CONFIG


def all_cells():
    """Yield (arch, shape, runs, reason) for the 10 x 4 dry-run matrix."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for shape in LM_SHAPES:
            runs, reason = shape_applicable(cfg, shape)
            yield a, shape, runs, reason


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.encdec:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
        if cfg.encdec:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of S positions
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}
