"""Mamba-2 780M — attention-free SSD. [arXiv:2405.21060; unverified]
48L d_model=1536 vocab=50280, state N=128, expand 2 (d_inner 3072,
head P=64 -> 48 ssd heads).  Sub-quadratic (runs long_500k)."""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    attn="none", rope="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, n_heads=48, expand=2,
                  chunk=256, conv_width=4),
    act="silu_glu", tie_embeddings=True,
)
