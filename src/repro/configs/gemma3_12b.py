"""Gemma-3 12B — 5:1 local:global, 128k context, qk-norm.

[hf:google/gemma-3-1b-pt scaled family; unverified]  48L d_model=3840 16H
(GQA kv=8) d_ff=15360 vocab=262144, window 1024, every 6th layer global.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    local_window=1024, global_every=6, qk_norm=True,
    rope_theta=1000000.0,
    act="gelu_glu", tie_embeddings=True, embed_scale=True,
)
