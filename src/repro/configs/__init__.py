"""Per-architecture configs (exact published dims) + the paper's own suites."""

from .registry import ARCH_IDS, all_cells, get_config, input_specs  # noqa: F401
