"""Hymba 1.5B — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Sub-quadratic (runs long_500k).
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    local_window=1024, global_every=16,   # hymba: most layers SWA + few global
    ssm=SSMConfig(state_dim=16, head_dim=50, n_heads=32, expand=2,
                  chunk=128, conv_width=4),
    act="silu_glu", tie_embeddings=True,
)
