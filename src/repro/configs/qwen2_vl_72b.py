"""Qwen2-VL 72B — M-RoPE VLM backbone (vision frontend stubbed).

[arXiv:2409.12191; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  input_specs feed token ids (text path); patch embeddings
enter via ``forward(embeds=...)`` in the examples.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    rope="mrope", rope_theta=1000000.0,
    act="silu_glu", tie_embeddings=False,
)
