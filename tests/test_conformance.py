"""Engine-identity conformance table (ISSUE-7 satellite).

Every backend x slab-layout combination answers the same query set; the
jnp-jit f32 bucketed engine is the reference.  f32 backends must match it
bitwise; quantized (bf16) backends must keep distances inside the
documented ``2 * qerr`` bound while covis verdicts and via/hub argmin
winners stay bitwise-identical (the residual-rescue guarantee).  A
separate test anchors the reference itself against the exact float64 host
oracle, so bitwise agreement is agreement with a *correct* answer.
"""

import numpy as np
import pytest

from conftest import ConformanceHarness

# (backend, layout) case table; host is f64 + argmin-less so only the f32
# distance column applies to it
CASES = [(b, l) for b in ConformanceHarness.BACKENDS
         for l in ConformanceHarness.LAYOUTS
         if not (b == "host" and l != "f32")]

HOST_TOL = 1e-4      # f32 engine vs f64 oracle, relative
REL_SLOP = 1e-4      # f32 accumulation slop on top of the 2*qerr bound


def _ids(case):
    return f"{case[0]}-{case[1]}"


def test_baseline_matches_host_oracle(conformance):
    """The reference column itself is correct, not merely self-consistent."""
    d = conformance.baseline[0]
    truth = conformance.truth
    fin = np.isfinite(truth)
    assert np.array_equal(fin, np.isfinite(d))
    np.testing.assert_allclose(d[fin], truth[fin], rtol=HOST_TOL,
                               atol=HOST_TOL)


@pytest.mark.parametrize("case", CASES, ids=_ids)
def test_distances_conform(conformance, case):
    backend, layout = case
    d = conformance.run(backend, layout)[0]
    base = conformance.baseline[0]
    fin = np.isfinite(base)
    assert np.array_equal(fin, np.isfinite(d)), \
        f"{backend}/{layout}: reachability verdicts differ from reference"
    if backend == "host":
        np.testing.assert_allclose(d[fin], base[fin], rtol=HOST_TOL,
                                   atol=HOST_TOL)
    elif backend == "jnp" and layout == "f32":
        # eager mode: same math, but XLA fusion in the jitted reference
        # reassociates float adds — ulp-level drift, not an identity target
        np.testing.assert_allclose(d[fin], base[fin], rtol=1e-5, atol=1e-5)
    elif layout == "f32":
        np.testing.assert_array_equal(d, base)
    else:
        bound = 2.0 * conformance.qerr(layout) + REL_SLOP * np.abs(base[fin])
        err = np.abs(d[fin] - base[fin])
        assert np.all(err <= bound + 1e-6), \
            (f"{backend}/{layout}: max distance error {err.max():.3e} over "
             f"the quantization bound")


@pytest.mark.parametrize("case", [c for c in CASES if c[0] != "host"],
                         ids=_ids)
def test_argmin_conforms(conformance, case):
    """covis + via/hub winners bitwise across ALL backends and layouts —
    quantized rows with ambiguous margins must have been rescued."""
    backend, layout = case
    d, covis, via_s, hub, via_t = conformance.run(backend, layout)
    bd, bcv, bvs, bhb, bvt = conformance.baseline
    assert np.array_equal(covis, bcv), \
        f"{backend}/{layout}: co-visibility verdicts differ"
    m = ~bcv & np.isfinite(bd)
    for name, got, ref in (("via_s", via_s, bvs), ("hub", hub, bhb),
                           ("via_t", via_t, bvt)):
        assert np.array_equal(got[m], ref[m]), \
            f"{backend}/{layout}: argmin {name} winners differ"


def test_quantized_actually_shrinks(conformance):
    """The table is only meaningful if bf16 really packs a different
    (smaller) artifact rather than silently falling back to f32."""
    b32 = conformance.bucketed("f32").device_bytes()
    bq = conformance.bucketed("bf16").device_bytes()
    assert bq < b32
    assert conformance.qerr("bf16") > 0.0
