"""Sharded serving: planner balance, (shard, bucket) routing, bitwise
identity vs the single-device engine, and atomic multi-shard hot-swap.

Most tests run on the single real CPU device (conftest rule) with shards
round-robined onto it — the routing/merging/transfer code paths are
identical, the device_puts just degenerate to same-device copies.  The
acceptance gate (true 4-device mesh, 1k random queries, swap under load)
runs in a subprocess with ``--xla_force_host_platform_device_count=4``.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import (bucketed_device_bytes, pack_bucketed,
                               query_batch_bucketed)
from repro.core.workload import cluster_queries, uniform_queries
from repro.indexing import IndexManager
from repro.serving.engine import PathServer
from repro.sharding import (ShardPlanner, ShardedQueryEngine,
                            sharded_overhead_bytes)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N_SHARDS = 4


@pytest.fixture(scope="module")
def sharded_setup(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    compress_to_fraction(idx, 0.3)
    bx = pack_bucketed(idx)
    planner = ShardPlanner(N_SHARDS)
    sharded = planner.build(idx)
    return idx, bx, sharded


# ----------------------------------------------------------------- planner

def test_planner_balances_and_covers(sharded_setup):
    idx, bx, sharded = sharded_setup
    plan = sharded.plan
    assert plan.num_shards == N_SHARDS
    # every region placed, every shard non-empty
    assert plan.assignment.shape == (bx.num_regions,)
    assert sorted(np.unique(plan.assignment)) == list(range(N_SHARDS))
    # predicted slab balance within tolerance
    assert plan.imbalance <= plan.tol + 1e-9
    # realized per-shard device bytes within the acceptance bound
    per = sharded.per_shard_bytes()
    assert max(per) <= 1.15 * sharded.device_bytes() / N_SHARDS
    # label data is partitioned, not replicated: summed slab slots match
    used_sharded = sum(s.label_slots()[0] for s in sharded.shards)
    assert used_sharded == bx.label_slots()[0]


def test_planner_rejects_more_shards_than_regions(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    with pytest.raises(ValueError):
        ShardPlanner(10 ** 6).plan(idx)


# ------------------------------------------------------- routing + identity

def test_sharded_answers_bitwise_identical(sharded_setup, scene_s, graph_s):
    _, bx, sharded = sharded_setup
    eng = ShardedQueryEngine(sharded)
    qs = uniform_queries(scene_s, graph_s, 400, seed=3, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    ref = np.asarray(query_batch_bucketed(bx, s, t))
    out = eng.query(s, t)
    assert np.array_equal(np.isfinite(ref), np.isfinite(out))
    np.testing.assert_array_equal(np.where(np.isfinite(ref), ref, 0),
                                  np.where(np.isfinite(out), out, 0))
    # and through the full PathServer stack (fixed-shape padded batches)
    srv = PathServer(ShardedQueryEngine(sharded), batch_size=64)
    srv.warmup()
    d = srv.query(s, t)
    np.testing.assert_array_equal(np.where(np.isfinite(ref), ref, 0),
                                  np.where(np.isfinite(d), d, 0))
    assert len(srv.stats.per_shard) == N_SHARDS


def test_sharded_argmin_matches_single_device(sharded_setup, scene_s,
                                              graph_s):
    _, bx, sharded = sharded_setup
    eng = ShardedQueryEngine(sharded)
    qs = uniform_queries(scene_s, graph_s, 60, seed=5, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    ref = query_batch_bucketed(bx, s, t, want_argmin=True)
    out = eng.query(s, t, want_argmin=True)
    for r, o in zip(ref, out):
        r = np.asarray(r)
        fin = np.isfinite(r) if r.dtype.kind == "f" else np.ones_like(r, bool)
        np.testing.assert_array_equal(np.where(fin, r, 0),
                                      np.where(fin, np.asarray(o), 0))


def test_all_queries_on_one_shard_leaves_others_idle(sharded_setup, scene_s,
                                                     graph_s):
    """Single-destination batch: one shard serves, the rest see no
    sub-batch at all (the 'empty shard sub-batch' edge case)."""
    _, bx, sharded = sharded_setup
    eng = ShardedQueryEngine(sharded)
    qs = uniform_queries(scene_s, graph_s, 300, seed=9, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    keys = eng.buckets_of(s, t)
    # pick the busiest destination shard and keep only its queries
    dest = np.array([eng.router.decode_key(int(k))[0] for k in keys])
    k = np.bincount(dest, minlength=N_SHARDS).argmax()
    m = dest == k
    assert m.sum() >= 3
    out = eng.query(s[m], t[m])
    ref = np.asarray(query_batch_bucketed(bx, s[m], t[m]))
    np.testing.assert_array_equal(np.where(np.isfinite(ref), ref, 0),
                                  np.where(np.isfinite(out), out, 0))
    st = eng.shard_stats()
    for j in range(N_SHARDS):
        if j != k:
            assert st[j].batches == 0 and st[j].slots == 0
    assert st[k].batches >= 1 and st[k].slots == int(m.sum())


def test_merge_preserves_input_order(sharded_setup, scene_s, graph_s):
    _, bx, sharded = sharded_setup
    eng = ShardedQueryEngine(sharded)
    qs = uniform_queries(scene_s, graph_s, 200, seed=13, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    base = eng.query(s, t)
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(s))
    shuffled = eng.query(s[perm], t[perm])
    np.testing.assert_array_equal(
        np.where(np.isfinite(base[perm]), base[perm], 0),
        np.where(np.isfinite(shuffled), shuffled, 0))


def test_cross_shard_queries_exist_and_match(sharded_setup, scene_s,
                                             graph_s):
    """Random endpoints must exercise the cross-shard gather path."""
    _, bx, sharded = sharded_setup
    eng = ShardedQueryEngine(sharded)
    qs = uniform_queries(scene_s, graph_s, 200, seed=17, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    keys = eng.buckets_of(s, t)
    pairs = {eng.router.decode_key(int(k))[:2] for k in keys}
    assert any(i != j for i, j in pairs), "no cross-shard traffic routed"
    eng.query(s, t)
    assert sum(st.gathers_out for st in eng.shard_stats()) > 0


def test_sharded_async_submit_matches_sync(sharded_setup, scene_s, graph_s):
    """The continuous-batching loop over the sharded engine (split-phase
    stage/join with cross-shard gathers overlapping the in-flight join)
    answers bitwise-identically to the synchronous sharded path."""
    _, _, sharded = sharded_setup
    srv = PathServer(ShardedQueryEngine(sharded), batch_size=32)
    srv.warmup()
    qs = uniform_queries(scene_s, graph_s, 150, seed=23, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    keys = srv.engine.buckets_of(s, t)
    assert any(srv.engine.router.decode_key(int(k))[0]
               != srv.engine.router.decode_key(int(k))[1]
               for k in keys), "no cross-shard traffic to pipeline"
    ref = srv.query(s, t)
    tickets = [srv.submit(s[i], t[i]) for i in range(len(s))]
    srv.flush()
    assert srv.drain(timeout=120)
    got = np.concatenate([tk.result(timeout=1) for tk in tickets])
    srv.stop_async()
    np.testing.assert_array_equal(ref, got)
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0
    assert len(srv.stats.per_shard) == N_SHARDS


# ------------------------------------------------------------ swap behavior

def test_pinned_generation_consistent_during_sharded_swap(scene_s, graph_s,
                                                          hl_s):
    """A request pinned before a multi-shard swap must resolve every call
    (routing + all sub-batches) against the old shard set; the swap flips
    all shards at once for new requests."""
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5) \
        + sharded_overhead_bytes(idx, N_SHARDS)
    mgr = IndexManager(idx, budget, batch_size=32, min_queries=60,
                       replan_threshold=0.10, min_dwell=0, probe_n=16,
                       num_shards=N_SHARDS, seed=13)
    qs = cluster_queries(scene_s, graph_s, 2, 150, seed=31,
                         require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    mgr.recorder.record(s, t)

    old_engine = mgr.engine.current
    old_index = old_engine.index
    cm = mgr.engine.pin()
    pinned = cm.__enter__()                  # in-flight request, gen 0
    assert pinned is old_engine

    assert mgr.maybe_adapt() is True         # swap published under load
    assert mgr.generation == 1
    new_engine = mgr.engine.current
    assert new_engine is not old_engine
    assert new_engine.index is not old_index
    # one generation across ALL shards: the new engine's shard set is
    # entirely new, the pinned one's entirely old — no mixed set exists
    assert all(a is not b for a, b in zip(new_engine.index.shards,
                                          old_index.shards))
    assert pinned.index is old_index
    d_old = pinned.query(s[:40], t[:40])     # still served by the old set
    d_new = mgr.engine.query(s[:40], t[:40])
    fin = np.isfinite(d_old)
    np.testing.assert_array_equal(fin, np.isfinite(d_new))
    np.testing.assert_array_equal(np.where(fin, d_old, 0),
                                  np.where(fin, d_new, 0))
    assert mgr.engine.retired_generations() == [0]
    cm.__exit__(None, None, None)            # drain -> old shard set freed
    assert mgr.engine.retired_generations() == []
    assert mgr.engine.drops == 1


def test_path_server_requests_never_mix_generations(scene_s, graph_s, hl_s,
                                                    monkeypatch):
    """Every engine call inside one PathServer request hits one engine
    object even when a swap lands mid-request."""
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5) \
        + sharded_overhead_bytes(idx, N_SHARDS)
    mgr = IndexManager(idx, budget, batch_size=16, min_queries=40,
                       replan_threshold=0.10, min_dwell=0, probe_n=8,
                       num_shards=N_SHARDS, seed=5)
    srv = PathServer(mgr.engine, batch_size=16, recorder=mgr.recorder)

    served_by: list = []
    orig = ShardedQueryEngine.batch

    def spy(self, s, t, bucket=0):
        served_by.append(id(self))
        if len(served_by) == 2:
            # a swap lands while this request is mid-flight
            qs = cluster_queries(scene_s, graph_s, 2, 80, seed=61,
                                 require_path=False)
            mgr.recorder.record(qs.s, qs.t)
            assert mgr.maybe_adapt() is True
        return orig(self, s, t, bucket=bucket)

    monkeypatch.setattr(ShardedQueryEngine, "batch", spy)
    qs = uniform_queries(scene_s, graph_s, 120, seed=7, require_path=False)
    srv.query(qs.s.astype(np.float32), qs.t.astype(np.float32))
    assert len(served_by) >= 3                  # several sub-batches
    assert len(set(served_by)) == 1             # ...all on one generation
    assert mgr.generation == 1
    assert srv.stats.stale_batches > 0          # observed as stale, not mixed


# ------------------------------------------------ acceptance: 4-device mesh

def test_sharded_acceptance_on_forced_4_device_mesh():
    """The ISSUE gate, on a real (forced) 4-device host platform: answers
    bitwise-identical to the single-device engine on >= 1k random queries,
    per-shard bytes within 1.15x of fair share, one shard per device, and
    a hot-swap under load publishing one generation."""
    code = textwrap.dedent("""
        import numpy as np
        import jax
        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.maps import make_map
        from repro.core.visgraph import build_visgraph
        from repro.core.hublabel import build_hub_labels
        from repro.core.grid import build_ehl
        from repro.core.compression import compress_to_fraction
        from repro.core.packed import (bucketed_device_bytes, pack_bucketed,
                                       query_batch_bucketed)
        from repro.core.workload import cluster_queries, uniform_queries
        from repro.indexing import IndexManager
        from repro.launch.mesh import make_serving_mesh
        from repro.serving import PathServer
        from repro.sharding import (ShardPlanner, ShardedQueryEngine,
                                    sharded_overhead_bytes)

        scene = make_map("rooms-S", seed=1)
        graph = build_visgraph(scene)
        hl = build_hub_labels(graph)
        idx = build_ehl(scene, 2.0, graph=graph, hl=hl)
        compress_to_fraction(idx, 0.3)
        bx = pack_bucketed(idx)
        mesh = make_serving_mesh(4)
        sharded = ShardPlanner(4).build(idx)
        eng = ShardedQueryEngine(sharded, mesh=mesh)
        # one shard per distinct mesh device
        devs = {str(d) for d in eng.router.devices}
        assert len(devs) == 4, devs
        per = sharded.per_shard_bytes()
        assert max(per) <= 1.15 * sharded.device_bytes() / 4, per

        qs = uniform_queries(scene, graph, 1000, seed=42,
                             require_path=False)
        s = qs.s.astype(np.float32); t = qs.t.astype(np.float32)
        ref = np.asarray(query_batch_bucketed(bx, s, t))
        out = eng.query(s, t)
        fin = np.isfinite(ref)
        assert np.array_equal(fin, np.isfinite(out))
        assert np.array_equal(np.where(fin, ref, 0), np.where(fin, out, 0))

        # hot-swap under load: requests keep flowing while the manager
        # builds/validates/swaps; answers stay bitwise-stable and exactly
        # one generation is published across all four shards
        idx2 = build_ehl(scene, 2.0, graph=graph, hl=hl)
        budget = int(bucketed_device_bytes(idx2) * 0.5) \\
            + sharded_overhead_bytes(idx2, 4)
        mgr = IndexManager(idx2, budget, batch_size=64, min_queries=60,
                           replan_threshold=0.10, min_dwell=0, probe_n=32,
                           num_shards=4, mesh=mesh, seed=13,
                           validate_tol=0.0)
        srv = PathServer(mgr.engine, batch_size=64, recorder=mgr.recorder)
        srv.warmup()
        cq = cluster_queries(scene, graph, 2, 200, seed=31,
                             require_path=False)
        cs = cq.s.astype(np.float32); ct = cq.t.astype(np.float32)
        d0 = srv.query(cs, ct)
        mgr.maybe_adapt(block=False)         # swap off the serving path
        import time
        while mgr.swaps == 0:                # serve under load until it lands
            d = srv.query(cs, ct)
            f = np.isfinite(d0)
            assert np.array_equal(f, np.isfinite(d))
            assert np.array_equal(np.where(f, d0, 0), np.where(f, d, 0))
            mgr.join(timeout=0.05)
        mgr.join()
        d1 = srv.query(cs, ct)
        f = np.isfinite(d0)
        assert np.array_equal(np.where(f, d0, 0), np.where(f, d1, 0))
        assert mgr.generation == 1 and mgr.validation_failures == 0
        assert srv.stats.generation == 1
        assert max(mgr.engine.per_shard_bytes()) <= 1.15 * budget / 4
        print("SHARDED_ACCEPTANCE_OK")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_ACCEPTANCE_OK" in out.stdout


# ------------------------------------------------------- edge clipping (§10)

def _chambered_scene():
    """Four near-closed chambers around a center junction (>= 128 edges).

    Visibility — and therefore label via reach — is chamber-local except
    through the doors, so per-shard clipped edge subsets genuinely shrink:
    the regime the §10 shard edge clipping targets.  Open suite maps see
    map-wide, where clips legitimately keep everything.
    """
    from repro.core.geometry import Scene

    def rect(x0, y0, x1, y1):
        return np.array([[x0, y0], [x1, y0], [x1, y1], [x0, y1]], float)

    W = 120.0
    polys = [rect(58, 0, 62, 55), rect(58, 65, 62, 120),
             rect(0, 58, 55, 62), rect(65, 58, 120, 62)]
    rng = np.random.default_rng(0)
    for cx, cy in ((0, 0), (62, 0), (0, 62), (62, 62)):
        for i in range(12):
            x0 = cx + 4 + (i % 4) * 13 + rng.uniform(0, 3)
            y0 = cy + 4 + (i // 4) * 15 + rng.uniform(0, 3)
            w, h = rng.uniform(4, 7, 2)
            polys.append(rect(x0, y0, x0 + w, y0 + h))
    return Scene.build(polys, W, W)


def test_shard_edge_clipping_drops_bytes_and_stays_bitwise():
    """Per-shard edge subsets beat full replication on occluded maps.

    Asserts the §10 clip (a) keeps strictly fewer edges than replication
    on most shards, (b) drops summed edge bytes below the replicated
    baseline, and (c) never changes an answer — the clipped sharded engine
    is bitwise-identical to the single-device full-edge engine, which is
    the proof the clip boxes really cover every owned visibility segment.
    """
    from repro.core.visgraph import build_visgraph
    from repro.core.hublabel import build_hub_labels

    scene = _chambered_scene()
    E = scene.edges.shape[0]
    assert E >= 128          # above one lane, so clipping can change bytes
    graph = build_visgraph(scene)
    idx = build_ehl(scene, 4.0, graph=graph, hl=build_hub_labels(graph))
    bx = pack_bucketed(idx)
    full_edge_bytes = int(sum(np.prod(a.shape) * 4 for a in
                              (bx.edges_a, bx.edges_b, bx.edges_c))) + \
        (bx.grid.device_bytes() if bx.grid else 0)

    S = 12
    sharded = ShardPlanner(S).build(idx)
    kept = [int(m.sum()) for m in sharded.edge_masks]
    assert all(len(m) == E for m in sharded.edge_masks)
    assert sum(k < E for k in kept) >= S // 3, (
        f"clipping kept everything almost everywhere: {kept}")
    assert sum(sharded.edge_bytes()) < S * full_edge_bytes, (
        "summed clipped edge bytes did not beat full replication")

    qs = uniform_queries(scene, graph, 120, seed=3, require_path=False)
    s = qs.s.astype(np.float32)
    t = qs.t.astype(np.float32)
    ref = np.asarray(query_batch_bucketed(bx, s, t))
    out = ShardedQueryEngine(sharded).query(s, t)
    assert np.array_equal(np.isfinite(ref), np.isfinite(out))
    np.testing.assert_array_equal(np.where(np.isfinite(ref), ref, 0),
                                  np.where(np.isfinite(out), out, 0))
