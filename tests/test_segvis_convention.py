"""DESIGN.md §5 blocking convention: every backend, one answer.

Regression battery for the degenerate-geometry classes that historically
flipped between the host reference and the device predicate (collinear
overlap, segment anchored on an edge endpoint, through-vertex transversal,
all cross products in the zero band) — plus the compiler-robustness
regression: under jit, XLA contracts the cross-product ``t1 - t2`` into an
fma, and the old exact-zero sign tests turned vertex-anchored segments into
phantom proper crossings.  The banded predicate must classify identically
eager, jitted, in the Pallas kernel, and through the edge-grid path.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.geometry import (Scene, blocked_strict_batch,
                                 segments_block_strict, visible_batch)
from repro.core.edgegrid import build_edge_grid, segvis_grid
from repro.core.packed import _pack_edges, padded_edge_count
from repro.kernels import ops
from repro.kernels.segvis import segvis

SQ = Scene.build([np.array([[4.0, 4.0], [6.0, 4.0], [6.0, 6.0], [4.0, 6.0]])],
                 10.0, 10.0)

# (p, q, blocked) — hand-constructed degenerate contacts against SQ.
# Convention: touching != blocked, interior penetration = blocked.
DEGENERATE_CASES = [
    # collinear slide along the bottom edge (overlap, containment, partial)
    ([3.0, 4.0], [7.0, 4.0], False),
    ([4.0, 4.0], [6.0, 4.0], False),     # vertex-to-vertex along the edge
    ([5.0, 4.0], [7.0, 4.0], False),     # starts on the open edge, slides out
    # segment ending exactly on a corner (graze from outside)
    ([1.0, 1.0], [4.0, 4.0], False),
    ([7.0, 5.0], [6.0, 6.0], False),
    # segment ending exactly on an open edge, approaching from outside
    ([5.0, 1.0], [5.0, 4.0], False),
    # segment ending on the boundary after crossing the interior
    ([5.0, 1.0], [5.0, 6.0], True),
    # through-vertex transversal entering the corner wedge: through (4,6),
    # no proper edge crossing anywhere (both walls met exactly at the vertex)
    ([3.0, 8.0], [4.5, 5.0], True),
    ([3.9, 3.9], [6.1, 6.1], True),      # corner-to-corner diagonal
    # tangent line through a corner, staying outside the wedge
    ([3.0, 5.0], [5.0, 3.0], False),     # touches (4,4), both arms one side
    ([2.0, 6.0], [6.0, 2.0], False),     # longer tangent through (4,4)
    # near-tangent genuine crossing: clips the corner by 5e-5 — must stay
    # OUTSIDE the zero band (the band absorbs ulps, not real clearances)
    ([3.0, 5.0], [5.0, 3.0001], True),
    # proper crossing (sanity)
    ([1.0, 5.0], [9.0, 5.0], True),
]


def _edge_arrays(scene, dtype):
    return (scene.edges[:, 0].astype(dtype), scene.edges[:, 1].astype(dtype),
            scene.edge_next.astype(dtype))


def _all_backends(scene, P, Q):
    """Visibility verdicts of every §5 backend on float32-cast inputs."""
    A, B, C = _edge_arrays(scene, np.float32)
    P32 = P.astype(np.float32)
    Q32 = Q.astype(np.float32)
    args = tuple(map(jnp.asarray, (P32, Q32, A, B, C)))
    out = {
        "host-f64": ~segments_block_strict(P32, Q32, A, B, C).any(axis=1),
        "ref-eager": np.asarray(ops.segvis_ref(*args)),
        "ref-jit": np.asarray(jax.jit(ops.segvis_ref)(*args)),
        "kernel": np.asarray(segvis(*args, interpret=True)),
    }
    ea, eb, ec = _pack_edges(scene, lane=128)
    grid = build_edge_grid(ea, eb, scene.edges.shape[0], scene.width,
                           scene.height, sentinel=ea.shape[0] - 1)
    out["grid"] = np.asarray(segvis_grid(
        args[0], args[1], jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(ec),
        grid))
    out["grid-jit"] = np.asarray(jax.jit(
        lambda p, q: segvis_grid(p, q, jnp.asarray(ea), jnp.asarray(eb),
                                 jnp.asarray(ec), grid))(args[0], args[1]))
    return out


def test_degenerate_cases_agree_across_backends():
    P = np.array([c[0] for c in DEGENERATE_CASES], dtype=np.float64)
    Q = np.array([c[1] for c in DEGENERATE_CASES], dtype=np.float64)
    want_vis = ~np.array([c[2] for c in DEGENERATE_CASES])
    backends = _all_backends(SQ, P, Q)
    for name, got in backends.items():
        assert (got == want_vis).all(), (
            f"{name} disagrees at cases "
            f"{np.nonzero(got != want_vis)[0].tolist()}")
    # the midpoint-containment oracle realizes the same convention
    oracle = visible_batch(SQ, P, Q)
    assert (oracle == want_vis).all(), (
        f"oracle disagrees at {np.nonzero(oracle != want_vis)[0].tolist()}")


def test_strict_predicate_matches_oracle_on_exact_cases():
    """blocked_strict_batch is the sign-rule twin of the midpoint oracle."""
    P = np.array([c[0] for c in DEGENERATE_CASES], dtype=np.float64)
    Q = np.array([c[1] for c in DEGENERATE_CASES], dtype=np.float64)
    strict = ~blocked_strict_batch(SQ, P, Q)
    oracle = visible_batch(SQ, P, Q)
    assert (strict == oracle).all()


def test_containment_is_outside_the_predicate_contract():
    """A fully-interior segment crosses no edge — the sign rules pass it.

    The §5 predicate's precondition is that at least one endpoint lies in
    free space, which every engine segment satisfies (query points are
    free, vias are boundary vertices).  The midpoint oracle, which has no
    such precondition, blocks it.
    """
    P = np.array([[4.5, 5.0]])
    Q = np.array([[5.5, 5.0]])
    assert not visible_batch(SQ, P, Q)[0]
    assert not blocked_strict_batch(SQ, P, Q)[0]   # no crossing seen


def test_vertex_anchored_segments_stable_under_jit(scene_s):
    """The fma regression: segments ending exactly on polygon vertices.

    Via vertices ARE polygon corners, so every (query point -> via)
    visibility segment in the packed engine hits this class.  Before the
    banded signs, jit-compiled crosses carried few-ulp fma residuals where
    exact zeros were expected, flipping hundreds of vertex-anchored
    segments to "blocked".
    """
    rng = np.random.default_rng(7)
    V = scene_s.vertices.astype(np.float32)
    n = len(V)
    P = rng.uniform(0, [scene_s.width, scene_s.height],
                    (n, 2)).astype(np.float32)
    A, B, C = map(jnp.asarray, _edge_arrays(scene_s, np.float32))
    p, q = jnp.asarray(P), jnp.asarray(V)
    eager = np.asarray(ops.segvis_ref(p, q, A, B, C))
    jitted = np.asarray(jax.jit(ops.segvis_ref)(p, q, A, B, C))
    assert (eager == jitted).all(), (
        f"{(eager != jitted).sum()} vertex-anchored segments flip under jit")
    # and the f64 host twin agrees on the f32-cast coordinates
    host = ~segments_block_strict(P, np.asarray(V), np.asarray(A),
                                  np.asarray(B), np.asarray(C)).any(axis=1)
    assert (eager == host).all()


def test_vertex_to_vertex_segments_stable_under_jit(scene_s):
    """Path legs between convex corners — both endpoints degenerate."""
    V = scene_s.convex_vertices.astype(np.float32)
    rng = np.random.default_rng(11)
    i = rng.integers(0, len(V), 64)
    j = rng.integers(0, len(V), 64)
    A, B, C = map(jnp.asarray, _edge_arrays(scene_s, np.float32))
    p, q = jnp.asarray(V[i]), jnp.asarray(V[j])
    eager = np.asarray(ops.segvis_ref(p, q, A, B, C))
    jitted = np.asarray(jax.jit(ops.segvis_ref)(p, q, A, B, C))
    kernel = np.asarray(segvis(p, q, A, B, C, interpret=True))
    assert (eager == jitted).all()
    assert (eager == kernel).all()


# ---------------------------------------------------------------------------
# padding guarantee (the provably non-blocking sentinel)
# ---------------------------------------------------------------------------

def test_pack_edges_padding_is_degenerate():
    ea, eb, ec = _pack_edges(SQ, lane=128)
    E = SQ.edges.shape[0]
    assert ea.shape[0] == padded_edge_count(E, 128) > E
    assert (ea[E:] == eb[E:]).all() and (eb[E:] == ec[E:]).all()


def test_all_padding_tile_is_visible():
    """A batch against pure padding must come back fully visible.

    This is the load-bearing guarantee for both lane padding and the edge
    grid's sentinel slots: a degenerate (a == b == c) edge can never fire
    any §5 rule, under any backend, for any query segment — including
    segments whose endpoints coincide with the sentinel coordinates.
    """
    ea, eb, ec = _pack_edges(SQ, lane=128)
    E = SQ.edges.shape[0]
    pad_a = jnp.asarray(np.repeat(ea[E:E + 1], 128, axis=0))
    pad_b = jnp.asarray(np.repeat(eb[E:E + 1], 128, axis=0))
    pad_c = jnp.asarray(np.repeat(ec[E:E + 1], 128, axis=0))
    rng = np.random.default_rng(3)
    p = rng.uniform(0, 10, (32, 2)).astype(np.float32)
    q = rng.uniform(0, 10, (32, 2)).astype(np.float32)
    # include segments touching / anchored on the sentinel point itself
    p[0] = np.asarray(pad_a[0])
    q[1] = np.asarray(pad_a[0])
    p[2] = q[2] = np.asarray(pad_a[0])          # degenerate segment on it
    p, q = jnp.asarray(p), jnp.asarray(q)
    for fn in (ops.segvis_ref, jax.jit(ops.segvis_ref)):
        assert np.asarray(fn(p, q, pad_a, pad_b, pad_c)).all()
    assert np.asarray(segvis(p, q, pad_a, pad_b, pad_c,
                             interpret=True)).all()
    # tiles form: every slot a sentinel
    S = 16
    tiles = [jnp.broadcast_to(v, (32, S)) for v in
             (pad_a[0, 0], pad_a[0, 1], pad_b[0, 0], pad_b[0, 1],
              pad_c[0, 0], pad_c[0, 1])]
    assert np.asarray(ops.segvis_tiles_ref(p, q, *tiles)).all()
    assert np.asarray(ops.segvis_tiles_kernel(p, q, *tiles)).all()


def test_reflex_collinear_penetration_is_outside_the_sign_rules():
    """Known §5 boundary, pinned: collinear entry through a reflex vertex.

    A segment sliding along a boundary edge and continuing collinearly
    into the interior where the boundary turns away (requires a reflex
    obstacle vertex) fires no sign rule — the arm it must straddle is
    collinear with it.  Every device backend shares the behavior, so
    backends still agree with each other; the midpoint oracle blocks it.
    Unreachable for engine segments (endpoints free/boundary) on the
    convex-polygon suite maps — if this test ever *fails* because the
    backends start blocking it, the §5 docs and this pin must move
    together.
    """
    u_shape = Scene.build([np.array([[0.0, 0.0], [6.0, 0.0], [6.0, 6.0],
                                     [4.0, 6.0], [4.0, 3.0], [2.0, 3.0],
                                     [2.0, 6.0], [0.0, 6.0]])], 10.0, 10.0)
    P = np.array([[3.0, 3.0]])       # on the notch floor
    Q = np.array([[1.0, 3.0]])       # strictly inside the solid
    assert not visible_batch(u_shape, P, Q)[0]            # oracle: blocked
    assert not blocked_strict_batch(u_shape, P, Q)[0]     # sign rules: miss
    A, B, C = _edge_arrays(u_shape, np.float32)
    ref = np.asarray(ops.segvis_ref(*map(jnp.asarray,
                                         (P.astype(np.float32),
                                          Q.astype(np.float32), A, B, C))))
    assert ref[0]                    # device agrees with the f64 sign rules
