"""Shared fixtures: small scenes + prebuilt indexes reused across modules.

Note: NO XLA_FLAGS device-count override here — smoke tests and benches must
see the single real CPU device.  Only launch/dryrun.py forces 512 devices.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def scene_s():
    from repro.core.maps import make_map
    return make_map("rooms-S", seed=1)


@pytest.fixture(scope="session")
def graph_s(scene_s):
    from repro.core.visgraph import build_visgraph
    return build_visgraph(scene_s)


@pytest.fixture(scope="session")
def hl_s(graph_s):
    from repro.core.hublabel import build_hub_labels
    return build_hub_labels(graph_s)


@pytest.fixture(scope="session")
def ehl_s(scene_s, graph_s, hl_s):
    """Uncompressed EHL index on the small rooms map."""
    from repro.core.grid import build_ehl
    return build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)


@pytest.fixture(scope="session")
def queries_s(scene_s, graph_s):
    from repro.core.workload import uniform_queries
    return uniform_queries(scene_s, graph_s, 40, seed=11)


@pytest.fixture()
def fresh_ehl(scene_s, graph_s, hl_s):
    """Mutable copy-equivalent index for compression tests."""
    from repro.core.grid import build_ehl
    return build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)


@pytest.fixture(scope="session")
def compressed_s(scene_s, graph_s, hl_s, queries_s):
    """Budget-compressed index + exact host-f64 truth on ``queries_s``.

    Session-scoped and treated as read-only by every consumer (packers
    never mutate the region set)."""
    from repro.core.compression import compress_to_fraction
    from repro.core.grid import build_ehl
    from repro.core.query import query

    idx = build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)
    truth = np.array([query(idx, s, t, want_path=False)[0]
                      for s, t in zip(queries_s.s, queries_s.t)])
    compress_to_fraction(idx, 0.2)
    return idx, truth


class ConformanceHarness:
    """One query set answered by every (backend, slab layout) combination.

    The case table every engine identity test runs on: ``run(backend,
    layout)`` returns the full argmin tuple as numpy arrays (or a 1-tuple
    of distances for the argmin-less host oracle), with artifacts and
    engines cached per combination.  ``baseline`` is the jnp-jit f32
    bucketed engine — the layout every other backend is measured against;
    ``truth`` anchors the baseline itself to the exact float64 oracle.
    """

    BACKENDS = ("host", "jnp", "jnp-jit", "pallas", "grid", "slab",
                "sharded")
    LAYOUTS = ("f32", "bf16")

    def __init__(self, idx, truth, queries):
        self.idx = idx
        self.truth = truth
        self.s = queries.s.astype(np.float32)
        self.t = queries.t.astype(np.float32)
        self._cache: dict = {}

    def _layout(self, name: str):
        from repro.core.packed import slab_layout
        return slab_layout(name)

    def bucketed(self, layout: str, edge_grid=None):
        from repro.core.packed import pack_bucketed
        key = ("bx", layout, edge_grid)
        if key not in self._cache:
            self._cache[key] = pack_bucketed(
                self.idx, layout=self._layout(layout), edge_grid=edge_grid)
        return self._cache[key]

    def qerr(self, layout: str) -> float:
        bx = self.bucketed(layout)
        return float(np.asarray(bx.qerr)) if bx.qerr is not None else 0.0

    def _sharded(self, layout: str):
        from repro.sharding import ShardPlanner, ShardedQueryEngine
        key = ("sharded", layout)
        if key not in self._cache:
            art = ShardPlanner(2, layout=self._layout(layout)).build(self.idx)
            self._cache[key] = ShardedQueryEngine(art)
        return self._cache[key]

    def _slab_engine(self, layout: str):
        from repro.core.packed import pack_index
        from repro.serving.query_engine import JnpEngine
        key = ("slab", layout)
        if key not in self._cache:
            pk = pack_index(self.idx, layout=self._layout(layout))
            self._cache[key] = JnpEngine(pk)
        return self._cache[key]

    @property
    def baseline(self) -> tuple:
        return self.run("jnp-jit", "f32")

    def run(self, backend: str, layout: str) -> tuple:
        """(d, covis, via_s, hub, via_t) numpy tuple — (d,) for host."""
        import jax
        from repro.core.packed import query_batch_bucketed
        from repro.core.query import query as host_query

        key = ("run", backend, layout)
        if key in self._cache:
            return self._cache[key]
        if backend == "host":
            res = (np.array([host_query(self.idx, si, ti,
                                        want_path=False)[0]
                             for si, ti in zip(self.s, self.t)],
                            dtype=np.float32),)
        elif backend == "sharded":
            res = self._sharded(layout).query(self.s, self.t,
                                              want_argmin=True)
        elif backend == "slab":
            eng = self._slab_engine(layout)
            res = eng.batch_argmin(self.s, self.t)
        else:
            bx = self.bucketed(layout,
                               edge_grid=True if backend == "grid" else None)
            kw = dict(want_argmin=True,
                      use_kernels=backend == "pallas")
            if backend == "jnp":
                with jax.disable_jit():
                    res = query_batch_bucketed(bx, self.s, self.t, **kw)
            else:
                res = query_batch_bucketed(bx, self.s, self.t, **kw)
        res = tuple(np.asarray(r) for r in res)
        self._cache[key] = res
        return res


@pytest.fixture(scope="session")
def conformance(compressed_s, queries_s):
    idx, truth = compressed_s
    return ConformanceHarness(idx, truth, queries_s)
