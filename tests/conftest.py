"""Shared fixtures: small scenes + prebuilt indexes reused across modules.

Note: NO XLA_FLAGS device-count override here — smoke tests and benches must
see the single real CPU device.  Only launch/dryrun.py forces 512 devices.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def scene_s():
    from repro.core.maps import make_map
    return make_map("rooms-S", seed=1)


@pytest.fixture(scope="session")
def graph_s(scene_s):
    from repro.core.visgraph import build_visgraph
    return build_visgraph(scene_s)


@pytest.fixture(scope="session")
def hl_s(graph_s):
    from repro.core.hublabel import build_hub_labels
    return build_hub_labels(graph_s)


@pytest.fixture(scope="session")
def ehl_s(scene_s, graph_s, hl_s):
    """Uncompressed EHL index on the small rooms map."""
    from repro.core.grid import build_ehl
    return build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)


@pytest.fixture(scope="session")
def queries_s(scene_s, graph_s):
    from repro.core.workload import uniform_queries
    return uniform_queries(scene_s, graph_s, 40, seed=11)


@pytest.fixture()
def fresh_ehl(scene_s, graph_s, hl_s):
    """Mutable copy-equivalent index for compression tests."""
    from repro.core.grid import build_ehl
    return build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)
