"""GPipe pipeline over the pod axis == plain forward (exactness + grads)."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pipeline_forward_and_grad_match():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.models import layers as ll
        from repro.distributed import hints
        from repro.distributed.compat import make_mesh
        from repro.distributed.pipeline import pipeline_forward

        mesh = make_mesh((2, 4), ("pod", "data"))
        cfg = get_config("tinyllama-1.1b").reduced()   # 4 layers, 2 stages
        params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab)
        _, hid = T.forward(cfg, params, toks, return_hidden=True)
        ref = ll.rmsnorm(hid, params["final_norm"], cfg.norm_eps)
        with hints.mesh_hints(mesh), mesh:
            out = jax.jit(lambda p, t: pipeline_forward(
                cfg, p, t, n_micro=4))(params, toks)
        e1 = float(jnp.max(jnp.abs(out - ref)))

        def loss_pp(p):
            h = pipeline_forward(cfg, p, toks, n_micro=4)
            return (h.astype(jnp.float32) ** 2).mean()

        def loss_ref(p):
            _, hd = T.forward(cfg, p, toks, return_hidden=True)
            h = ll.rmsnorm(hd, p["final_norm"], cfg.norm_eps)
            return (h.astype(jnp.float32) ** 2).mean()

        with hints.mesh_hints(mesh), mesh:
            g1 = jax.jit(jax.grad(loss_pp))(params)
        g2 = jax.grad(loss_ref)(params)
        e2 = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
        print("ERR", e1, e2)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    e1, e2 = [float(x) for x in out.stdout.split("ERR")[1].split()]
    assert e1 < 1e-5 and e2 < 1e-6
