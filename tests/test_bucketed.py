"""Bucketed packed layout: oracle agreement, memory win, serving parity.

Covers the ISSUE acceptance properties at test scale (rooms-S):

* ``BucketedIndex`` query distances match the exact host oracle on a
  budget-compressed index (1e-4, float32 vs float64);
* bucketed dispatch is *bitwise* identical to the single-slab jnp engine
  (same arithmetic per label slot, extra slots are inf/HUB_PAD padding);
* total device bytes of the bucketed layout never exceed the single slab,
  and the per-bucket slot accounting is consistent;
* PathServer bucket routing + batched path extraction over the engines.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import (HUB_PAD, bucket_width, dispatch_buckets,
                               pack_bucketed, pack_index, query_batch,
                               query_batch_argmin, query_batch_at_bucket,
                               query_batch_bucketed, slab_device_bytes)
from repro.core.query import path_length, query
from repro.serving.engine import PathServer
from repro.serving.query_engine import HostEngine, make_engine


@pytest.fixture(scope="module")
def compressed(scene_s, graph_s, hl_s, queries_s):
    idx = build_ehl(scene_s, cell_size=2.0, graph=graph_s, hl=hl_s)
    truth = np.array([query(idx, s, t, want_path=False)[0]
                      for s, t in zip(queries_s.s, queries_s.t)])
    compress_to_fraction(idx, 0.2)
    return idx, truth


def test_bucket_width_is_pow2_multiple_of_lane():
    assert bucket_width(1, lane=128) == 128
    assert bucket_width(128, lane=128) == 128
    assert bucket_width(129, lane=128) == 256
    assert bucket_width(700, lane=128) == 1024


def test_bucketed_layout_consistency(compressed):
    idx, _ = compressed
    bx = pack_bucketed(idx)
    counts = idx.packed_label_counts()
    assert bx.num_regions == len(counts)
    rb = np.asarray(bx.region_bucket)
    rr = np.asarray(bx.region_row)
    for i, c in enumerate(counts):
        k, row = int(rb[i]), int(rr[i])
        # region sits in the smallest bucket that holds it, fully copied
        assert bx.widths[k] == bucket_width(max(1, int(c)))
        hub_row = np.asarray(bx.hub_ids[k][row])
        assert (hub_row != HUB_PAD).sum() == c
    used, total = bx.label_slots()
    assert used == int(counts.sum())
    assert used <= total


def test_bucketed_device_bytes_at_most_single_slab(compressed):
    idx, _ = compressed
    pk = pack_index(idx)
    bx = pack_bucketed(idx)
    assert bx.device_bytes() <= pk.device_bytes()
    # the analytic estimates (used to report layout footprints without
    # materializing them) are exact
    assert slab_device_bytes(idx) == pk.device_bytes()
    from repro.core.packed import bucketed_device_bytes
    assert bucketed_device_bytes(idx) == bx.device_bytes()
    # padding waste accounting agrees with the byte win
    used_b, total_b = bx.label_slots()
    used_p, total_p = pk.label_slots()
    assert used_b == used_p            # same live labels, different padding
    assert total_b <= total_p


def test_bucketed_matches_host_oracle(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    d = query_batch_bucketed(bx, queries_s.s, queries_s.t)
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("use_kernels", [False, True])
def test_bucketed_bitwise_matches_single_slab(compressed, queries_s,
                                              use_kernels):
    idx, _ = compressed
    pk = pack_index(idx)
    bx = pack_bucketed(idx)
    full = np.asarray(query_batch(pk, jnp.asarray(queries_s.s),
                                  jnp.asarray(queries_s.t),
                                  use_kernels=use_kernels))
    buck = query_batch_bucketed(bx, queries_s.s, queries_s.t,
                                use_kernels=use_kernels)
    np.testing.assert_array_equal(buck, full)


def test_bucketed_random_points_match_oracle(compressed, scene_s, graph_s):
    """Property-style sweep: fresh random free points, several seeds."""
    from repro.core.geometry import random_free_points
    idx, _ = compressed
    bx = pack_bucketed(idx)
    for seed in (3, 17, 91):
        rng = np.random.default_rng(seed)
        s = random_free_points(scene_s, 16, rng)
        t = random_free_points(scene_s, 16, rng)
        truth = np.array([query(idx, si, ti, want_path=False)[0]
                          for si, ti in zip(s, t)])
        d = query_batch_bucketed(bx, s, t)
        np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


def test_dispatch_buckets_cover_every_query(compressed, queries_s):
    idx, _ = compressed
    bx = pack_bucketed(idx)
    b = dispatch_buckets(bx, queries_s.s, queries_s.t)
    assert b.shape == (len(queries_s.s),)
    assert (b >= 0).all() and (b < bx.num_buckets).all()
    # per-bucket entry point agrees with the routed wrapper on its own group
    for k in np.unique(b):
        m = b == k
        d_k = np.asarray(query_batch_at_bucket(
            bx, jnp.asarray(queries_s.s[m].astype(np.float32)),
            jnp.asarray(queries_s.t[m].astype(np.float32)), bucket=int(k)))
        d_r = query_batch_bucketed(bx, queries_s.s[m], queries_s.t[m])
        np.testing.assert_array_equal(d_r, d_k)


def test_bucketed_argmin_matches_single_slab(compressed, queries_s):
    idx, truth = compressed
    pk = pack_index(idx)
    bx = pack_bucketed(idx)
    ds, cs, vs, hs, vt = (np.asarray(a) for a in query_batch_argmin(
        pk, jnp.asarray(queries_s.s), jnp.asarray(queries_s.t)))
    db, cb, vb, hb, vtb = query_batch_bucketed(bx, queries_s.s, queries_s.t,
                                               want_argmin=True)
    np.testing.assert_array_equal(db, ds)
    np.testing.assert_array_equal(cb, cs)
    m = ~cb & np.isfinite(db)          # reachable, not co-visible
    np.testing.assert_array_equal(vb[m], vs[m])
    np.testing.assert_array_equal(hb[m], hs[m])
    np.testing.assert_array_equal(vtb[m], vt[m])
    assert (vb[m] >= 0).all() and (vtb[m] >= 0).all()


def test_path_server_bucket_routing(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    srv = PathServer(bx, batch_size=16)
    srv.warmup()
    d = srv.query(queries_s.s, queries_s.t)
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)
    assert srv.stats.queries == len(truth)
    per = srv.stats.per_bucket
    assert per and sum(b.queries for b in per.values()) == len(truth)
    for b in per.values():
        assert 0.0 < b.occupancy <= 1.0
        assert b.width in bx.widths


def test_path_server_paths_are_optimal(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    srv = PathServer(bx, batch_size=16)
    d, paths = srv.query_paths(queries_s.s, queries_s.t, host_index=idx)
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)
    for di, p in zip(d, paths):
        if np.isfinite(di):
            assert abs(path_length(p) - di) < 1e-3
        else:
            assert p == []


def test_engine_backends_agree(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    host = make_engine(idx, backend="host")
    assert isinstance(host, HostEngine)
    d_host = host.batch(queries_s.s, queries_s.t)
    d_jnp = PathServer(make_engine(bx, backend="jnp"), batch_size=16).query(
        queries_s.s, queries_s.t)
    d_pal = PathServer(make_engine(bx, backend="pallas"), batch_size=16).query(
        queries_s.s, queries_s.t)
    np.testing.assert_allclose(d_host, truth, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d_jnp, truth, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(d_pal, d_jnp)
