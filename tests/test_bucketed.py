"""Bucketed packed layout: memory win, slot accounting, serving behavior.

Engine-identity checks (host oracle / single-slab bitwise / backend
agreement / argmin parity) live in the parameterized conformance table in
``test_conformance.py``; this module keeps the layout- and serving-
specific properties:

* bucket-width/slot accounting consistency and the device-byte win over
  the single slab (plus exact analytic estimators);
* bucket dispatch covers every query and agrees with the per-bucket entry;
* PathServer bucket routing + batched path extraction over the engines.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.packed import (HUB_PAD, bucket_width, dispatch_buckets,
                               pack_bucketed, pack_index,
                               query_batch_at_bucket,
                               query_batch_bucketed, slab_device_bytes)
from repro.core.query import path_length, query
from repro.serving.engine import PathServer


@pytest.fixture(scope="module")
def compressed(compressed_s):
    """Alias of the session-scoped compressed index + f64 truth."""
    return compressed_s


def test_bucket_width_is_pow2_multiple_of_lane():
    assert bucket_width(1, lane=128) == 128
    assert bucket_width(128, lane=128) == 128
    assert bucket_width(129, lane=128) == 256
    assert bucket_width(700, lane=128) == 1024


def test_bucketed_layout_consistency(compressed):
    idx, _ = compressed
    bx = pack_bucketed(idx)
    counts = idx.packed_label_counts()
    assert bx.num_regions == len(counts)
    rb = np.asarray(bx.region_bucket)
    rr = np.asarray(bx.region_row)
    for i, c in enumerate(counts):
        k, row = int(rb[i]), int(rr[i])
        # region sits in the smallest bucket that holds it, fully copied
        assert bx.widths[k] == bucket_width(max(1, int(c)))
        hub_row = np.asarray(bx.hub_ids[k][row])
        assert (hub_row != HUB_PAD).sum() == c
    used, total = bx.label_slots()
    assert used == int(counts.sum())
    assert used <= total


def test_bucketed_device_bytes_at_most_single_slab(compressed):
    idx, _ = compressed
    pk = pack_index(idx)
    bx = pack_bucketed(idx)
    assert bx.device_bytes() <= pk.device_bytes()
    # the analytic estimates (used to report layout footprints without
    # materializing them) are exact
    assert slab_device_bytes(idx) == pk.device_bytes()
    from repro.core.packed import bucketed_device_bytes
    assert bucketed_device_bytes(idx) == bx.device_bytes()
    # padding waste accounting agrees with the byte win
    used_b, total_b = bx.label_slots()
    used_p, total_p = pk.label_slots()
    assert used_b == used_p            # same live labels, different padding
    assert total_b <= total_p


def test_bucketed_random_points_match_oracle(compressed, scene_s, graph_s):
    """Property-style sweep: fresh random free points, several seeds."""
    from repro.core.geometry import random_free_points
    idx, _ = compressed
    bx = pack_bucketed(idx)
    for seed in (3, 17, 91):
        rng = np.random.default_rng(seed)
        s = random_free_points(scene_s, 16, rng)
        t = random_free_points(scene_s, 16, rng)
        truth = np.array([query(idx, si, ti, want_path=False)[0]
                          for si, ti in zip(s, t)])
        d = query_batch_bucketed(bx, s, t)
        np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


def test_dispatch_buckets_cover_every_query(compressed, queries_s):
    idx, _ = compressed
    bx = pack_bucketed(idx)
    b = dispatch_buckets(bx, queries_s.s, queries_s.t)
    assert b.shape == (len(queries_s.s),)
    assert (b >= 0).all() and (b < bx.num_buckets).all()
    # per-bucket entry point agrees with the routed wrapper on its own group
    for k in np.unique(b):
        m = b == k
        d_k = np.asarray(query_batch_at_bucket(
            bx, jnp.asarray(queries_s.s[m].astype(np.float32)),
            jnp.asarray(queries_s.t[m].astype(np.float32)), bucket=int(k)))
        d_r = query_batch_bucketed(bx, queries_s.s[m], queries_s.t[m])
        np.testing.assert_array_equal(d_r, d_k)


def test_path_server_bucket_routing(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    srv = PathServer(bx, batch_size=16)
    srv.warmup()
    d = srv.query(queries_s.s, queries_s.t)
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)
    assert srv.stats.queries == len(truth)
    per = srv.stats.per_bucket
    assert per and sum(b.queries for b in per.values()) == len(truth)
    for b in per.values():
        assert 0.0 < b.occupancy <= 1.0
        assert b.width in bx.widths


def test_path_server_paths_are_optimal(compressed, queries_s):
    idx, truth = compressed
    bx = pack_bucketed(idx)
    srv = PathServer(bx, batch_size=16)
    d, paths = srv.query_paths(queries_s.s, queries_s.t, host_index=idx)
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)
    for di, p in zip(d, paths):
        if np.isfinite(di):
            assert abs(path_length(p) - di) < 1e-3
        else:
            assert p == []
