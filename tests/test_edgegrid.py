"""Edge-grid pruning (DESIGN.md §10): bitwise-identical to the dense path.

The load-bearing property is the superset argument — every edge that can
block a segment is gathered by the segment's cell walk — which makes the
grid-pruned OR-reduction equal the dense OR-reduction *bitwise*, not just
approximately.  Exercised deterministically on the suite map (including
segments lying exactly on cell boundaries and walks through empty cells)
and property-tested on random scenes with hypothesis.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.edgegrid import build_edge_grid, segvis_grid
from repro.core.geometry import Scene
from repro.core.packed import _pack_edges, pack_index
from repro.kernels import ops


def _grid_of(scene, target_cells=None):
    ea, eb, ec = _pack_edges(scene, lane=128)
    grid = build_edge_grid(ea, eb, scene.edges.shape[0], scene.width,
                           scene.height, sentinel=ea.shape[0] - 1,
                           target_cells=target_cells)
    return grid, jnp.asarray(ea), jnp.asarray(eb), jnp.asarray(ec)


def _assert_grid_matches_dense(scene, p, q, target_cells=None):
    grid, ea, eb, ec = _grid_of(scene, target_cells)
    p = jnp.asarray(np.asarray(p, np.float32))
    q = jnp.asarray(np.asarray(q, np.float32))
    dense = np.asarray(ops.segvis_ref(p, q, ea, eb, ec))
    pruned = np.asarray(segvis_grid(p, q, ea, eb, ec, grid))
    assert (dense == pruned).all(), (
        f"grid/dense split at {np.nonzero(dense != pruned)[0].tolist()}")
    jitted = np.asarray(jax.jit(
        lambda a, b: segvis_grid(a, b, ea, eb, ec, grid))(p, q))
    assert (dense == jitted).all()


def _boundary_heavy_segments(scene, grid, rng, n):
    """Random segments with coordinates snapped onto grid-cell boundaries."""
    w, h = scene.width, scene.height
    pts = rng.uniform(0, [w, h], (2 * n, 2)).astype(np.float32)
    g = np.float32(grid.gcell)
    snap = rng.random((2 * n, 2)) < 0.5
    pts = np.where(snap, np.round(pts / g) * g, pts).astype(np.float32)
    return pts[:n], pts[n:]


def test_grid_matches_dense_on_suite_map(scene_s):
    rng = np.random.default_rng(0)
    grid, *_ = _grid_of(scene_s)
    p, q = _boundary_heavy_segments(scene_s, grid, rng, 300)
    # axis-aligned, degenerate, and map-crossing segments
    p[0], q[0] = (0.0, 0.0), (scene_s.width, scene_s.height)
    p[1], q[1] = (grid.gcell, 1.0), (grid.gcell, scene_s.height - 1.0)
    p[2] = q[2] = (grid.gcell * 2, grid.gcell * 3)       # zero-length
    p[3], q[3] = (1.0, grid.gcell), (scene_s.width - 1.0, grid.gcell)
    _assert_grid_matches_dense(scene_s, p, q)


def test_grid_matches_dense_with_vertex_anchored_segments(scene_s):
    """The packed engine's segment population: free point -> via vertex."""
    rng = np.random.default_rng(1)
    V = scene_s.vertices.astype(np.float32)
    P = rng.uniform(0, [scene_s.width, scene_s.height],
                    V.shape).astype(np.float32)
    _assert_grid_matches_dense(scene_s, P, V)


def test_grid_matches_dense_through_empty_cells():
    """Edges in one corner; segments sweep cells with zero registrations."""
    sc = Scene.build([np.array([[1.0, 1.0], [2.0, 1.0], [2.0, 2.0],
                                [1.0, 2.0]])], 32.0, 32.0)
    rng = np.random.default_rng(2)
    p = rng.uniform(8, 32, (64, 2)).astype(np.float32)   # far from edges
    q = rng.uniform(0, 32, (64, 2)).astype(np.float32)
    _assert_grid_matches_dense(sc, p, q, target_cells=16)


def test_walk_visits_every_touched_cell(scene_s):
    """Superset half of the §10 argument, checked by dense sampling."""
    grid, *_ = _grid_of(scene_s)
    rng = np.random.default_rng(3)
    p, q = _boundary_heavy_segments(scene_s, grid, rng, 40)
    cells = np.asarray(grid.visited_cells(jnp.asarray(p), jnp.asarray(q)))
    ts = np.linspace(0.0, 1.0, 512)[None, :, None]
    pts = p[:, None, :] + ts * (q - p)[:, None, :]
    g = grid.gcell
    ix = np.clip((pts[..., 0] / g).astype(int), 0, grid.gnx - 1)
    iy = np.clip((pts[..., 1] / g).astype(int), 0, grid.gny - 1)
    touched = iy * grid.gnx + ix
    for i in range(len(p)):
        missing = set(touched[i]) - set(cells[i])
        assert not missing, f"segment {i} walk missed cells {missing}"


def test_gathered_tiles_cover_blocking_edges(scene_s):
    """Any edge the dense predicate blocks on appears in the tile."""
    grid, ea, eb, ec = _grid_of(scene_s)
    rng = np.random.default_rng(4)
    p, q = _boundary_heavy_segments(scene_s, grid, rng, 64)
    p, q = jnp.asarray(p), jnp.asarray(q)
    from repro.kernels.ref import blocked_pairs
    blk = np.asarray(blocked_pairs(
        p[:, 0, None], p[:, 1, None], q[:, 0, None], q[:, 1, None],
        ea[None, :, 0], ea[None, :, 1], eb[None, :, 0], eb[None, :, 1],
        ec[None, :, 0], ec[None, :, 1]))
    cells = np.asarray(grid.visited_cells(p, q))
    ids = np.asarray(grid.cell_ids)[cells].reshape(len(np.asarray(p)), -1)
    for i, e in zip(*np.nonzero(blk)):
        assert e in ids[i], f"blocking edge {e} absent from segment {i} tile"


def test_packed_grid_auto_policy(ehl_s):
    """edge_grid=None attaches the grid iff the gathered tile is smaller."""
    forced = pack_index(ehl_s, edge_grid=True)
    assert forced.grid is not None
    off = pack_index(ehl_s, edge_grid=False)
    assert off.grid is None
    auto = pack_index(ehl_s)
    if auto.grid is not None:
        assert auto.grid.tile_slots < auto.edges_a.shape[0]


# ---------------------------------------------------------------------------
# hypothesis property: random scenes, random segments
# ---------------------------------------------------------------------------

try:                                   # test dep (pyproject [test]); the
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True            # deterministic tests above still run
except ImportError:                    # without it
    _HAVE_HYPOTHESIS = False

    def _skipped():
        pytest.skip("hypothesis not installed")

    def given(*a, **k):
        return lambda f: _skipped

    def settings(*a, **k):
        return lambda f: f

    class st:  # noqa: N801
        @staticmethod
        def integers(*a, **k):
            return None


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_grid_equals_dense_property(seed):
    rng = np.random.default_rng(seed)
    polys = []
    for _ in range(rng.integers(1, 4)):
        x0, y0 = rng.uniform(0, 24, 2)
        w, h = rng.uniform(0.5, 6, 2)
        polys.append(np.array([[x0, y0], [x0 + w, y0],
                               [x0 + w, y0 + h], [x0, y0 + h]]))
    sc = Scene.build(polys, 32.0, 32.0)
    grid, ea, eb, ec = _grid_of(sc, target_cells=int(rng.integers(4, 17)))
    n = 48
    pts = rng.uniform(0, 32, (2 * n, 2)).astype(np.float32)
    g = np.float32(grid.gcell)
    snap = rng.random((2 * n, 2)) < 0.3
    pts = np.where(snap, np.round(pts / g) * g, pts).astype(np.float32)
    # anchor some segments on obstacle vertices (the engine population)
    V = sc.vertices.astype(np.float32)
    k = min(8, len(V))
    pts[n:n + k] = V[:k]
    p, q = jnp.asarray(pts[:n]), jnp.asarray(pts[n:])
    dense = np.asarray(ops.segvis_ref(p, q, ea, eb, ec))
    pruned = np.asarray(segvis_grid(p, q, ea, eb, ec, grid))
    assert (dense == pruned).all()
