"""EHL index + query engine vs the exact A* oracle (optimality)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # test dep (pyproject [test]); skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.geometry import random_free_points, visible_batch, edist
from repro.core.query import query, path_length
from repro.core.visgraph import astar


def test_ehl_distances_match_astar(ehl_s, graph_s, queries_s):
    for s, t in zip(queries_s.s, queries_s.t):
        dref, _ = astar(graph_s, s, t)
        d, path = query(ehl_s, s, t)
        assert d == pytest.approx(dref, abs=1e-8)
        assert path_length(path) == pytest.approx(d, abs=1e-8)


def test_path_is_obstacle_avoiding(ehl_s, queries_s):
    scene = ehl_s.scene
    for s, t in zip(queries_s.s[:15], queries_s.t[:15]):
        _, path = query(ehl_s, s, t)
        P = np.array(path[:-1])
        Q = np.array(path[1:])
        assert visible_batch(scene, P, Q).all()


def test_covisible_shortcut(ehl_s):
    s = np.array([1.0, 1.0])
    t = np.array([2.0, 2.0])
    d, path = query(ehl_s, s, t)
    assert d == pytest.approx(edist(s, t))
    assert len(path) == 2


def test_same_point_query(ehl_s):
    p = np.array([1.0, 1.0])
    d, _ = query(ehl_s, p, p)
    assert d == pytest.approx(0.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_random_pairs_optimal(ehl_s, graph_s, seed):
    """Hypothesis sweep: EHL distance == A* for random free-space pairs."""
    rng = np.random.default_rng(seed)
    pts = random_free_points(ehl_s.scene, 2, rng)
    dref, _ = astar(graph_s, pts[0], pts[1])
    d, _ = query(ehl_s, pts[0], pts[1], want_path=False)
    if np.isfinite(dref):
        assert d == pytest.approx(dref, abs=1e-8)
    else:
        assert not np.isfinite(d)


def test_mapper_partitions_grid(ehl_s):
    C = ehl_s.nx * ehl_s.ny
    assert ehl_s.mapper.shape == (C,)
    for ci in range(C):
        rid = int(ehl_s.mapper[ci])
        assert rid in ehl_s.regions
        assert ci in ehl_s.regions[rid].cells
    total = sum(len(r.cells) for r in ehl_s.regions.values())
    assert total == C


def test_label_memory_accounting(ehl_s):
    from repro.core.grid import LABEL_BYTES
    n = sum(r.n_labels for r in ehl_s.regions.values())
    assert ehl_s.label_memory() == n * LABEL_BYTES
    assert ehl_s.total_memory() > ehl_s.label_memory()


def test_ehl_grid_scaling_reduces_memory(scene_s, graph_s, hl_s):
    """EHL-2/EHL-4 behaviour: larger cells -> less memory (paper Table 5)."""
    from repro.core.grid import build_ehl
    m1 = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s).label_memory()
    m2 = build_ehl(scene_s, 4.0, graph=graph_s, hl=hl_s).label_memory()
    m4 = build_ehl(scene_s, 8.0, graph=graph_s, hl=hl_s).label_memory()
    assert m1 > m2 > m4
