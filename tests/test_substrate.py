"""Substrate tests: optimizer, checkpoint, data pipeline, fault handling,
gradient compression, sharding rules."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, host_batch_size, synthetic_batch
from repro.distributed import fault
from repro.distributed.sharding import param_specs
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.optim.compression import compress_psum_ref


# ---------------------------------------------------------------- optimizer
def _quad_params():
    return {"w": jnp.array([2.0, -3.0]), "b": jnp.array([1.0])}


def test_adamw_converges_on_quadratic():
    params = _quad_params()
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            total_steps=400, min_lr_frac=1.0)
    state = adamw.init_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments_track_fp32():
    params = _quad_params()
    base = adamw.AdamWConfig(lr=0.01, weight_decay=0.0, warmup_steps=0)
    half = adamw.AdamWConfig(lr=0.01, weight_decay=0.0, warmup_steps=0,
                             moment_dtype=jnp.bfloat16)
    s32, s16 = adamw.init_state(params, base), adamw.init_state(params, half)
    p32 = p16 = params
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(50):
        p32, s32, _ = adamw.apply_updates(p32, jax.grad(loss)(p32), s32, base)
        p16, s16, _ = adamw.apply_updates(p16, jax.grad(loss)(p16), s16, half)
    assert s16["m"]["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(p16["w"]), np.asarray(p32["w"]),
                               atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, 0)) == pytest.approx(0.0)
    assert float(adamw.schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, 110)) == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------- compression
def test_int8_error_feedback_psum():
    rng = np.random.default_rng(0)
    shards = [rng.normal(size=(64,)).astype(np.float32) for _ in range(4)]
    res = [np.zeros(64, np.float32) for _ in range(4)]
    true_mean = sum(shards) / 4
    # single round: quantization error bounded by scale
    mean, res = compress_psum_ref(shards, res)
    scale = max(np.abs(s).max() for s in shards) / 127
    assert np.abs(mean - true_mean).max() < scale * 1.01
    # error feedback: same gradient repeated -> running mean converges
    acc = np.zeros(64)
    for it in range(30):
        mean, res = compress_psum_ref(shards, res)
        acc += mean
    np.testing.assert_allclose(acc / 30, true_mean, atol=1e-3)


def test_quantize_psum_in_shard_map():
    from jax.experimental.shard_map import shard_map
    from repro.optim.compression import quantize_psum
    mesh = make_host_mesh()
    g = jnp.arange(8, dtype=jnp.float32)
    r = jnp.zeros(8, jnp.float32)
    f = shard_map(lambda g, r: quantize_psum(g, "data", r),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    out, res = f(g, r)
    scale = 7.0 / 127
    assert np.abs(np.asarray(out) - np.asarray(g)).max() <= scale * 1.01


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    store.save(str(tmp_path), 7, tree)
    assert store.latest_step(str(tmp_path)) == 7
    out = store.restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_latest_and_atomicity(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    store.save(str(tmp_path), 1, tree)
    store.save(str(tmp_path), 5, tree)
    os.makedirs(tmp_path / "step_000009.tmp0", exist_ok=True)  # crashed save
    assert store.latest_step(str(tmp_path)) == 5


def test_elastic_remesh_roundtrip(tmp_path):
    """Save under one mesh, restore under another (elastic resize)."""
    from jax.sharding import NamedSharding
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    store.save(str(tmp_path), 0, tree)
    mesh_b = make_host_mesh()          # same devices, fresh mesh object
    sh = {"w": NamedSharding(mesh_b, P("data", None))}
    out = store.restore(str(tmp_path), 0, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------- data
def test_data_determinism_and_shard_disjointness():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    a = synthetic_batch(cfg, 3)
    b = synthetic_batch(cfg, 3)
    np.testing.assert_array_equal(a, b)          # resumable
    c = synthetic_batch(cfg, 4)
    assert not np.array_equal(a, c)              # steps differ
    h0 = DataConfig(vocab=1000, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=0)
    h1 = DataConfig(vocab=1000, seq_len=16, global_batch=8, n_hosts=2,
                    host_id=1)
    assert host_batch_size(h0) == 4
    assert not np.array_equal(synthetic_batch(h0, 3), synthetic_batch(h1, 3))


def test_tokens_in_vocab_range():
    cfg = DataConfig(vocab=77, seq_len=32, global_batch=4)
    t = synthetic_batch(cfg, 0)
    assert t.min() >= 0 and t.max() < 77


# ---------------------------------------------------------------- fault
def test_step_guard_restores_and_replays(tmp_path):
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if calls["n"] == 1:
            raise fault.SimulatedFault("boom")
        return state + 1

    guard = fault.StepGuard(str(tmp_path), save_every=1)
    out = guard.run(step_fn, 10, step=3, restore_fn=lambda: 10)
    assert out == 11
    assert guard.events and guard.events[0].kind == "device"


def test_plan_remesh():
    assert fault.plan_remesh(512, 16) == (32, 16)
    assert fault.plan_remesh(256, 16) == (16, 16)
    assert fault.plan_remesh(240, 16) == (15, 16)
    with pytest.raises(ValueError):
        fault.plan_remesh(8, 16)


def test_straggler_policy():
    p = fault.StragglerPolicy(threshold=2.0)
    for step in range(6):
        for h in range(4):
            p.record(h, 1.0 if h != 2 else 5.0)
    assert p.stragglers() == [2]


# ---------------------------------------------------------------- sharding
def test_param_specs_patterns():
    mesh = make_host_mesh()
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    shapes = jax.eval_shape(
        lambda k: T.init_params(cfg, k, dtype=jnp.float32),
        jax.random.PRNGKey(0))
    specs = param_specs(shapes, mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    d = {"/".join(str(getattr(k, "key", k)) for k in path): s
         for path, s in flat}
    # norms replicated; projections sharded per the table (host mesh is 1x1
    # so axes that don't divide are dropped -> all P() here; pattern check
    # runs against a fat fake mesh below)
    assert all(isinstance(s, P) for s in d.values())


def test_param_specs_on_production_shapes():
    """Pattern table must shard big tensors on a 16x16 mesh (validated
    against the spec structure, no devices needed)."""
    import re
    from repro.distributed.sharding import _rules
    rules = _rules("data", "model")
    pats = [p for p, _ in rules]
    for needed in [r"embed$", r"moe/w[gud]$", r"attn/w[qkv]$",
                   r"ssm/in_proj$", r"mlp/w[gu]$"]:
        assert needed in pats
