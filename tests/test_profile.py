"""Performance attribution layer (DESIGN.md §13): jit compile/cost
capture, build-pipeline spans, bench history + the regression gate.

The capture tests drive a private ``MetricsRegistry`` and restore the
process-wide profiler in ``finally`` blocks, so nothing here leaks into
other modules' steady-state dispatch.  ``jax.clear_caches()`` forces the
cold compiles the capture exists to observe — the pjit cache is
process-wide, so without it a session-scoped fixture may already have
traced every shape.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.core.grid import build_ehl
from repro.core.packed import TRACES, bucketed_device_bytes, pack_bucketed
from repro.core.workload import cluster_queries
from repro.indexing import IndexManager
from repro.serving.engine import PathServer
from repro.serving.query_engine import JnpEngine


def _total(reg, name):
    return sum(m.value for m in reg.series(name))


# ------------------------------------------------------ cost normalization

def test_normalize_cost_variants():
    # jax 0.4.x returns either a dict or a one-element list of dicts
    d = {"flops": 10.0, "bytes accessed": 20.0, "utilization": "high"}
    assert obs.normalize_cost([d]) == {"flops": 10.0, "bytes accessed": 20.0}
    assert obs.normalize_cost(d)["flops"] == 10.0
    assert obs.normalize_cost(None) == {}
    assert obs.normalize_cost([]) == {}


def test_aot_cost_counts_known_flops():
    import jax.numpy as jnp
    a = jnp.ones((8, 16), jnp.float32)
    cost = obs.aot_cost(lambda x: x @ x.T, a)
    # 8x16 @ 16x8 matmul: 2*M*N*K = 2048 flops, XLA counts exactly this
    assert cost.get("flops") == pytest.approx(2 * 8 * 8 * 16)
    assert cost.get("bytes accessed", 0) > 0


# ---------------------------------------------- compile capture (serving)

def test_compile_and_cost_series_after_cold_warmup(compressed_s):
    """Cold ``PathServer.warmup()`` with capture live: every jit entry the
    query path hits lands compile-count, compile-time, and cost_analysis
    series in the capture's registry; warm re-execution adds nothing."""
    import jax

    idx, _ = compressed_s
    reg = obs.MetricsRegistry()
    prof = obs.enable_profile(registry=reg)
    try:
        jax.clear_caches()                      # force cold compiles
        bx = pack_bucketed(idx)
        srv = PathServer(JnpEngine(bx), batch_size=16)
        srv.warmup()

        from repro.core import packed

        compiles = _total(reg, "jit_compiles_total")
        assert compiles >= 1
        entries = {dict(m.labels)["entry"]
                   for m in reg.series("jit_compiles_total")}
        assert entries                           # labeled per jit entry
        declared = {w.entry for w in vars(packed).values()
                    if hasattr(w, "entry")}
        assert entries <= declared
        assert _total(reg, "jit_compile_seconds_total") > 0
        assert _total(reg, "jit_cost_flops_total") > 0
        assert _total(reg, "jit_cost_bytes_total") > 0
        assert _total(reg, "jit_cost_output_bytes_total") > 0
        # capture kept per-compile records with the raw cost dicts
        assert prof.records and all(r.compile_s > 0 for r in prof.records)
        summ = prof.summary()
        assert sum(v["compiles"] for v in summ.values()) == compiles

        # steady state: identical shapes re-dispatch without re-tracing,
        # so the capture must not grow (the ~zero-overhead property the
        # bench gates on)
        rng = np.random.default_rng(0)
        pts = rng.uniform(0, 10, (16, 2)).astype(np.float32)
        srv.query(pts, pts)
        warm = _total(reg, "jit_compiles_total")
        srv.query(pts, pts)
        assert _total(reg, "jit_compiles_total") == warm
    finally:
        obs.disable_profile()


def test_disable_profile_stops_capture(compressed_s):
    import jax

    idx, _ = compressed_s
    reg = obs.MetricsRegistry()
    obs.enable_profile(registry=reg)
    obs.disable_profile()
    assert TRACES.profiler is None
    jax.clear_caches()
    bx = pack_bucketed(idx)
    srv = PathServer(JnpEngine(bx), batch_size=16)
    srv.warmup()                                 # cold, but capture is off
    assert not reg.series("jit_compiles_total")


def test_trace_counter_thread_attribution():
    """A compile on another thread must not be credited to this one —
    the foreground wrapper keys on the thread-local count, not the
    process-wide total."""
    import threading

    before_global = TRACES.count
    before_local = TRACES.thread_count()
    th = threading.Thread(target=lambda: TRACES.bump("elsewhere"))
    th.start()
    th.join()
    assert TRACES.count == before_global + 1
    assert TRACES.thread_count() == before_local


# ----------------------------------------------- build-pipeline spans

@pytest.fixture()
def traced_manager(scene_s, graph_s, hl_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    budget = int(bucketed_device_bytes(idx) * 0.5)
    tel = obs.Telemetry(registry=obs.MetricsRegistry(), sample_rate=1.0)
    mgr = IndexManager(idx, budget, batch_size=16, min_queries=40,
                       replan_threshold=0.10, probe_n=8, seed=29,
                       telemetry=tel)
    return mgr, tel, budget


def _drive(mgr, scene_s, graph_s, seed):
    qs = cluster_queries(scene_s, graph_s, 2, 60, seed=seed,
                         require_path=False)
    mgr.recorder.record(qs.s, qs.t)


def test_build_stage_spans_telescope_to_e2e(traced_manager, scene_s,
                                            graph_s):
    mgr, tel, _ = traced_manager
    _drive(mgr, scene_s, graph_s, seed=61)
    assert mgr.maybe_adapt() is True

    (tr,) = tel.spans.traces("build")
    assert tr.closed and tr.complete(obs.BUILD_STAGES)
    assert tr.attrs["outcome"] == "ok"
    assert [c["name"] for c in tr.tree()["children"]] == \
        list(obs.BUILD_STAGES)
    # stage boundaries are one stopwatch's consecutive laps, so the
    # telescoped sum reproduces e2e up to float summation noise — far
    # tighter than the 5% gate the serving spans get
    assert tr.e2e_seconds > 0
    assert abs(tr.stage_sum - tr.e2e_seconds) <= 1e-6 * tr.e2e_seconds
    # every stage also landed its histogram + the outcome counter
    reg = tel.registry
    for st in obs.BUILD_STAGES:
        (h,) = reg.find("build_stage_ms", stage=st)
        assert h.count == 1
    (ok,) = reg.find("builds_total", outcome="ok")
    assert ok.value == 1
    # planner decision records in the structured event log
    (dec,) = tel.events.events("plan_decision")
    assert dec["decision"] != "skip" and dec["budget_bytes"] > 0
    (ex,) = tel.events.events("plan_execute")
    assert ex["regions_in"] == ex["regions_admitted"] + ex["regions_evicted"]
    assert ex["label_bytes_out"] <= ex["label_bytes_in"]


def test_async_build_span_covers_hot_swap_under_serving(traced_manager,
                                                        scene_s, graph_s):
    """A background build (hot-swap mid-serving): the span is produced on
    the builder thread and still telescopes; the foreground keeps serving
    through the swap."""
    mgr, tel, budget = traced_manager
    srv = PathServer(mgr.engine, batch_size=16, recorder=mgr.recorder,
                     telemetry=tel)
    srv.warmup()
    qs = cluster_queries(scene_s, graph_s, 2, 60, seed=91,
                         require_path=False)
    s, t = qs.s.astype(np.float32), qs.t.astype(np.float32)
    srv.query(s, t)
    gen0 = mgr.generation
    assert mgr.maybe_adapt(block=False) is False   # builds on the thread
    srv.query(s, t)                                # serve during the build
    mgr.join(timeout=120.0)
    assert mgr.generation == gen0 + 1 and mgr.swaps == 1
    srv.query(s, t)                                # and after the swap

    (tr,) = tel.spans.traces("build")
    assert tr.complete(obs.BUILD_STAGES) and tr.attrs["outcome"] == "ok"
    assert tr.attrs["async_build"] is True
    assert abs(tr.stage_sum - tr.e2e_seconds) <= 1e-6 * tr.e2e_seconds
    assert tr.attrs["generation"] == mgr.generation
    # byte/region deltas ride on the span
    assert tr.attrs["device_bytes_out"] <= budget
    assert tr.attrs["regions_out"] <= tr.attrs["regions_in"]


def test_aborted_build_traced_with_abort_outcome(traced_manager, scene_s,
                                                 graph_s):
    mgr, tel, budget = traced_manager
    mgr.set_budget(10_000)                       # no candidate can fit
    assert mgr.maybe_adapt() is False
    (tr,) = tel.spans.traces("build")
    assert tr.closed and tr.complete(obs.BUILD_STAGES)
    assert tr.attrs["outcome"] == "abort"
    assert abs(tr.stage_sum - tr.e2e_seconds) <= 1e-6 * tr.e2e_seconds
    (ab,) = tel.registry.find("builds_total", outcome="abort")
    assert ab.value == 1
    assert not tel.registry.find("builds_total", outcome="ok")


def test_build_series_export_round_trip(traced_manager, scene_s, graph_s):
    """New series survive the Prometheus text + JSON round trip."""
    mgr, tel, _ = traced_manager
    _drive(mgr, scene_s, graph_s, seed=71)
    reg = tel.registry
    reg.counter("jit_compiles_total", entry="join_gathered").inc(2)
    reg.counter("jit_cost_flops_total", entry="join_gathered").inc(12345)
    assert mgr.maybe_adapt() is True

    parsed = obs.parse_prometheus(obs.prometheus_text(reg))
    assert parsed["jit_compiles_total"][(("entry", "join_gathered"),)] == 2
    assert parsed["jit_cost_flops_total"][
        (("entry", "join_gathered"),)] == 12345
    assert sum(parsed["builds_total"].values()) == 1
    stages = {dict(k)["stage"] for k in parsed["build_stage_ms_count"]}
    assert stages == set(obs.BUILD_STAGES)
    snap = json.loads(obs.json_snapshot(reg))
    hist_names = {h["name"] for h in snap["histograms"]}
    assert "build_stage_ms" in hist_names
    ctr_names = {c["name"] for c in snap["counters"]}
    assert {"jit_compiles_total", "builds_total"} <= ctr_names


# ------------------------------------------- bench history + regression

def _fake_bench(monkeypatch, tmp_path, sha, qps, p99, n=600):
    from benchmarks import common
    monkeypatch.setattr(common, "git_sha", lambda: sha)
    common.write_bench_json(
        "serving", qps=qps, p50_ms=p99 / 2, p99_ms=p99,
        out_dir=str(tmp_path),
        data=dict(map="rooms-M", n=n, batch_size=64, budget_frac=0.3))


def test_write_bench_json_appends_sha_keyed_history(monkeypatch, tmp_path):
    from benchmarks import common
    _fake_bench(monkeypatch, tmp_path, "a" * 40, 1000.0, 10.0)
    _fake_bench(monkeypatch, tmp_path, "b" * 40, 1100.0, 9.0)
    # same-sha rerun overwrites that sha's entry instead of appending
    _fake_bench(monkeypatch, tmp_path, "b" * 40, 1050.0, 9.5)
    hist = common.load_history("serving", out_dir=str(tmp_path))
    assert [h["git_sha"][:1] for h in hist] == ["a", "b"]
    assert hist[-1]["qps"] == 1050.0             # oldest first, overwritten
    assert all("written_at" in h for h in hist)
    # the main artifact is the newest run
    cur = json.load(open(tmp_path / "BENCH_serving.json"))
    assert cur["git_sha"].startswith("b") and cur["qps"] == 1050.0


def test_regression_gate_passes_and_fails_on_injected_slowdown(
        monkeypatch, tmp_path):
    """The CI gate demonstrated end-to-end: a healthy run passes against
    the history baseline; an injected qps drop / p99 inflation fails."""
    from benchmarks import check_regression

    _fake_bench(monkeypatch, tmp_path, "a" * 40, 1000.0, 10.0)  # baseline
    _fake_bench(monkeypatch, tmp_path, "b" * 40, 980.0, 10.5)   # healthy
    assert check_regression.check("serving", out_dir=str(tmp_path)) == []

    # injected slowdown: 20% qps drop at the same config
    _fake_bench(monkeypatch, tmp_path, "c" * 40, 800.0, 10.0)
    failures = check_regression.check("serving", out_dir=str(tmp_path))
    assert failures and "qps" in failures[0]
    with pytest.raises(SystemExit):
        monkeypatch.setattr(check_regression.common, "ARTIFACTS",
                            str(tmp_path))
        check_regression.main(["serving"])

    # injected p99 inflation: qps fine, tail blown past 1.25x + 2ms
    _fake_bench(monkeypatch, tmp_path, "d" * 40, 1000.0, 40.0)
    failures = check_regression.check("serving", out_dir=str(tmp_path))
    assert failures and "p99" in failures[0]


def test_regression_gate_skips_unmatched_config(monkeypatch, tmp_path):
    """A smoke run never gates against a full run's numbers."""
    from benchmarks import check_regression

    _fake_bench(monkeypatch, tmp_path, "a" * 40, 5000.0, 1.0, n=2000)
    _fake_bench(monkeypatch, tmp_path, "b" * 40, 500.0, 50.0, n=600)
    assert check_regression.check("serving", out_dir=str(tmp_path)) == []


def test_trend_table_renders_committed_history():
    from benchmarks import make_tables
    text = make_tables.trend_table()
    assert "Bench history" in text
    assert "**serving**" in text                 # seeded in this repo
