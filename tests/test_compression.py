"""EHL* compression (Algorithm 1): budget adherence + optimality invariance."""

import numpy as np
import pytest

from repro.core.compression import compress, compress_to_fraction, jaccard
from repro.core.query import query
from repro.core.visgraph import astar
from repro.core.workload import (cluster_queries, workload_scores,
                                 uniform_queries)


def test_jaccard():
    a = np.array([1, 2, 3], dtype=np.int64)
    b = np.array([2, 3, 4], dtype=np.int64)
    assert jaccard(a, b) == pytest.approx(2 / 4)
    assert jaccard(a, a) == 1.0
    assert jaccard(np.zeros(0, np.int64), np.zeros(0, np.int64)) == 1.0
    assert jaccard(a, np.zeros(0, np.int64)) == 0.0


@pytest.mark.parametrize("frac", [0.6, 0.3, 0.1])
def test_budget_satisfied(fresh_ehl, frac):
    stats = compress_to_fraction(fresh_ehl, frac)
    assert stats.final_bytes <= stats.budget or stats.hit_single_region
    assert fresh_ehl.label_memory() == stats.final_bytes


def test_optimality_preserved_across_budgets(fresh_ehl, graph_s, queries_s):
    """The paper's core guarantee: merging never breaks optimality."""
    refs = [astar(graph_s, s, t)[0]
            for s, t in zip(queries_s.s[:20], queries_s.t[:20])]
    for frac in (0.5, 0.2, 0.08):
        compress_to_fraction(fresh_ehl, frac)
        for (s, t), dref in zip(zip(queries_s.s[:20], queries_s.t[:20]), refs):
            d, _ = query(fresh_ehl, s, t, want_path=False)
            assert d == pytest.approx(dref, abs=1e-8)


def test_merged_region_is_label_superset(fresh_ehl):
    """Region labels must be the union of member-cell labels (correctness)."""
    import copy
    before = {ci: fresh_ehl.regions[int(fresh_ehl.mapper[ci])].keys.copy()
              for ci in range(fresh_ehl.nx * fresh_ehl.ny)}
    compress_to_fraction(fresh_ehl, 0.25)
    for ci, keys in before.items():
        r = fresh_ehl.regions[int(fresh_ehl.mapper[ci])]
        assert np.isin(keys, r.keys).all()


def test_mapper_consistency_after_compression(fresh_ehl):
    compress_to_fraction(fresh_ehl, 0.2)
    C = fresh_ehl.nx * fresh_ehl.ny
    cells_seen = []
    for rid, r in fresh_ehl.regions.items():
        assert r.rid == rid
        cells_seen.extend(r.cells)
        for ci in r.cells:
            assert int(fresh_ehl.mapper[ci]) == rid
    assert sorted(cells_seen) == list(range(C))


def test_regions_stay_grid_connected(fresh_ehl):
    """Merging only adjacent regions keeps every region 4-connected."""
    compress_to_fraction(fresh_ehl, 0.15)
    for r in fresh_ehl.regions.values():
        cells = set(r.cells)
        start = next(iter(cells))
        seen = {start}
        stack = [start]
        while stack:
            c = stack.pop()
            for nb in fresh_ehl.cell_neighbors(c):
                if nb in cells and nb not in seen:
                    seen.add(nb)
                    stack.append(nb)
        assert seen == cells, f"region {r.rid} disconnected"


def test_compress_to_single_region_halts(fresh_ehl):
    stats = compress(fresh_ehl, budget_bytes=0)
    assert stats.hit_single_region
    assert len(fresh_ehl.regions) == 1
    # even at one region the index still answers queries (worst-case EHL*)


def test_single_region_still_optimal(fresh_ehl, graph_s, queries_s):
    compress(fresh_ehl, budget_bytes=0)
    for s, t in zip(queries_s.s[:10], queries_s.t[:10]):
        dref, _ = astar(graph_s, s, t)
        d, _ = query(fresh_ehl, s, t, want_path=False)
        assert d == pytest.approx(dref, abs=1e-8)


def test_workload_aware_keeps_cluster_cells_finer(scene_s, graph_s, hl_s):
    """Fig. 5 behaviour: hot cells end up in smaller regions."""
    from repro.core.grid import build_ehl
    idx_u = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    idx_w = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    hist = cluster_queries(scene_s, graph_s, k=2, n=150, seed=5,
                           require_path=False)
    scores = workload_scores(idx_w, hist)
    compress_to_fraction(idx_u, 0.10)
    compress_to_fraction(idx_w, 0.10, cell_scores=scores, alpha=0.2)

    hot = np.nonzero(scores > 1.0)[0]
    def mean_hot_region_size(idx):
        return np.mean([len(idx.regions[int(idx.mapper[c])].cells) for c in hot])
    assert mean_hot_region_size(idx_w) < mean_hot_region_size(idx_u)


def test_workload_aware_optimality(scene_s, graph_s, hl_s):
    from repro.core.grid import build_ehl
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    hist = cluster_queries(scene_s, graph_s, k=2, n=100, seed=6,
                           require_path=False)
    scores = workload_scores(idx, hist)
    compress_to_fraction(idx, 0.08, cell_scores=scores, alpha=0.2)
    ev = uniform_queries(scene_s, graph_s, 15, seed=13)
    for s, t in zip(ev.s, ev.t):
        dref, _ = astar(graph_s, s, t)
        d, _ = query(idx, s, t, want_path=False)
        assert d == pytest.approx(dref, abs=1e-8)
