"""launch/train.py end-to-end: loss falls, faults recover, resume works."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _train(tmp, extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "tinyllama-1.1b", "--reduced",
           "--batch", "4", "--seq", "64", "--lr", "3e-3",
           "--ckpt-dir", str(tmp)] + extra
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_loss_decreases_and_fault_recovery(tmp_path):
    log = _train(tmp_path, ["--steps", "16", "--ckpt-every", "4",
                            "--fault-inject", "6"])
    assert "[guard] restored step" in log
    losses = [float(l.split("loss=")[1].split()[0])
              for l in log.splitlines() if l.startswith("step")]
    assert len(losses) == 16
    assert losses[-1] < losses[0]


def test_resume_from_checkpoint(tmp_path):
    _train(tmp_path, ["--steps", "8", "--ckpt-every", "4"])
    log = _train(tmp_path, ["--steps", "12", "--resume"])
    assert "resumed from step 7" in log
