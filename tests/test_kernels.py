"""Pallas kernels vs pure-jnp oracles: shape sweeps + hypothesis properties.

Kernels run in interpret mode on CPU — the kernel *bodies* execute exactly as
they would inside Mosaic, so agreement here validates the kernel math and the
BlockSpec/padding plumbing.
"""

import numpy as np
import pytest
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # test dep (pyproject [test]); skip, not error
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.label_join import label_join_rowmin
from repro.kernels.segvis import segvis


def _rand_segs(rng, n, e):
    p = rng.uniform(0, 10, (n, 2)).astype(np.float32)
    q = rng.uniform(0, 10, (n, 2)).astype(np.float32)
    ea = rng.uniform(0, 10, (e, 2)).astype(np.float32)
    eb = rng.uniform(0, 10, (e, 2)).astype(np.float32)
    return map(jnp.asarray, (p, q, ea, eb))


@pytest.mark.parametrize("n", [1, 7, 256, 300])
@pytest.mark.parametrize("e", [1, 64, 512, 700])
def test_segvis_kernel_matches_ref_shapes(n, e):
    rng = np.random.default_rng(n * 1000 + e)
    p, q, ea, eb = _rand_segs(rng, n, e)
    ref = ops.segvis_ref(p, q, ea, eb)
    ker = segvis(p, q, ea, eb, interpret=True)
    assert (np.asarray(ref) == np.asarray(ker)).all()


@pytest.mark.parametrize("seg_blk,edge_blk", [(128, 128), (256, 512), (512, 256)])
def test_segvis_block_shape_invariance(seg_blk, edge_blk):
    rng = np.random.default_rng(5)
    p, q, ea, eb = _rand_segs(rng, 333, 257)
    ref = ops.segvis_ref(p, q, ea, eb)
    ker = segvis(p, q, ea, eb, seg_blk=seg_blk, edge_blk=edge_blk,
                 interpret=True)
    assert (np.asarray(ref) == np.asarray(ker)).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_segvis_property_blocked_iff_any_edge_blocks(seed):
    """Decomposition property: vis(all edges) == AND over single edges."""
    rng = np.random.default_rng(seed)
    p, q, ea, eb = _rand_segs(rng, 16, 8)
    full = np.asarray(ops.segvis_ref(p, q, ea, eb))
    single = np.stack([np.asarray(ops.segvis_ref(p, q, ea[i:i+1], eb[i:i+1]))
                       for i in range(ea.shape[0])])
    assert (full == single.all(axis=0)).all()


def _rand_join(rng, b, l, hubs=64, dtype=np.float32):
    hub_s = np.sort(rng.integers(0, hubs, (b, l)).astype(np.int32), axis=1)
    hub_t = np.sort(rng.integers(0, hubs, (b, l)).astype(np.int32), axis=1)
    vd_s = rng.uniform(0, 100, (b, l)).astype(dtype)
    vd_t = rng.uniform(0, 100, (b, l)).astype(dtype)
    # sprinkle infinities (invisible via labels)
    vd_s[rng.random((b, l)) < 0.2] = np.inf
    vd_t[rng.random((b, l)) < 0.2] = np.inf
    return map(jnp.asarray, (hub_s, vd_s, hub_t, vd_t))


@pytest.mark.parametrize("b", [1, 5, 8, 33])
@pytest.mark.parametrize("l", [16, 128, 384])
def test_label_join_kernel_matches_ref_shapes(b, l):
    rng = np.random.default_rng(b * 7919 + l)
    hs, vs, ht, vt = _rand_join(rng, b, l)
    ref = ops.label_join_ref(hs, vs, ht, vt)
    ker = ops.label_join_kernel(hs, vs, ht, vt, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker), rtol=1e-6)


@pytest.mark.parametrize("b_blk,t_blk", [(1, 128), (8, 128), (16, 256)])
def test_label_join_block_invariance(b_blk, t_blk):
    rng = np.random.default_rng(11)
    hs, vs, ht, vt = _rand_join(rng, 19, 200)
    ref = ops.label_join_rowmin_ref(hs, vs, ht, vt)
    ker = label_join_rowmin(hs, vs, ht, vt, b_blk=b_blk, t_blk=t_blk,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_label_join_property_matches_bruteforce(seed):
    """Against an O(L^2) python brute force with exact merge-join semantics."""
    rng = np.random.default_rng(seed)
    hs, vs, ht, vt = _rand_join(rng, 4, 24, hubs=8)
    ref = np.asarray(ops.label_join_ref(hs, vs, ht, vt))
    hs, vs, ht, vt = map(np.asarray, (hs, vs, ht, vt))
    for b in range(4):
        best = np.inf
        for i in range(24):
            for j in range(24):
                if hs[b, i] == ht[b, j]:
                    best = min(best, vs[b, i] + vt[b, j])
        assert (ref[b] == pytest.approx(best, rel=1e-6)) or \
               (np.isinf(ref[b]) and np.isinf(best))


def test_label_join_hubdense_matches_ref():
    rng = np.random.default_rng(3)
    hs, vs, ht, vt = _rand_join(rng, 9, 64, hubs=32)
    ref = np.asarray(ops.label_join_ref(hs, vs, ht, vt))
    dense = np.asarray(ops.label_join_hubdense_ref(hs, vs, ht, vt, num_hubs=32))
    np.testing.assert_allclose(ref, dense, rtol=1e-6)


def test_all_inf_labels_give_inf():
    b, l = 4, 128
    hs = jnp.zeros((b, l), jnp.int32)
    vs = jnp.full((b, l), jnp.inf, jnp.float32)
    out = ops.label_join_kernel(hs, vs, hs, vs, interpret=True)
    assert np.isinf(np.asarray(out)).all()
