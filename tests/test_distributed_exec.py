"""Multi-device execution tests (subprocess: 8 host devices).

The main test process must keep the single real CPU device (conftest rule),
so shard_map behaviours — EP dispatch, distributed flash-decode, int8
compressed psum — execute in a child interpreter with
``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_ep_matches_gspmd_reference():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import layers as ll
        from repro.distributed import hints
        from repro.distributed.compat import make_mesh
        from repro.distributed.moe_ep import moe_block_ep

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("deepseek-v3-671b").reduced()
        key = jax.random.PRNGKey(0)
        p = jax.tree.map(lambda a: a[0], ll.init_moe(cfg, key, 1, jnp.float32))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        cap = 1 << 20
        ref = ll.moe_block(cfg, p, x, cap)
        with hints.mesh_hints(mesh), mesh:
            out = jax.jit(lambda p, x: moe_block_ep(cfg, p, x, cap))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        g1 = jax.grad(lambda p: (ll.moe_block(cfg, p, x, cap) ** 2).mean())(p)
        with hints.mesh_hints(mesh), mesh:
            g2 = jax.jit(jax.grad(
                lambda p: (moe_block_ep(cfg, p, x, cap) ** 2).mean()))(p)
        ge = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
        print("ERR", err, ge)
    """)
    err, gerr = [float(x) for x in out.split("ERR")[1].split()]
    assert err < 1e-4 and gerr < 1e-5


def test_flash_decode_matches_plain():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.distributed import hints
        from repro.distributed.compat import make_mesh
        from repro.distributed.flash_decode import (
            decode_attention_dist, seq_sharded_decode_applicable)
        from repro.models.layers import decode_attention

        mesh = make_mesh((2, 4), ("data", "model"))
        B, Smax, K, H, hd = 4, 32, 3, 6, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, 1, H, hd))
        kc = jax.random.normal(ks[1], (B, Smax, K, hd))
        vc = jax.random.normal(ks[2], (B, Smax, K, hd))
        kn = jax.random.normal(ks[3], (B, 1, K, hd))
        vn = jax.random.normal(ks[4], (B, 1, K, hd))
        assert seq_sharded_decode_applicable(mesh, B, Smax, K)
        errs = []
        for pos, w, cap in [(17, 0, 0.0), (9, 5, 30.0), (31, 0, 50.0)]:
            with hints.mesh_hints(mesh), mesh:
                od, kd, vd = jax.jit(lambda *a: decode_attention_dist(
                    *a, pos, window=w, softcap=cap))(q, kc, vc, kn, vn)
            kr = jax.lax.dynamic_update_slice_in_dim(kc, kn, pos, axis=1)
            vr = jax.lax.dynamic_update_slice_in_dim(vc, vn, pos, axis=1)
            orf = decode_attention(q, kr, vr, pos + 1, window=w, softcap=cap)
            errs.append(float(jnp.abs(od - orf).max()))
            errs.append(float(jnp.abs(kd - kr).max()))
        print("ERR", max(errs))
    """)
    assert float(out.split("ERR")[1]) < 1e-5


def test_train_step_on_8_device_mesh():
    """Full sharded train step (FSDP+TP) runs and loss decreases."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.optim import adamw
        from repro.distributed.sharding import param_shardings, batch_spec
        from repro.distributed import hints
        from repro.distributed.compat import make_mesh

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = get_config("tinyllama-1.1b").reduced()
        with hints.mesh_hints(mesh), mesh:
            pshapes = jax.eval_shape(
                lambda k: T.init_params(cfg, k, dtype=jnp.float32),
                jax.random.PRNGKey(0))
            psh = param_shardings(pshapes, mesh)
            params = jax.jit(lambda k: T.init_params(cfg, k,
                                                     dtype=jnp.float32),
                             out_shardings=psh)(jax.random.PRNGKey(0))
            ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=30)
            opt = adamw.init_state(params, ocfg)
            bsh = NamedSharding(mesh, batch_spec(mesh))

            @jax.jit
            def step(params, opt, batch):
                l, g = jax.value_and_grad(
                    lambda p: T.loss_fn(cfg, p, batch))(params)
                params, opt, _ = adamw.apply_updates(params, g, opt, ocfg)
                return params, opt, l

            losses = []
            for i in range(12):
                tok = jax.random.randint(jax.random.PRNGKey(i % 3),
                                         (8, 64), 0, cfg.vocab)
                tok = jax.device_put(tok, bsh)
                params, opt, l = step(params, opt, tok)
                losses.append(float(l))
        print("LOSS", losses[0], losses[-1])
    """)
    first, last = [float(x) for x in out.split("LOSS")[1].split()]
    assert last < first


def test_narrow_view_bucketed_correctness(scene_s, graph_s, hl_s, queries_s):
    """Width-bucketed routing returns exactly the full-width distances."""
    import jax.numpy as jnp
    from repro.core.grid import build_ehl
    from repro.core.compression import compress_to_fraction
    from repro.core.packed import (pack_index, pack_bucketed, query_batch,
                                   query_batch_bucketed)
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    compress_to_fraction(idx, 0.3)
    pk = pack_index(idx)
    bx = pack_bucketed(idx)
    s = jnp.asarray(queries_s.s.astype("float32"))
    t = jnp.asarray(queries_s.t.astype("float32"))
    full = query_batch(pk, s, t)
    buck = query_batch_bucketed(bx, s, t)
    np.testing.assert_allclose(buck, np.asarray(full), rtol=0, atol=0)
