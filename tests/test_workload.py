"""Workload construction: cluster rects, histograms, score feasibility."""

import numpy as np
import pytest

from repro.core.geometry import Scene, points_strictly_inside
from repro.core.workload import (_free_points_in_rect, cluster_queries,
                                 historical_workload, make_clusters,
                                 uniform_queries, workload_scores)


def test_make_clusters_rects_in_bounds_with_free_points(scene_s):
    rng = np.random.default_rng(3)
    rects = make_clusters(scene_s, k=4, rng=rng)
    assert len(rects) == 4
    for x0, y0, x1, y1 in rects:
        assert 0.0 <= x0 < x1 <= scene_s.width
        assert 0.0 <= y0 < y1 <= scene_s.height
        pts = _free_points_in_rect(scene_s, (x0, y0, x1, y1), 4,
                                   np.random.default_rng(5))
        assert pts.shape == (4, 2)
        assert (~points_strictly_inside(scene_s, pts)).all()
        assert (pts[:, 0] >= x0).all() and (pts[:, 0] <= x1).all()
        assert (pts[:, 1] >= y0).all() and (pts[:, 1] <= y1).all()


def test_free_points_in_rect_strict_raises_on_blocked_rect():
    """A rect fully inside an obstacle must raise, not silently short-return
    (the old behavior propagated short arrays into QuerySets)."""
    square = np.array([[2.0, 2.0], [8.0, 2.0], [8.0, 8.0], [2.0, 8.0]])
    scene = Scene.build([square], width=10.0, height=10.0)
    rng = np.random.default_rng(0)
    with pytest.raises(RuntimeError, match="free points"):
        _free_points_in_rect(scene, (3.0, 3.0, 7.0, 7.0), 4, rng)
    # probing mode still returns what it found (here: nothing)
    got = _free_points_in_rect(scene, (3.0, 3.0, 7.0, 7.0), 4, rng,
                               strict=False)
    assert len(got) == 0


def test_historical_workload_counts_sum(ehl_s, scene_s, graph_s):
    qs = uniform_queries(scene_s, graph_s, 25, seed=7, require_path=False)
    w = historical_workload(ehl_s, qs)
    assert w.sum() == len(qs.s) + len(qs.t)       # every endpoint counted
    assert (w >= 0).all() and w.shape == (ehl_s.nx * ehl_s.ny,)
    scores = workload_scores(ehl_s, qs)
    assert (scores >= 1.0).all()
    assert scores.sum() == pytest.approx(w.sum() + ehl_s.nx * ehl_s.ny)


def test_cluster_queries_endpoints_in_cluster_rects(scene_s, graph_s):
    qs = cluster_queries(scene_s, graph_s, k=2, n=30, seed=9,
                         require_path=False)
    assert qs.s.shape == (30, 2) and qs.t.shape == (30, 2)
    assert (~points_strictly_inside(scene_s, qs.s)).all()
    assert (~points_strictly_inside(scene_s, qs.t)).all()


def test_workload_aware_budget_feasibility(scene_s, graph_s, hl_s):
    """Eq. 5 scores change *which* regions merge, never whether the budget
    is reachable: both uniform and workload-aware compression must land
    under the same byte budget."""
    from repro.core.compression import compress_to_fraction
    from repro.core.grid import build_ehl

    hist = cluster_queries(scene_s, graph_s, k=2, n=120, seed=4,
                           require_path=False)
    idx_u = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    idx_w = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    scores = workload_scores(idx_w, hist)
    st_u = compress_to_fraction(idx_u, 0.15)
    st_w = compress_to_fraction(idx_w, 0.15, cell_scores=scores, alpha=0.2)
    assert st_u.budget == st_w.budget
    assert st_u.final_bytes <= st_u.budget or st_u.hit_single_region
    assert st_w.final_bytes <= st_w.budget or st_w.hit_single_region
    assert idx_w.label_memory() == st_w.final_bytes
