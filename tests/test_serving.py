"""Serving engine: PathServer batching/stats + LMServer decode loop."""

import numpy as np
import pytest

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import pack_index
from repro.core.query import query
from repro.serving.engine import LMServer, PathServer


@pytest.fixture(scope="module")
def server_setup(scene_s, graph_s, hl_s, queries_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    truth = np.array([query(idx, s, t, want_path=False)[0]
                      for s, t in zip(queries_s.s, queries_s.t)])
    compress_to_fraction(idx, 0.3)
    return pack_index(idx), truth


def test_path_server_answers_match_oracle(server_setup, queries_s):
    pk, truth = server_setup
    srv = PathServer(pk, batch_size=16)
    srv.warmup()
    d = srv.query(queries_s.s.astype(np.float32),
                  queries_s.t.astype(np.float32))
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


def test_path_server_ragged_tail_batch(server_setup, queries_s):
    pk, truth = server_setup
    srv = PathServer(pk, batch_size=32)
    n = 37                                   # not a multiple of 32
    d = srv.query(queries_s.s[:n].astype(np.float32),
                  queries_s.t[:n].astype(np.float32))
    assert d.shape == (n,)
    np.testing.assert_allclose(d, truth[:n], rtol=1e-4, atol=1e-4)
    assert srv.stats.queries == n
    assert srv.stats.batches == 2
    assert srv.stats.us_per_query > 0


def test_lm_server_greedy_decode():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, S = 2, 8
    cache = T.init_cache(cfg, B, S + 16, dtype=jnp.float32)
    prompt = np.array([[1, 2, 3], [4, 5, 6]], dtype=np.int32)
    # prefill by stepping the prompt
    srv = LMServer(cfg, params, cache)
    for i in range(prompt.shape[1] - 1):
        srv._step(params, srv.cache, jnp.asarray(prompt[:, i:i + 1]))
    out = srv.generate(prompt, n_new=5)
    assert out.shape == (B, 5)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    assert srv.stats.queries == B * 5
