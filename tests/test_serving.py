"""Serving engine: PathServer batching/stats + LMServer decode loop."""

import numpy as np
import pytest

from repro.core.compression import compress_to_fraction
from repro.core.grid import build_ehl
from repro.core.packed import pack_index
from repro.core.query import query
from repro.serving.engine import LMServer, PathServer


@pytest.fixture(scope="module")
def server_setup(scene_s, graph_s, hl_s, queries_s):
    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    truth = np.array([query(idx, s, t, want_path=False)[0]
                      for s, t in zip(queries_s.s, queries_s.t)])
    compress_to_fraction(idx, 0.3)
    return pack_index(idx), truth


def test_path_server_answers_match_oracle(server_setup, queries_s):
    pk, truth = server_setup
    srv = PathServer(pk, batch_size=16)
    srv.warmup()
    d = srv.query(queries_s.s.astype(np.float32),
                  queries_s.t.astype(np.float32))
    np.testing.assert_allclose(d, truth, rtol=1e-4, atol=1e-4)


def test_path_server_ragged_tail_batch(server_setup, queries_s):
    pk, truth = server_setup
    srv = PathServer(pk, batch_size=32)
    n = 37                                   # not a multiple of 32
    d = srv.query(queries_s.s[:n].astype(np.float32),
                  queries_s.t[:n].astype(np.float32))
    assert d.shape == (n,)
    np.testing.assert_allclose(d, truth[:n], rtol=1e-4, atol=1e-4)
    assert srv.stats.queries == n
    assert srv.stats.batches == 2
    assert srv.stats.us_per_query > 0
    # slots are counted once per dispatch, so padding never double-counts
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0
        assert bstats.queries <= bstats.slots


def test_warmup_covers_every_jit_entry(scene_s, graph_s, hl_s, queries_s):
    """``warmup(paths=True)`` must leave no serving entry cold: after it,
    live traffic (every bucket, ragged tails, the argmin/path variant) may
    not trigger a single new XLA trace (``core.packed.TRACES``)."""
    from repro.core import packed
    from repro.core.packed import pack_bucketed
    from repro.serving.query_engine import JnpEngine

    idx = build_ehl(scene_s, 2.0, graph=graph_s, hl=hl_s)
    compress_to_fraction(idx, 0.3)
    bx = pack_bucketed(idx)
    srv = PathServer(JnpEngine(bx), batch_size=16)
    srv.warmup(paths=True)
    c0 = packed.TRACES.count
    s = queries_s.s.astype(np.float32)
    t = queries_s.t.astype(np.float32)
    srv.query(s, t)                              # every bucket present
    srv.query(s[:7], t[:7])                      # ragged tail (padded)
    srv.query_paths(s[:5], t[:5], host_index=idx)  # argmin entries
    assert packed.TRACES.count == c0, \
        "serving traffic hit a jit entry warmup did not trace"
    for bstats in srv.stats.per_bucket.values():
        assert bstats.occupancy <= 1.0
    # and the counter is live, not vacuously constant: an unseen batch
    # shape must retrace
    srv2 = PathServer(JnpEngine(bx), batch_size=8)
    srv2.query(s[:3], t[:3])
    assert packed.TRACES.count > c0


def test_trace_entries_taxonomy_matches_decorators():
    """``TRACE_ENTRIES`` is the static jit-entry taxonomy the docs, the
    jit-registry checker, and compile attribution all key off — it must
    equal the set of ``@_jit_entry`` names actually defined, with no
    duplicates (the ``repolint`` jit-registry rule enforces the same
    invariant in CI; this is the in-process cross-check)."""
    import ast
    import inspect

    from repro.core import packed

    assert len(packed.TRACE_ENTRIES) == len(set(packed.TRACE_ENTRIES))
    tree = ast.parse(inspect.getsource(packed))
    decorated = set()
    for node in ast.walk(tree):
        for dec in getattr(node, "decorator_list", ()):
            if isinstance(dec, ast.Call) and \
                    getattr(dec.func, "id", "") == "_jit_entry" and \
                    dec.args and isinstance(dec.args[0], ast.Constant):
                decorated.add(dec.args[0].value)
    assert decorated == set(packed.TRACE_ENTRIES)
    # every wrapped entry carries its name for attribution
    for name in packed.TRACE_ENTRIES:
        fn = getattr(packed, name, None)
        if fn is not None and hasattr(fn, "entry"):
            assert fn.entry == name


def test_lm_server_greedy_decode():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, S = 2, 8
    cache = T.init_cache(cfg, B, S + 16, dtype=jnp.float32)
    prompt = np.array([[1, 2, 3], [4, 5, 6]], dtype=np.int32)
    # prefill by stepping the prompt
    srv = LMServer(cfg, params, cache)
    for i in range(prompt.shape[1] - 1):
        srv._step(params, srv.cache, jnp.asarray(prompt[:, i:i + 1]))
    out = srv.generate(prompt, n_new=5)
    assert out.shape == (B, 5)
    assert (out >= 0).all() and (out < cfg.vocab).all()
    assert srv.stats.queries == B * 5
