"""repro.analysis: checker fixtures, suppressions, lock order, CLI.

Each checker gets a known-bad fixture (must produce findings), a
known-good fixture (must not), and a suppression fixture (finding
silenced by ``# repolint: disable=<rule>``).  The lock-order section
seeds an AB/BA deadlock and asserts both the rank inversion and the
cycle are reported; the runtime ``OrderedLock`` sanitizer is exercised
directly, including as the lock behind a ``threading.Condition``.  The
final regression runs the full pass over the real tree and requires
zero findings — the same gate CI enforces.
"""

import json
import os
import threading
import time

import pytest

from repro.analysis import load_project, run
from repro.analysis.cli import main as cli_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_project(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    return load_project([str(tmp_path)], root=str(tmp_path))


def findings_for(tmp_path, files, rule):
    return [f for f in run(make_project(tmp_path, files), select=[rule])]


# ------------------------------------------------------------ jit-registry
def test_jit_registry_flags_raw_jax_jit(tmp_path):
    fs = {"src/repro/serving/j.py":
          "import jax\nf = jax.jit(lambda x: x)\n"}
    out = findings_for(tmp_path, fs, "jit-registry")
    assert len(out) == 1 and out[0].line == 2
    assert "raw jax.jit" in out[0].message


def test_jit_registry_flags_partial_jax_jit(tmp_path):
    fs = {"src/repro/serving/j.py":
          "import functools\nimport jax\n"
          "@functools.partial(jax.jit, static_argnames=('n',))\n"
          "def f(x, n):\n    return x\n"}
    out = findings_for(tmp_path, fs, "jit-registry")
    assert len(out) == 1 and out[0].line == 3


def test_jit_registry_taxonomy_drift_both_directions(tmp_path):
    fs = {"src/repro/core/packed.py": (
        "TRACE_ENTRIES = ('a',)\n"
        "def _jit_entry(entry, **kw):\n"
        "    def deco(fn):\n        return fn\n    return deco\n"
        "@_jit_entry('b')\n"
        "def entry_b():\n    pass\n")}
    msgs = [f.message for f in findings_for(tmp_path, fs, "jit-registry")]
    assert any("'b' is not listed" in m for m in msgs)
    assert any("lists 'a'" in m for m in msgs)


def test_jit_registry_clean_and_suppressed(tmp_path):
    assert not findings_for(
        tmp_path, {"src/repro/serving/ok.py": "def f():\n    return 1\n"},
        "jit-registry")
    fs = {"src/repro/serving/j.py":
          "import jax\n"
          "f = jax.jit(lambda x: x)  "
          "# repolint: disable=jit-registry -- fixture\n"}
    assert not findings_for(tmp_path, fs, "jit-registry")


# ----------------------------------------------------------- hot-path-sync
_HOT_BAD = """\
import numpy as np

class Engine:
    def stage(self, s):
        return np.asarray(s)

    def dispatch_staged(self, staged):
        return self._finish(staged)

    def _finish(self, staged):
        return staged.item()
"""


def test_hot_path_sync_flags_direct_and_via_callee(tmp_path):
    out = findings_for(tmp_path, {"src/repro/serving/e.py": _HOT_BAD},
                       "hot-path-sync")
    lines = {f.line for f in out}
    assert 5 in lines                       # np.asarray inside stage
    assert 11 in lines                      # .item() via call graph
    via = [f for f in out if f.line == 11]
    assert "reached from" in via[0].message


def test_hot_path_sync_ignores_cold_functions(tmp_path):
    fs = {"src/repro/serving/e.py":
          "import numpy as np\n"
          "class Engine:\n"
          "    def build(self, s):\n"
          "        return np.asarray(s)\n"}
    assert not findings_for(tmp_path, fs, "hot-path-sync")


def test_hot_path_sync_suppression(tmp_path):
    fs = {"src/repro/serving/e.py":
          "import numpy as np\n"
          "class Engine:\n"
          "    def stage(self, s):\n"
          "        # repolint: disable=hot-path-sync -- host input\n"
          "        return np.asarray(s)\n"}
    assert not findings_for(tmp_path, fs, "hot-path-sync")


# ---------------------------------------------------------------- layering
def test_layering_obs_toplevel_jax(tmp_path):
    out = findings_for(tmp_path,
                       {"src/repro/obs/x.py": "import jax\n"}, "layering")
    assert len(out) == 1 and "without jax" in out[0].message


def test_layering_obs_lazy_jax_ok(tmp_path):
    fs = {"src/repro/obs/x.py":
          "def f():\n    import jax\n    return jax\n"}
    assert not findings_for(tmp_path, fs, "layering")


def test_layering_core_never_imports_serving(tmp_path):
    fs = {"src/repro/core/x.py":
          "def f():\n    from repro.serving import engine\n"}
    out = findings_for(tmp_path, fs, "layering")
    assert len(out) == 1 and "leaf layer" in out[0].message


def test_layering_benchmarks_deep_import(tmp_path):
    fs = {"src/repro/core/__init__.py": "from .packed import pack\n",
          "src/repro/core/packed.py": "def pack():\n    return 1\n",
          "benchmarks/b.py": "from repro.core.packed import pack\n"}
    out = findings_for(tmp_path, fs, "layering")
    assert len(out) == 1 and "deep-imports" in out[0].message


def test_layering_benchmarks_init_export_ok(tmp_path):
    fs = {"src/repro/core/__init__.py": "from .packed import pack\n",
          "src/repro/core/packed.py": "def pack():\n    return 1\n",
          "benchmarks/b.py": "from repro.core import pack\n"}
    assert not findings_for(tmp_path, fs, "layering")


def test_layering_benchmarks_unexported_name(tmp_path):
    fs = {"src/repro/core/__init__.py": "from .packed import pack\n",
          "src/repro/core/packed.py":
              "def pack():\n    return 1\ndef _hidden():\n    return 2\n",
          "benchmarks/b.py": "from repro.core import _hidden\n"}
    out = findings_for(tmp_path, fs, "layering")
    assert len(out) == 1 and "does not export" in out[0].message


# ----------------------------------------------------------- monotonic-time
def test_monotonic_time_flags_wall_clock(tmp_path):
    fs = {"src/repro/serving/t.py":
          "import time\ndef f():\n    return time.time()\n"}
    out = findings_for(tmp_path, fs, "monotonic-time")
    assert len(out) == 1 and out[0].line == 3


def test_monotonic_time_bare_import_form(tmp_path):
    fs = {"src/repro/serving/t.py":
          "from time import time\ndef f():\n    return time()\n"}
    assert findings_for(tmp_path, fs, "monotonic-time")


def test_monotonic_time_allowlist_and_suppression(tmp_path):
    fs = {"src/repro/obs/timing.py":
          "import time\ndef wall():\n    return time.time()\n"}
    assert not findings_for(tmp_path, fs, "monotonic-time")
    fs = {"src/repro/serving/t.py":
          "import time\n"
          "t = time.time()  # repolint: disable=monotonic-time -- meta\n"}
    assert not findings_for(tmp_path, fs, "monotonic-time")


# --------------------------------------------------------------- lock-order
_FIXTURE_LOCKS = """\
LOCK_RANKS = {"a": 1, "b": 2}
def make_lock(name):
    import threading
    return threading.Lock()
"""

_AB_BA = """\
from repro.obs.locks import make_lock

class S:
    def __init__(self):
        self._a = make_lock("a")
        self._b = make_lock("b")

    def good(self):
        with self._a:
            with self._b:
                return 1

    def bad(self):
        with self._b:
            with self._a:
                return 2
"""


def test_lock_order_catches_ab_ba_deadlock(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": _AB_BA}
    out = findings_for(tmp_path, fs, "lock-order")
    msgs = [f.message for f in out]
    assert any("rank inversion" in m for m in msgs), msgs
    assert any("cycle" in m for m in msgs), msgs
    # the inversion is reported at the inner acquisition in bad()
    inv = [f for f in out if "rank inversion" in f.message]
    assert inv[0].line == 15


def test_lock_order_clean_nesting_passes(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": (
              "from repro.obs.locks import make_lock\n"
              "class S:\n"
              "    def __init__(self):\n"
              "        self._a = make_lock('a')\n"
              "        self._b = make_lock('b')\n"
              "    def good(self):\n"
              "        with self._a:\n"
              "            with self._b:\n"
              "                return 1\n")}
    assert not findings_for(tmp_path, fs, "lock-order")


def test_lock_order_cross_function_edge(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": (
              "from repro.obs.locks import make_lock\n"
              "class S:\n"
              "    def __init__(self):\n"
              "        self._a = make_lock('a')\n"
              "        self._b = make_lock('b')\n"
              "    def outer(self):\n"
              "        with self._b:\n"
              "            return self.inner()\n"
              "    def inner(self):\n"
              "        with self._a:\n"
              "            return 1\n")}
    out = findings_for(tmp_path, fs, "lock-order")
    assert any("rank inversion" in f.message and "via" in f.message
               for f in out)


def test_lock_order_raw_lock_in_monitored_module(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": (
              "import threading\n"
              "class S:\n"
              "    def __init__(self):\n"
              "        self._lock = threading.Lock()\n")}
    out = findings_for(tmp_path, fs, "lock-order")
    assert len(out) == 1 and "raw threading lock" in out[0].message


def test_lock_order_unranked_name(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": (
              "from repro.obs.locks import make_lock\n"
              "class S:\n"
              "    def __init__(self):\n"
              "        self._x = make_lock('zz')\n")}
    out = findings_for(tmp_path, fs, "lock-order")
    assert len(out) == 1 and "no declared rank" in out[0].message


def test_lock_order_condition_aliases_lock_rank(tmp_path):
    fs = {"src/repro/obs/locks.py": _FIXTURE_LOCKS,
          "src/repro/indexing/swap.py": (
              "import threading\n"
              "from repro.obs.locks import make_lock\n"
              "class S:\n"
              "    def __init__(self):\n"
              "        self._a = make_lock('a')\n"
              "        self._cond = threading.Condition(self._a)\n"
              "        self._b = make_lock('b')\n"
              "    def f(self):\n"
              "        with self._b:\n"
              "            with self._cond:\n"
              "                return 1\n")}
    out = findings_for(tmp_path, fs, "lock-order")
    assert any("rank inversion" in f.message for f in out)


# ------------------------------------------------------------- suppressions
def test_file_level_suppression(tmp_path):
    fs = {"src/repro/serving/t.py":
          "# repolint: disable-file=monotonic-time -- fixture file\n"
          "import time\n"
          "def f():\n    return time.time()\n"
          "def g():\n    return time.time()\n"}
    assert not findings_for(tmp_path, fs, "monotonic-time")


def test_previous_line_suppression(tmp_path):
    fs = {"src/repro/serving/t.py":
          "import time\n"
          "def f():\n"
          "    # repolint: disable=monotonic-time -- why\n"
          "    return time.time()\n"}
    assert not findings_for(tmp_path, fs, "monotonic-time")


def test_suppression_is_per_rule(tmp_path):
    fs = {"src/repro/serving/t.py":
          "import time\n"
          "t = time.time()  # repolint: disable=jit-registry -- wrong rule\n"}
    assert findings_for(tmp_path, fs, "monotonic-time")


# -------------------------------------------------------------------- CLI
def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = tmp_path / "p1"
    bad.mkdir()
    (bad / "src" / "repro" / "serving").mkdir(parents=True)
    (bad / "src" / "repro" / "serving" / "t.py").write_text(
        "import time\nt = time.time()\n")
    rc = cli_main(["--root", str(bad), "--format", "json",
                   str(bad / "src")])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == 1
    assert payload["findings"][0]["rule"] == "monotonic-time"

    good = tmp_path / "p2"
    (good / "src").mkdir(parents=True)
    (good / "src" / "ok.py").write_text("x = 1\n")
    assert cli_main(["--root", str(good), str(good / "src")]) == 0
    capsys.readouterr()

    assert cli_main(["--list-rules"]) == 0
    listing = capsys.readouterr().out
    for rule in ("jit-registry", "hot-path-sync", "layering",
                 "monotonic-time", "lock-order"):
        assert rule in listing

    assert cli_main(["--select", "nope", str(good / "src"),
                     "--root", str(good)]) == 2
    assert cli_main([str(tmp_path / "empty-nothing")]) == 2


# ------------------------------------------------------ OrderedLock runtime
def test_make_lock_plain_by_default(monkeypatch):
    from repro.obs import locks
    monkeypatch.delenv("REPRO_LOCK_CHECK", raising=False)
    lk = locks.make_lock("obs.events")
    assert not isinstance(lk, locks.OrderedLock)
    with pytest.raises(KeyError):
        locks.make_lock("not-a-lock")


def test_ordered_lock_asserts_partial_order(monkeypatch):
    from repro.obs import locks
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    a = locks.make_lock("indexing.adapt")        # rank 10
    b = locks.make_lock("engine.swap")           # rank 30
    assert isinstance(a, locks.OrderedLock)
    with a:
        with b:
            assert locks.held_locks() == ["indexing.adapt", "engine.swap"]
    assert locks.held_locks() == []
    with b:
        with pytest.raises(locks.LockOrderError):
            a.acquire()
    assert locks.held_locks() == []
    with pytest.raises(KeyError):
        locks.make_lock("not-a-lock")


def test_ordered_lock_same_rank_rejected(monkeypatch):
    from repro.obs import locks
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    a1 = locks.make_lock("obs.series")
    a2 = locks.make_lock("obs.series")
    with a1:
        with pytest.raises(locks.LockOrderError):
            a2.acquire()


def test_ordered_lock_behind_condition(monkeypatch):
    from repro.obs import locks
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    lk = locks.make_lock("batcher.queue")
    cond = threading.Condition(lk)
    seen = []

    def waiter():
        with cond:
            cond.wait(timeout=5)
            seen.append(tuple(locks.held_locks()))

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)
    with cond:
        cond.notify_all()
    th.join(timeout=5)
    assert not th.is_alive()
    assert seen == [("batcher.queue",)]
    assert locks.held_locks() == []


def test_ordered_lock_stress_cross_subsystem(monkeypatch):
    """Threads hammering the real nesting shape (queue -> ticket,
    queue -> obs leaves) under the sanitizer: no LockOrderError."""
    from repro.obs import locks
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    queue = locks.make_lock("batcher.queue")
    ticket = locks.make_lock("batcher.ticket")
    series = locks.make_lock("obs.series")
    events = locks.make_lock("obs.events")
    errors = []

    def worker(_):
        try:
            for _ in range(200):
                with queue:
                    with series:
                        pass
                    with events:
                        pass
                with ticket:
                    pass
        except locks.LockOrderError as e:   # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors
    assert locks.held_locks() == []


# ------------------------------------------------------- full-tree regression
def test_full_tree_has_zero_findings():
    """The committed tree passes every checker — the same blocking gate
    CI runs via ``python -m repro.analysis src benchmarks``."""
    project = load_project([os.path.join(REPO, "src"),
                            os.path.join(REPO, "benchmarks")], root=REPO)
    assert project.modules, "expected sources under src/ and benchmarks/"
    out = run(project)
    assert out == [], "\n".join(f.render() for f in out)


def test_lock_ranks_cover_every_made_lock():
    """Every make_lock() call site in the tree names a declared rank —
    checked statically so a rename cannot drift past the table."""
    import ast as _ast

    from repro.obs.locks import LOCK_RANKS

    project = load_project([os.path.join(REPO, "src")], root=REPO)
    names = set()
    for mod in project.modules:
        for node in _ast.walk(mod.tree):
            if isinstance(node, _ast.Call) and \
                    getattr(node.func, "id",
                            getattr(node.func, "attr", "")) == "make_lock" \
                    and node.args and isinstance(node.args[0], _ast.Constant):
                names.add(node.args[0].value)
    assert names, "expected make_lock call sites in src/"
    assert names <= set(LOCK_RANKS)
